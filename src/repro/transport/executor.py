"""Chunked exchange executor — overlap communication with attention compute.

The monolithic all-gather in the Voltage path serializes the whole exchange
before the first attention FLOP.  :func:`ring_prefill_attention` instead
walks the sequence partitions as a ring: at every step each device
``ppermute``-forwards the K/V block it holds to its neighbour *while*
computing attention against the block it just received, merging partial
results with an online-softmax (flash-style) accumulator.  Each block
transfer is further split into ``overlap_chunks`` independent ``ppermute``
calls, giving XLA's scheduler chunk-granular freedom to double-buffer
communication under compute.  The result is numerically the same full
attention (float-roundoff vs the gather path), with comm hidden behind
compute instead of in front of it.

:func:`codec_prefill_attention` is the generic compressed exchange for
non-summarizing codecs (``int8``/``int4``/``topk``): encode the local K/V
partition, all-gather the compact payload, decode remote partitions, keep
the own partition exact, and run standard attention — the quantized
analogue of PRISM's "local exact + remote compressed" scheme.
:func:`codec_sim_attention` is its single-host oracle (the validation
target, mirroring ``simulate_prism_attention``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.prism_attention import (NEG_INF, _grouped_scores,
                                        _grouped_values, _softcap,
                                        chunked_reference_attention,
                                        reference_attention)
from repro.transport.codecs import CodecSpec, get_codec


def _spec_of(cfg) -> CodecSpec:
    return CodecSpec(L=cfg.L, param=cfg.codec_param)


# ---------------------------------------------------------------------------
# ring exchange with online-softmax merge
# ---------------------------------------------------------------------------

def _ppermute_chunks(x: jnp.ndarray, axis_name: str, perm, n_chunks: int,
                     token_axis: int = 1) -> jnp.ndarray:
    """One ring transfer split into ``n_chunks`` independent ``ppermute``
    calls along the token axis (chunk-granular double buffering)."""
    if n_chunks <= 1 or x.shape[token_axis] % n_chunks != 0:
        return jax.lax.ppermute(x, axis_name, perm)
    parts = jnp.split(x, n_chunks, axis=token_axis)
    return jnp.concatenate(
        [jax.lax.ppermute(c, axis_name, perm) for c in parts],
        axis=token_axis)


def _partial_block(qs, kb, vb, mb, *, q_offset, kv_offset, causal, scale,
                   logit_softcap):
    """Unnormalized attention of local queries against one K/V block:
    returns (o [B,Nq,H,dh] f32, m [B,H,Nq,1], l [B,H,Nq])."""
    Nq, Nk = qs.shape[1], kb.shape[1]
    logits = _grouped_scores(qs, kb) * scale
    logits = _softcap(logits, logit_softcap)
    qpos = q_offset + jnp.arange(Nq)[:, None]
    kpos = kv_offset + jnp.arange(Nk)[None, :]
    if causal:
        logits = jnp.where((qpos >= kpos)[None, None], logits, NEG_INF)
    logits = jnp.where(mb[:, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    w = jnp.exp(logits - m)
    return _grouped_values(w, vb), m, jnp.sum(w, axis=-1)


def _merge(acc, blk):
    """Online-softmax merge of two unnormalized partials."""
    o1, m1, l1 = acc
    o2, m2, l2 = blk
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    # o is [B,Nq,H,dh]; m/l carry [B,H,Nq] layout
    o = o1 * a1[..., 0].transpose(0, 2, 1)[..., None] \
        + o2 * a2[..., 0].transpose(0, 2, 1)[..., None]
    return o, m, l1 * a1[..., 0] + l2 * a2[..., 0]


def ring_prefill_attention(q, k, v, cfg, *, causal=False, window=None,
                           logit_softcap=None, scale=None, kv_mask=None):
    """Full-tensor exchange as a ring of ``ppermute`` steps overlapped with
    per-block attention (the chunked executor's Voltage path).  Numerically
    equivalent to the all-gather implementation up to float roundoff.
    """
    if window is not None:
        raise NotImplementedError(
            "ring exchange does not support sliding windows; windowed "
            "layers use the halo/voltage paths")
    from repro.core import exchange as xchg
    axis, Pn = cfg.seq_axis, cfg.seq_shards
    n_chunks = max(cfg.overlap_chunks, 1)
    if kv_mask is None:
        kv_mask = jnp.ones(k.shape[:2], dtype=bool)
    q, k, v = (xchg._pin_seq_sharding(t, axis) for t in (q, k, v))
    perm = [(i, (i + 1) % Pn) for i in range(Pn)]

    def ring(qs, ks, vs, ms):
        p = jax.lax.axis_index(axis)
        Np = qs.shape[1]
        dh = qs.shape[-1]
        scl = (dh ** -0.5) if scale is None else scale
        bufs, src = (ks, vs, ms), p
        acc = None
        for s in range(Pn):
            if s < Pn - 1:
                nxt = tuple(_ppermute_chunks(t, axis, perm, n_chunks)
                            for t in bufs)          # comm for step s+1 ...
            blk = _partial_block(                   # ... overlaps this block
                qs, bufs[0], bufs[1], bufs[2], q_offset=p * Np,
                kv_offset=src * Np, causal=causal, scale=scl,
                logit_softcap=logit_softcap)
            acc = blk if acc is None else _merge(acc, blk)
            if s < Pn - 1:
                bufs, src = nxt, (src - 1) % Pn
        o, _, l = acc
        out = o / l.transpose(0, 2, 1)[..., None]
        return out.astype(qs.dtype)

    bax = xchg._manual_batch_axes(q.shape[0], cfg)
    return xchg._seq_shard_map(ring, axis, n_masks=1, batch_axes=bax)(
        q, k, v, kv_mask)


# ---------------------------------------------------------------------------
# generic compressed exchange (non-summarizing codecs)
# ---------------------------------------------------------------------------

def codec_prefill_attention(q, k, v, cfg, *, causal=False, window=None,
                            logit_softcap=None, scale=None, kv_mask=None):
    """Codec exchange: encode local K/V, all-gather the compact payload,
    decode remote partitions (own partition stays exact), full attention.
    """
    from repro.core import exchange as xchg
    codec = get_codec(cfg.codec)
    if codec.summarizing:
        raise ValueError(f"codec {cfg.codec!r} is summarizing — it routes "
                         "through the PRISM scaling-aware path, not the "
                         "reconstruction exchange")
    if window is not None:
        # windowed layers exchange only a halo; reuse the exact voltage
        # machinery there (compression of an already-small halo is noise)
        from repro.core.exchange import ExchangeMode
        return xchg.exchange_attention(
            q, k, v, cfg.with_mode(ExchangeMode.VOLTAGE), causal=causal,
            window=window, logit_softcap=logit_softcap, scale=scale,
            kv_mask=kv_mask)
    axis, Pn = cfg.seq_axis, cfg.seq_shards
    spec = _spec_of(cfg)
    if kv_mask is None:
        kv_mask = jnp.ones(k.shape[:2], dtype=bool)
    q, k, v = (xchg._pin_seq_sharding(t, axis) for t in (q, k, v))

    def fn(qs, ks, vs, ms):
        p = jax.lax.axis_index(axis)
        B, Np, Hk, dh = ks.shape
        pk = codec.encode(ks, spec)
        pv = codec.encode(vs, spec)
        gather = lambda t: jax.lax.all_gather(t, axis)       # [P, ...]
        pk_all = jax.tree_util.tree_map(gather, pk)
        pv_all = jax.tree_util.tree_map(gather, pv)
        mg = jax.lax.all_gather(ms, axis, axis=1, tiled=True)  # [B, N]
        dec = jax.vmap(lambda pl: codec.decode(pl, spec, shape=ks.shape,
                                               dtype=ks.dtype))
        k_hat = jnp.moveaxis(dec(pk_all), 0, 1).reshape(B, Pn * Np, Hk, dh)
        v_hat = jnp.moveaxis(dec(pv_all), 0, 1).reshape(B, Pn * Np, Hk, dh)
        # own partition attends exactly (the PRISM local/remote split)
        k_hat = jax.lax.dynamic_update_slice_in_dim(
            k_hat, ks.astype(k_hat.dtype), p * Np, axis=1)
        v_hat = jax.lax.dynamic_update_slice_in_dim(
            v_hat, vs.astype(v_hat.dtype), p * Np, axis=1)
        return chunked_reference_attention(
            qs, k_hat, v_hat, causal=causal, q_offset=p * Np,
            logit_softcap=logit_softcap, scale=scale, kv_mask=mg)

    bax = xchg._manual_batch_axes(q.shape[0], cfg)
    return xchg._seq_shard_map(fn, axis, n_masks=1, batch_axes=bax)(
        q, k, v, kv_mask)


def codec_sim_attention(q, k, v, P: int, codec_name: str, spec: CodecSpec,
                        *, causal: bool = False,
                        logit_softcap: Optional[float] = None,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """Single-host oracle of the P-device codec exchange: every device sees
    its own partition exact and every remote partition through one codec
    encode→decode round trip.  Mirrors ``simulate_prism_attention``."""
    from repro.core.partition import partition_sequence
    codec = get_codec(codec_name)
    B, N, H, dh = q.shape
    Np = N // P
    qp = partition_sequence(q, P)
    kp = partition_sequence(k, P)
    vp = partition_sequence(v, P)
    k_hat = [codec.decode(codec.encode(kp[i], spec), spec,
                          shape=kp[i].shape, dtype=k.dtype)
             for i in range(P)]
    v_hat = [codec.decode(codec.encode(vp[i], spec), spec,
                          shape=vp[i].shape, dtype=v.dtype)
             for i in range(P)]
    outs = []
    for p in range(P):
        kc = jnp.concatenate(
            [kp[i] if i == p else k_hat[i] for i in range(P)], axis=1)
        vc = jnp.concatenate(
            [vp[i] if i == p else v_hat[i] for i in range(P)], axis=1)
        outs.append(reference_attention(
            qp[p], kc.astype(q.dtype), vc.astype(q.dtype), causal=causal,
            q_offset=p * Np, logit_softcap=logit_softcap, scale=scale))
    return jnp.concatenate(outs, axis=1)


def codec_sim_prefill_attention(q, k, v, cfg, *, causal=False, window=None,
                                logit_softcap=None, scale=None,
                                kv_mask=None):
    """``prism_sim``'s codec analogue: codec math on unpartitioned tensors
    (training / single-host validation)."""
    if window is not None:
        raise NotImplementedError("codec simulation with sliding window")
    if kv_mask is not None:
        raise NotImplementedError("codec simulation with padded kv_mask")
    return codec_sim_attention(q, k, v, cfg.seq_shards, cfg.codec,
                               _spec_of(cfg), causal=causal,
                               logit_softcap=logit_softcap, scale=scale)
