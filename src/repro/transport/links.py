"""Transport links — *how* exchanged bytes travel, with per-stage costs.

The paper's central measurement is that GLOO-over-WiFi communication is not
wire-limited but **staging**-limited: every collective crosses
GPU→CPU→GPU because embedded boards have no NVLink/PCIe peer path.  A
:class:`TransportLink` models one such path as explicit stages — host
staging, wire, payload reconstruction — each costed from the profiled
:class:`~repro.profiling.hardware.LinkProfile` constants and the live
bandwidth estimate:

* ``staged`` — the CPU-memory path (GLOO): D2H + H2D pinned copies through
  the profile's size-dependent staging curve, plus wire time and per-round
  RTT.
* ``direct`` — a peer/collective path (NVLink, TPU ICI): no host hop; wire
  time and RTT only.

:func:`exchange_cost` composes a codec with a link into the full
per-dispatch accounting the profiling backends and the session's telemetry
share — wire bytes, staged bytes, per-stage milliseconds, and the achieved
compression ratio.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Type

from repro.transport.codecs import CodecSpec, get_codec


class TransportError(RuntimeError):
    """One exchange over a link failed (flap, reset, staged-copy abort).

    Raised/recorded by the fault-injection layer and consumed by the
    retry machinery: a transport error is *retryable* by construction —
    the payload never left intact, so re-sending cannot duplicate work.
    ``worker`` names the endpoint whose dispatch failed; ``stage`` is the
    link stage that broke (``"staging"`` | ``"wire"`` | ``"decode"``).
    """

    def __init__(self, msg: str, worker: str = "", stage: str = "wire"):
        super().__init__(msg)
        self.worker = worker
        self.stage = stage
        self.retryable = True


@dataclasses.dataclass(frozen=True)
class LinkCost:
    """Per-stage cost of moving one dispatch's exchange traffic."""
    staging_ms: float = 0.0     # GPU↔CPU pinned copies (staged links only)
    wire_ms: float = 0.0        # bytes / bandwidth + per-round RTT
    decode_ms: float = 0.0      # payload reconstruction on the receiver

    @property
    def total_ms(self) -> float:
        return self.staging_ms + self.wire_ms + self.decode_ms

    def stages(self) -> Dict[str, float]:
        return {"staging_ms": self.staging_ms, "wire_ms": self.wire_ms,
                "decode_ms": self.decode_ms}


class TransportLink:
    """Protocol: subclass, set ``name``/``staged``, implement ``cost``."""

    name: str = ""
    staged: bool = False       # does traffic cross host memory?

    def cost(self, *, wire_bytes_per_call: float, n_calls: int,
             bandwidth_mbps: float, profile,
             raw_bytes_total: float = 0.0,
             decode_bw: float = 0.0) -> LinkCost:
        raise NotImplementedError

    @staticmethod
    def _wire_ms(wire_bytes_per_call, n_calls, bandwidth_mbps, profile):
        # Mbps → bytes/ms = BW·125 (the cost-model convention)
        return (wire_bytes_per_call * n_calls / (bandwidth_mbps * 125.0)
                + n_calls * profile.wire_rtt_ms)

    @staticmethod
    def _decode_ms(raw_bytes_total, decode_bw):
        if decode_bw <= 0 or raw_bytes_total <= 0:
            return 0.0
        return raw_bytes_total / decode_bw * 1e3


_REGISTRY: Dict[str, TransportLink] = {}


def register_link(cls: Type[TransportLink]) -> Type[TransportLink]:
    name = getattr(cls, "name", "")
    if not name:
        raise ValueError(f"{cls.__name__} must define a non-empty `name`")
    if name in _REGISTRY:
        raise ValueError(f"link {name!r} already registered")
    _REGISTRY[name] = cls()
    return cls


def get_link(name: str) -> TransportLink:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown transport link {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def list_links() -> List[str]:
    return sorted(_REGISTRY)


@register_link
class DirectLink(TransportLink):
    """Peer/collective path (NVLink, TPU ICI): wire + RTT, no host hop."""

    name = "direct"
    staged = False

    def cost(self, *, wire_bytes_per_call, n_calls, bandwidth_mbps, profile,
             raw_bytes_total=0.0, decode_bw=0.0) -> LinkCost:
        return LinkCost(
            staging_ms=0.0,
            wire_ms=self._wire_ms(wire_bytes_per_call, n_calls,
                                  bandwidth_mbps, profile),
            decode_ms=self._decode_ms(raw_bytes_total, decode_bw))


@register_link
class StagedLink(TransportLink):
    """CPU-memory path (GLOO): every wire byte is copied D2H then H2D
    through the profile's size-dependent pinned-copy curve (identical math
    to ``EdgeConstants.staging_ms`` — the two must not drift)."""

    name = "staged"
    staged = True

    def cost(self, *, wire_bytes_per_call, n_calls, bandwidth_mbps, profile,
             raw_bytes_total=0.0, decode_bw=0.0) -> LinkCost:
        staged_per_call = 2.0 * wire_bytes_per_call          # D2H + H2D
        bw = (profile.staging_bw_base + profile.staging_bw_extra
              * staged_per_call
              / (staged_per_call + profile.staging_knee_bytes))
        per_call = profile.staging_fixed_ms + staged_per_call / bw * 1e3
        return LinkCost(
            staging_ms=per_call * n_calls + profile.sync_overhead_ms,
            wire_ms=self._wire_ms(wire_bytes_per_call, n_calls,
                                  bandwidth_mbps, profile),
            decode_ms=self._decode_ms(raw_bytes_total, decode_bw))


# ---------------------------------------------------------------------------
# codec × link accounting — shared by profiling backends and telemetry
# ---------------------------------------------------------------------------

def exchange_wire_bytes(codec_name: str, *, n_tokens: int, d_model: int,
                        bytes_per_el: int, batch: int, P: int,
                        n_layers: int, L: int = 0, param: int = 0) -> int:
    """Total bytes one device puts on the wire for a full forward pass
    (one collective per layer), under the cost model's convention of a
    ``d_model``-wide per-token K/V payload."""
    if P <= 1:
        return 0
    codec = get_codec(codec_name)
    spec = CodecSpec(L=L, param=param)
    Np = n_tokens // P + (n_tokens % P > 0)
    shipped = (P - 1) * (L if codec.summarizing else Np)
    per_tok = codec.token_wire_bytes(d_model, bytes_per_el, spec)
    return int(shipped * per_tok * batch * n_layers)


def exchange_cost(codec_name: str, *, n_tokens: int, d_model: int,
                  bytes_per_el: int, batch: int, P: int, n_layers: int,
                  bandwidth_mbps: float, profile, link: str = "staged",
                  L: int = 0, param: int = 0) -> Dict[str, float]:
    """Full per-dispatch exchange accounting for one (codec, link) pair.

    Returns wire/staged byte totals, the per-stage latency decomposition
    (staging / wire / decode), and the achieved compression ratio relative
    to full-tensor exchange of the same remote tokens.
    """
    codec = get_codec(codec_name)
    lnk = get_link(link)
    spec = CodecSpec(L=L, param=param)
    Np = n_tokens // P + (n_tokens % P > 0)
    raw_remote = (P - 1) * Np * d_model * bytes_per_el * batch  # per call
    wire_total = exchange_wire_bytes(
        codec_name, n_tokens=n_tokens, d_model=d_model,
        bytes_per_el=bytes_per_el, batch=batch, P=P, n_layers=n_layers,
        L=L, param=param)
    wire_per_call = wire_total / max(n_layers, 1)
    # summarizing codecs are consumed directly (no per-token reconstruction)
    raw_total = 0.0 if codec.summarizing else raw_remote * n_layers
    cost = lnk.cost(wire_bytes_per_call=wire_per_call, n_calls=n_layers,
                    bandwidth_mbps=bandwidth_mbps, profile=profile,
                    raw_bytes_total=raw_total, decode_bw=codec.decode_bw)
    return {
        "wire_bytes": wire_total,
        "staged_bytes": (2.0 * wire_total) if lnk.staged else 0.0,
        "staging_ms": cost.staging_ms,
        "comm_ms": cost.wire_ms,
        "decode_ms": cost.decode_ms,
        "ratio": (raw_remote * n_layers) / max(wire_total, 1),
    }


def plan_wire_bytes(plan, cfg, batch: int,
                    n_tokens: Optional[int] = None) -> int:
    """Bytes-on-wire one dispatch of ``plan`` moves (0 for local plans) —
    the per-request telemetry `DispatchRecord`/`Completion` report."""
    if not plan.distributed or plan.seq_shards <= 1:
        return 0
    if not n_tokens or n_tokens <= 0:
        from repro.profiling.sweep import workload_from_config
        n_tokens = workload_from_config(cfg).n_tokens
    codec = plan.effective_codec or "identity"
    L = plan.L
    if get_codec(codec).summarizing and L <= 0 and plan.cr > 0:
        from repro.core.segment_means import cr_to_L
        L = cr_to_L(n_tokens, plan.seq_shards, plan.cr)
    return exchange_wire_bytes(
        codec, n_tokens=n_tokens, d_model=cfg.d_model,
        bytes_per_el=cfg.jdtype.itemsize, batch=batch, P=plan.seq_shards,
        n_layers=cfg.n_layers, L=L, param=plan.codec_param)
