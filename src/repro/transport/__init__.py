"""`repro.transport` — pluggable exchange codecs + staged-link transport.

The paper's bottleneck is CPU-staged communication that scales with *bytes
moved*; this package makes both byte-reducing axes first class:

* :class:`ExchangeCodec` registry (``identity`` / ``segment_means`` /
  ``int8`` / ``int4`` / ``topk``) — what the wire payload *is*: a
  jit-/shard_map-compatible encode/decode pair with exact wire-byte
  accounting (``@register_codec`` to add your own).
* :class:`TransportLink` registry (``staged`` CPU-memory path vs ``direct``
  collective) — *how* the bytes travel, with per-stage cost accounting fed
  by the profiled :class:`~repro.profiling.hardware.LinkProfile`.
* the chunked exchange executor (:func:`ring_prefill_attention`) — ring
  ``ppermute`` transfers split into chunks and double-buffered under
  attention compute, plus the generic codec exchange
  (:func:`codec_prefill_attention`) and its single-host oracle.

``ExecutionPlan(codec=..., codec_param=..., link=...)`` threads these
through the session/policy stack; :func:`exchange_cost` /
:func:`plan_wire_bytes` are the accounting entry points the profiler and
the serving telemetry share.
"""
from repro.transport.codecs import (CodecSpec, ExchangeCodec,
                                    calibrate_codec_bws, codec_overrides,
                                    get_codec, list_codecs,
                                    measure_decode_bw, payload_nbytes,
                                    register_codec)
from repro.transport.executor import (codec_prefill_attention,
                                      codec_sim_attention,
                                      codec_sim_prefill_attention,
                                      ring_prefill_attention)
from repro.transport.links import (LinkCost, TransportError, TransportLink,
                                   exchange_cost, exchange_wire_bytes,
                                   get_link, list_links, plan_wire_bytes,
                                   register_link)

__all__ = [
    "ExchangeCodec", "CodecSpec", "register_codec", "get_codec",
    "list_codecs", "payload_nbytes", "measure_decode_bw",
    "calibrate_codec_bws", "codec_overrides",
    "TransportLink", "TransportError", "LinkCost", "register_link",
    "get_link", "list_links",
    "exchange_cost", "exchange_wire_bytes", "plan_wire_bytes",
    "ring_prefill_attention", "codec_prefill_attention",
    "codec_sim_attention", "codec_sim_prefill_attention",
]
