"""Pluggable exchange codecs — what the bytes on the wire *are*.

The paper's bottleneck on Jetson-class devices is CPU-staged communication,
an overhead that scales with bytes moved; PRISM's Segment Means is one point
in a compression-ratio space (arXiv 2507.12145), and quantization-level
co-design is where edge wins come from (EdgeTran, arXiv 2303.13745).  This
module makes the compressor a first-class, registered axis: an
:class:`ExchangeCodec` is a jit-/shard_map-compatible encode/decode pair
with *exact* wire-byte accounting, so the profiler can sweep codecs and the
policy can select one per (batch, bandwidth) decision.

Built-ins:

* ``identity``      — full-tensor exchange (the Voltage baseline payload).
* ``segment_means`` — the paper's PRISM compressor (L column-wise means per
  partition, routed through the kernel-dispatch layer).  *Summarizing*: the
  decoded payload has L tokens, consumed by the scaling-aware softmax, not
  a per-token reconstruction.
* ``int8`` / ``int4`` — per-tile symmetric quantize–dequantize (one f32
  scale per tile along the feature axis; int4 packs two values per byte).
* ``topk``          — sparse: keep the k largest-|x| features per vector
  (values + indices on the wire).

Register your own with ``@register_codec``; after registration
``ExecutionPlan(mode="prism", codec="mycodec", ...)`` and the whole
session/policy surface work unchanged.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Dict, List, Type

import jax
import jax.numpy as jnp

# characters reserved by PerfKey ('|'), ExecutionPlan keys ('@', '+') and
# the sweep axis — a codec name must survive all three encodings
_RESERVED = set("|@+# \t\n")


@dataclasses.dataclass(frozen=True)
class CodecSpec:
    """Static per-plan codec parameters (safe to close over under jit).

    ``L``     — segment means per partition (``segment_means`` only).
    ``param`` — codec-specific knob: quantization tile size along the
                feature axis (0 = one scale per whole vector) for
                ``int8``/``int4``; k (features kept per vector) for
                ``topk``.
    """
    L: int = 0
    param: int = 0


class ExchangeCodec:
    """One way to put a K/V partition on the wire.

    ``encode``/``decode`` are pure jnp functions of arrays + a static
    :class:`CodecSpec` — traceable under ``jit`` and inside ``shard_map``
    manual regions.  ``wire_bytes`` is the exact payload size (must equal
    the summed ``nbytes`` of the encoded leaves); ``token_wire_bytes`` is
    the model-level cost the profiler charges per shipped token.
    """

    name: str = ""
    summarizing: bool = False     # decoded payload has L tokens, not N
    lossless: bool = False
    default_param: int = 0        # default spec.param for parameterized
                                  # codecs (profiling sweeps use it)
    # reconstruction throughput (raw bytes/s) charged by the profiler as
    # decode time on the receiving device; 0 = free.  The class attribute
    # is a documented-constant *model*; ``calibrate_codec_bws`` replaces it
    # with a measured value on the registry instance (shadowing the class
    # constant) and flips ``decode_bw_measured``.
    decode_bw: float = 0.0
    decode_bw_measured: bool = False

    # -- wire format ---------------------------------------------------------

    def encode(self, x: jnp.ndarray, spec: CodecSpec) -> Dict[str, jnp.ndarray]:
        raise NotImplementedError

    def decode(self, payload: Dict[str, jnp.ndarray], spec: CodecSpec,
               shape=None, dtype=None) -> jnp.ndarray:
        """Reconstruct (``shape``/``dtype`` of the original tensor; codecs
        that can derive them from the payload may ignore both)."""
        raise NotImplementedError

    # -- accounting ----------------------------------------------------------

    def wire_bytes(self, shape, dtype, spec: CodecSpec) -> int:
        """Exact bytes on the wire for one encoded tensor."""
        raise NotImplementedError

    def token_wire_bytes(self, feat: int, bytes_per_el: int,
                         spec: CodecSpec) -> float:
        """Model-level wire bytes per shipped token of a ``feat``-wide
        payload (the profiler's per-token charge)."""
        raise NotImplementedError

    def ratio(self, shape, dtype, spec: CodecSpec) -> float:
        """Compression ratio: raw bytes / wire bytes."""
        raw = math.prod(shape) * jnp.dtype(dtype).itemsize
        return raw / max(self.wire_bytes(shape, dtype, spec), 1)

    def validate_spec(self, spec: CodecSpec) -> None:
        """Raise on parameters this codec cannot execute with."""


_REGISTRY: Dict[str, ExchangeCodec] = {}


def register_codec(cls: Type[ExchangeCodec]) -> Type[ExchangeCodec]:
    """Class decorator: instantiate and register under ``cls.name``."""
    name = getattr(cls, "name", "")
    if not name:
        raise ValueError(f"{cls.__name__} must define a non-empty `name`")
    if _RESERVED & set(name):
        raise ValueError(f"codec name {name!r} contains a reserved "
                         f"character (one of {''.join(sorted(_RESERVED))!r})")
    if not name[0].isalpha():
        # "mode@cr+codec" parsing disambiguates exponent '+' from the
        # codec separator by this property
        raise ValueError(f"codec name {name!r} must start with a letter")
    if name in _REGISTRY:
        raise ValueError(f"codec {name!r} already registered "
                         f"(by {type(_REGISTRY[name]).__name__})")
    _REGISTRY[name] = cls()
    return cls


def get_codec(name: str) -> ExchangeCodec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown exchange codec {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def list_codecs() -> List[str]:
    return sorted(_REGISTRY)


def payload_nbytes(payload: Dict[str, jnp.ndarray]) -> int:
    """Summed device bytes of an encoded payload (accounting cross-check)."""
    return sum(int(v.size) * v.dtype.itemsize
               for v in jax.tree_util.tree_leaves(payload))


# ---------------------------------------------------------------------------
# identity — full-tensor exchange (the Voltage baseline payload)
# ---------------------------------------------------------------------------

@register_codec
class IdentityCodec(ExchangeCodec):
    name = "identity"
    lossless = True

    def encode(self, x, spec):
        return {"x": x}

    def decode(self, payload, spec, shape=None, dtype=None):
        return payload["x"]

    def wire_bytes(self, shape, dtype, spec):
        return math.prod(shape) * jnp.dtype(dtype).itemsize

    def token_wire_bytes(self, feat, bytes_per_el, spec):
        return feat * bytes_per_el


# ---------------------------------------------------------------------------
# segment_means — the paper's PRISM compressor (summarizing)
# ---------------------------------------------------------------------------

@register_codec
class SegmentMeansCodec(ExchangeCodec):
    """L column-wise means per partition (PRISM Eq. 1), via the
    kernel-dispatch layer (Pallas on TPU, jnp reference elsewhere).  The
    decoded payload *is* the means — consumers apply the scaling-aware
    softmax rather than reconstructing per-token K/V."""

    name = "segment_means"
    summarizing = True

    def encode(self, x, spec):
        from repro.kernels import dispatch as kdsp
        if spec.L <= 0:
            raise ValueError("segment_means codec needs spec.L > 0")
        return {"means": kdsp.segment_means(x, spec.L, axis=1)}

    def decode(self, payload, spec, shape=None, dtype=None):
        return payload["means"]

    def wire_bytes(self, shape, dtype, spec):
        n = shape[1]
        return (math.prod(shape) // n) * spec.L * jnp.dtype(dtype).itemsize

    def token_wire_bytes(self, feat, bytes_per_el, spec):
        # full precision per shipped *mean*; the token-count reduction
        # N_p → L is applied by the caller (shipped-token accounting)
        return feat * bytes_per_el

    def validate_spec(self, spec):
        if spec.L <= 0:
            raise ValueError("segment_means codec needs L > 0")


# ---------------------------------------------------------------------------
# int8 / int4 — per-tile symmetric quantization
# ---------------------------------------------------------------------------

def _tile(feat: int, spec: CodecSpec) -> int:
    t = spec.param if spec.param > 0 else feat
    if feat % t != 0:
        raise ValueError(f"feature width {feat} not divisible into "
                         f"quantization tiles of {t}")
    return t


class _QuantCodec(ExchangeCodec):
    """Shared symmetric per-tile quantizer: one f32 scale per tile along
    the trailing (feature) axis, values in [-qmax, qmax]."""

    qmax: int = 127

    def _scaled(self, x, spec):
        t = _tile(x.shape[-1], spec)
        xr = x.reshape(x.shape[:-1] + (x.shape[-1] // t, t)).astype(
            jnp.float32)
        scale = jnp.max(jnp.abs(xr), axis=-1, keepdims=True) / self.qmax
        q = jnp.round(xr / jnp.maximum(scale, 1e-12))
        q = jnp.clip(q, -self.qmax, self.qmax)
        return q, scale, xr.shape

    def wire_bytes(self, shape, dtype, spec):
        n_tiles = math.prod(shape) // _tile(shape[-1], spec)
        return self._payload_bytes(math.prod(shape)) + 4 * n_tiles

    def token_wire_bytes(self, feat, bytes_per_el, spec):
        t = spec.param if spec.param > 0 else feat
        return self._payload_bytes(feat) + 4.0 * -(-feat // t)

    def _payload_bytes(self, n_el: int) -> int:
        raise NotImplementedError


@register_codec
class Int8Codec(_QuantCodec):
    name = "int8"
    decode_bw = 8e8       # modeled dequantization throughput, raw bytes/s

    def encode(self, x, spec):
        q, scale, qshape = self._scaled(x, spec)
        return {"q": q.astype(jnp.int8).reshape(x.shape),
                "scale": scale.reshape(qshape[:-1])}

    def decode(self, payload, spec, shape=None, dtype=None):
        q, scale = payload["q"], payload["scale"]
        t = _tile(q.shape[-1], spec)
        xr = q.reshape(scale.shape + (t,)).astype(jnp.float32)
        out = (xr * scale[..., None]).reshape(q.shape)
        return out.astype(dtype if dtype is not None else jnp.float32)

    def _payload_bytes(self, n_el):
        return n_el


@register_codec
class Int4Codec(_QuantCodec):
    """4-bit symmetric quantization, two values packed per byte (the
    bit-unpacking makes reconstruction ~4x slower than int8 — the modeled
    ``decode_bw`` is what lets the policy trade wire savings against it)."""

    name = "int4"
    qmax = 7
    decode_bw = 2e8

    def encode(self, x, spec):
        if x.shape[-1] % 2 != 0:
            raise ValueError("int4 codec needs an even feature width "
                             f"(got {x.shape[-1]})")
        q, scale, qshape = self._scaled(x, spec)
        biased = (q + 8).astype(jnp.uint8).reshape(x.shape)  # 1..15
        packed = biased[..., 0::2] | (biased[..., 1::2] << 4)
        return {"q": packed, "scale": scale.reshape(qshape[:-1])}

    def decode(self, payload, spec, shape=None, dtype=None):
        packed, scale = payload["q"], payload["scale"]
        lo = (packed & 0xF).astype(jnp.int32)
        hi = (packed >> 4).astype(jnp.int32)
        q = jnp.stack([lo, hi], axis=-1).reshape(
            packed.shape[:-1] + (2 * packed.shape[-1],)) - 8
        t = _tile(q.shape[-1], spec)
        xr = q.reshape(scale.shape + (t,)).astype(jnp.float32)
        out = (xr * scale[..., None]).reshape(q.shape)
        return out.astype(dtype if dtype is not None else jnp.float32)

    def wire_bytes(self, shape, dtype, spec):
        n_tiles = math.prod(shape) // _tile(shape[-1], spec)
        return math.prod(shape) // 2 + 4 * n_tiles

    def _payload_bytes(self, n_el):
        return n_el / 2

    def validate_spec(self, spec):
        if spec.param % 2 != 0:
            raise ValueError("int4 tile size must be even "
                             f"(got {spec.param})")


# ---------------------------------------------------------------------------
# topk — sparse exchange (largest-|x| features per vector)
# ---------------------------------------------------------------------------

@register_codec
class TopKCodec(ExchangeCodec):
    """Keep the ``spec.param`` largest-magnitude features of each trailing
    vector; ship (values, int32 indices), reconstruct into zeros."""

    name = "topk"
    default_param = 8
    decode_bw = 5e8       # modeled scatter throughput, raw bytes/s

    def encode(self, x, spec):
        k = spec.param
        if not 0 < k <= x.shape[-1]:
            raise ValueError(f"topk codec needs 0 < k <= {x.shape[-1]} "
                             f"(got {k})")
        _, idx = jax.lax.top_k(jnp.abs(x.astype(jnp.float32)), k)
        vals = jnp.take_along_axis(x, idx, axis=-1)
        return {"vals": vals, "idx": idx.astype(jnp.int32)}

    def decode(self, payload, spec, shape=None, dtype=None):
        vals, idx = payload["vals"], payload["idx"]
        if shape is None:
            raise ValueError("topk decode needs the original `shape`")
        feat = shape[-1]
        onehot = jax.nn.one_hot(idx, feat, dtype=jnp.float32)
        out = jnp.einsum("...kf,...k->...f", onehot,
                         vals.astype(jnp.float32))
        return out.astype(dtype if dtype is not None else vals.dtype)

    def wire_bytes(self, shape, dtype, spec):
        lead = math.prod(shape) // shape[-1]
        return lead * spec.param * (jnp.dtype(dtype).itemsize + 4)

    def token_wire_bytes(self, feat, bytes_per_el, spec):
        return spec.param * (bytes_per_el + 4)

    def validate_spec(self, spec):
        if spec.param <= 0:
            raise ValueError("topk codec needs codec_param = k > 0")


# ---------------------------------------------------------------------------
# measured decode throughput — micro-benchmark replacing the documented
# constants (the hit-list item: decode_bw values were modeled, not measured)
# ---------------------------------------------------------------------------

def measure_decode_bw(codec: ExchangeCodec, *, shape=(4, 64, 256),
                      dtype=jnp.float32, spec: CodecSpec = None,
                      iters: int = 5, warmup: int = 2) -> float:
    """Measured reconstruction throughput of ``codec`` in raw bytes/s.

    Encodes one representative K/V-shaped tensor, jits the decode, and
    times it with device sync (:func:`~repro.utils.timing.timeit_jax`).
    Throughput is *raw* (reconstructed) bytes per second — the same unit
    as the modeled ``decode_bw`` constants, so
    :func:`~repro.transport.links.exchange_cost` consumes it unchanged.
    """
    from repro.utils.timing import timeit_jax
    if spec is None:
        spec = CodecSpec(param=codec.default_param)
    x = jax.random.normal(jax.random.key(0), shape, dtype)
    payload = jax.tree_util.tree_map(jax.block_until_ready,
                                     codec.encode(x, spec))

    def _decode(p):
        return codec.decode(p, spec, shape=shape, dtype=dtype)

    t = timeit_jax(jax.jit(_decode), payload, iters=iters, warmup=warmup)
    raw = math.prod(shape) * jnp.dtype(dtype).itemsize
    return raw / max(t, 1e-9)


def calibrate_codec_bws(names=None, *, force: bool = False,
                        shape=(4, 64, 256), iters: int = 5,
                        warmup: int = 2) -> Dict[str, float]:
    """Measure decode throughput for registered codecs and install the
    results on the registry instances (shadowing the class constants).

    ``exchange_cost`` reads ``get_codec(name).decode_bw`` live at sweep
    time, so calibrating *before* a profiling sweep feeds measured values
    straight into every policy table built afterwards.  By default only
    codecs that model a reconstruction cost (class ``decode_bw`` > 0 and
    not *summarizing* — segment means are consumed, never reconstructed)
    are measured; pass ``names`` to choose explicitly.  Results are cached
    on the instance (``decode_bw_measured``); ``force=True`` re-measures.
    Returns ``{codec_name: measured_bytes_per_s}``.
    """
    if names is None:
        names = [n for n in list_codecs()
                 if type(get_codec(n)).decode_bw > 0
                 and not get_codec(n).summarizing]
    out: Dict[str, float] = {}
    for name in names:
        codec = get_codec(name)
        if codec.summarizing:
            continue           # decoded payload is consumed, not rebuilt
        if codec.decode_bw_measured and not force:
            out[name] = codec.decode_bw
            continue
        bw = measure_decode_bw(codec, shape=shape, iters=iters,
                               warmup=warmup)
        codec.decode_bw = bw
        codec.decode_bw_measured = True
        out[name] = bw
    return out


@contextlib.contextmanager
def codec_overrides(decode_bws: Dict[str, float]):
    """Temporarily install per-codec ``decode_bw`` values on the registry
    instances (shadowing whatever is installed now) for the duration of
    the block, then restore the previous state exactly.

    This is how *per-device* calibration feeds a profiling sweep: the
    registry scales the host-measured throughputs to one worker's
    :class:`~repro.profiling.hardware.HardwareProfile` and runs that
    worker's sweep inside the override, so each worker's policy table
    prices reconstruction at *its* device speed — without leaking the
    scaled values into any other worker's sweep.
    """
    saved = {}
    for name, bw in decode_bws.items():
        codec = get_codec(name)
        saved[name] = (codec.__dict__.get("decode_bw"),
                       codec.__dict__.get("decode_bw_measured"))
        codec.decode_bw = float(bw)
        codec.decode_bw_measured = True
    try:
        yield
    finally:
        for name, (bw, measured) in saved.items():
            codec = get_codec(name)
            if bw is None:
                codec.__dict__.pop("decode_bw", None)
            else:
                codec.decode_bw = bw
            if measured is None:
                codec.__dict__.pop("decode_bw_measured", None)
            else:
                codec.decode_bw_measured = measured
