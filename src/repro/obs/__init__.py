"""repro.obs — end-to-end observability for the serving stack.

* :mod:`repro.obs.trace` — span tracing with deterministic virtual-clock
  support and cross-process (RPC) trace propagation.
* :mod:`repro.obs.metrics` — typed counter/gauge/histogram registry
  behind every tier's ``stats``/``stats_snapshot()``.
* :mod:`repro.obs.export` — JSONL span files, Prometheus text dumps,
  and the Table-2-style stage-breakdown line.

See ``docs/api.md`` → "Observability" for the span taxonomy, the
metric naming scheme and usage examples.
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      PROVENANCES, StatsDict)
from .trace import (STAGES, Span, Tracer, breakdown, build_tree,
                    maybe_span, request_breakdown, request_trace_id,
                    span_from_dict, span_to_dict, tree_lines)
from .export import (format_breakdown, prometheus_text, read_spans_jsonl,
                     write_spans_jsonl)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "PROVENANCES",
    "StatsDict", "STAGES", "Span", "Tracer", "breakdown", "build_tree",
    "maybe_span", "request_breakdown", "request_trace_id", "span_from_dict",
    "span_to_dict", "tree_lines", "format_breakdown", "prometheus_text",
    "read_spans_jsonl", "write_spans_jsonl",
]
