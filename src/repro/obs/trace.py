"""Structured span tracing across the whole stack.

One request touches five tiers — session, serving runtime, paged pool,
fleet router, RPC subprocess worker — and the paper's central finding
(CPU–GPU *staging* during communication, not raw wire bandwidth,
dominates Jetson-class latency; arXiv 2605.25682 Table 2) was only
discoverable because wall time could be attributed to *stages*.  This
module provides that attribution: a :class:`Tracer` emits
:class:`Span` records with ``trace_id``/``span_id``/``parent_id``
forming one tree per request, tagged with a stage from the fixed
taxonomy (:data:`STAGES`).

Two properties matter more than OpenTelemetry parity:

* **Deterministic on the virtual clock.**  Span ids are per-tracer
  counters (``"<tracer-name>:<n>"``), never random, and every
  ``start``/``record`` call accepts explicit timestamps so virtual-time
  drivers (``FleetRouter.drive_virtual``, ``SimWorker``) stamp spans
  with simulated time.  Same chaos seed → byte-identical span tree, so
  CI can assert on trace *structure* (see
  ``tests/test_obs.py::test_chaos_trace_deterministic``).
* **Cheap when disabled.**  Every instrumentation site guards on
  ``tracer is None``; attaching a tracer is opt-in
  (``--trace``/``--metrics`` on the launchers, or
  ``runtime.tracer = Tracer()``).

Spans from a subprocess worker are serialized with :func:`span_to_dict`,
shipped back on ``CompletionMsg``/``TokenChunk`` header fields, and
re-attached to the client tracer with :meth:`Tracer.ingest` — the
worker's root ``request`` span carries the client's dispatch span id as
``parent_id`` (propagated via ``SubmitRequest.trace_id`` /
``.parent_span``), so the merged tree is a single request tree that
crosses the process boundary.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence

#: The stage taxonomy (fixed; new stages need a doc + breakdown review).
#: Maps onto the paper's Table-2 decomposition: ``staging`` + ``wire`` +
#: ``codec_decode`` are the communication stages of a staged link,
#: ``prefill``/``decode``/``decode_chunk`` are compute, the rest are
#: serving/fleet control plane.
STAGES = (
    "queue_wait",     # arrival -> admission into a slot/page pool
    "prefill",        # prompt pass priming a slot (prime_slot)
    "admit",          # KV install + slot bookkeeping (admit_slot)
    "decode",         # admission -> completion residency of one request
    "decode_chunk",   # one continuous-batching chunk (all active rows)
    "codec_encode",   # exchange-codec encode (client->wire)
    "codec_decode",   # exchange-codec decode (wire->device)
    "staging",        # host<->device copy of a staged link (modeled)
    "wire",           # bytes on the link (RPC frame I/O, or modeled)
    "retry",          # re-submit / re-route of an owned request
    "failover",       # drain + re-route after a dead worker
)

#: Control-plane span names that are not stages but appear as tree nodes.
SPAN_KINDS = ("session", "serving", "fleet", "rpc", "transport")


def request_trace_id(req_id) -> str:
    """Canonical trace id for a serving request — stable across process
    boundaries and across kill -> retry -> re-serve (the request id is
    the exactly-once key, so it is the trace key too)."""
    return f"req:{req_id}"


@dataclasses.dataclass
class Span:
    """One timed node of a request tree.

    ``start``/``end`` are seconds on the owning tracer's clock (wall
    monotonic or virtual sim-time); ``end`` is NaN while open.  ``attrs``
    holds small JSON-safe scalars only — spans cross the RPC wire.
    """
    trace_id: str
    span_id: str
    parent_id: Optional[str]
    name: str                     # a STAGES entry or a control-plane name
    kind: str                     # SPAN_KINDS entry
    worker: str = ""
    start: float = 0.0
    end: float = float("nan")
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def duration_ms(self) -> float:
        return 1e3 * (self.end - self.start)

    @property
    def open(self) -> bool:
        return self.end != self.end      # NaN check without math import


def span_to_dict(sp: Span) -> Dict[str, object]:
    """JSON-safe encoding (wire format + JSONL exporter row)."""
    return {
        "trace_id": sp.trace_id, "span_id": sp.span_id,
        "parent_id": sp.parent_id, "name": sp.name, "kind": sp.kind,
        "worker": sp.worker, "start": sp.start, "end": sp.end,
        "attrs": dict(sp.attrs),
    }


def span_from_dict(doc: Dict[str, object]) -> Span:
    return Span(trace_id=str(doc["trace_id"]), span_id=str(doc["span_id"]),
                parent_id=doc.get("parent_id"), name=str(doc["name"]),
                kind=str(doc.get("kind", "")),
                worker=str(doc.get("worker", "")),
                start=float(doc.get("start", 0.0)),
                end=float(doc.get("end", float("nan"))),
                attrs=dict(doc.get("attrs", {})))


class _ActiveCtx:
    """``with tracer.active(span):`` — pushes a parent for nested spans."""

    def __init__(self, tracer: "Tracer", span: Optional[Span]):
        self._tracer, self._span = tracer, span

    def __enter__(self):
        self._tracer._stack.append(self._span)
        return self._span

    def __exit__(self, *exc):
        self._tracer._stack.pop()
        return False


class _SpanCtx:
    """``with tracer.span(...) as sp:`` — starts, parents, finishes."""

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer, self.span = tracer, span

    def __enter__(self):
        self._tracer._stack.append(self.span)
        return self.span

    def __exit__(self, exc_type, exc, tb):
        self._tracer._stack.pop()
        if exc_type is not None:
            self.span.attrs.setdefault("error", exc_type.__name__)
        self._tracer.finish(self.span)
        return False


class Tracer:
    """Span factory + buffer for one process (or one virtual fleet).

    ``name`` namespaces span ids (``"<name>:<counter>"``) so spans from
    different processes never collide when merged client-side.  ``clock``
    is any ``() -> float`` — ``time.monotonic`` by default; virtual-time
    drivers either inject their clock or pass explicit ``at=``/``start=``
    /``end=`` stamps, which always win over the clock.
    """

    def __init__(self, name: str = "main",
                 clock: Optional[Callable[[], float]] = None):
        self.name = name
        self.clock = clock or time.monotonic
        self.spans: List[Span] = []
        self._ids = itertools.count(1)
        self._stack: List[Optional[Span]] = []
        self._seen: set = set()          # (trace_id, span_id) of ingested

    # ---- creation ----------------------------------------------------
    def _next_id(self) -> str:
        return f"{self.name}:{next(self._ids)}"

    def current(self) -> Optional[Span]:
        """Innermost active span (or None)."""
        for sp in reversed(self._stack):
            if sp is not None:
                return sp
        return None

    def start(self, name: str, *, kind: str = "serving",
              trace_id: Optional[str] = None,
              parent_id: Optional[str] = None, worker: str = "",
              at: Optional[float] = None, **attrs) -> Span:
        """Open a span.  Parent defaults to the active span's id; trace
        defaults to the active span's trace (or a fresh one-off trace)."""
        cur = self.current()
        if parent_id is None and cur is not None:
            parent_id = cur.span_id
        if trace_id is None:
            trace_id = cur.trace_id if cur is not None else self._next_id()
        sp = Span(trace_id=trace_id, span_id=self._next_id(),
                  parent_id=parent_id, name=name, kind=kind, worker=worker,
                  start=self.clock() if at is None else at, attrs=attrs)
        self.spans.append(sp)
        return sp

    def finish(self, span: Span, *, at: Optional[float] = None) -> Span:
        span.end = self.clock() if at is None else at
        return span

    def record(self, name: str, *, start: float, end: float,
               kind: str = "serving", trace_id: Optional[str] = None,
               parent_id: Optional[str] = None, worker: str = "",
               **attrs) -> Span:
        """One-shot closed span with explicit timestamps (virtual-clock
        drivers and post-hoc attribution)."""
        sp = self.start(name, kind=kind, trace_id=trace_id,
                        parent_id=parent_id, worker=worker, at=start,
                        **attrs)
        sp.end = end
        return sp

    def span(self, name: str, **kw) -> _SpanCtx:
        """Context manager: start on enter, finish on exit, and act as
        the parent of spans opened inside the block."""
        return _SpanCtx(self, self.start(name, **kw))

    def active(self, span: Optional[Span]) -> _ActiveCtx:
        """Make ``span`` the parent for spans opened inside the block
        without owning its lifetime (it stays open on exit)."""
        return _ActiveCtx(self, span)

    # ---- cross-process merge -----------------------------------------
    def ingest(self, docs: Iterable[Dict[str, object]]) -> int:
        """Attach foreign spans (a subprocess worker's, shipped back on
        ``CompletionMsg``/``TokenChunk``).  Foreign span ids carry their
        own tracer namespace so they cannot collide; duplicates (a chunk
        re-shipped after a retry) are dropped by ``(trace, span)`` id."""
        n = 0
        for doc in docs:
            key = (doc.get("trace_id"), doc.get("span_id"))
            if key in self._seen:
                continue
            self._seen.add(key)
            self.spans.append(span_from_dict(doc))
            n += 1
        return n

    # ---- queries ------------------------------------------------------
    def trace(self, trace_id: str) -> List[Span]:
        return [s for s in self.spans if s.trace_id == trace_id]

    def trace_ids(self) -> List[str]:
        out, seen = [], set()
        for s in self.spans:
            if s.trace_id not in seen:
                seen.add(s.trace_id)
                out.append(s.trace_id)
        return out


def maybe_span(tracer: Optional[Tracer], name: str, **kw):
    """``with maybe_span(self.tracer, "prefill", ...):`` — the guard every
    instrumentation site uses so tracing-off costs one None check."""
    if tracer is None:
        return contextlib.nullcontext()
    return tracer.span(name, **kw)


# ---- tree + breakdown -----------------------------------------------


def build_tree(spans: Sequence[Span]) -> Dict[Optional[str], List[Span]]:
    """children-by-parent-id index, children in start order (ties broken
    by span id so virtual-clock trees are stable)."""
    tree: Dict[Optional[str], List[Span]] = {}
    ids = {s.span_id for s in spans}
    for s in spans:
        # a parent outside this span set (e.g. filtering one trace out of
        # a shared tracer) makes the span a root of the local view
        parent = s.parent_id if s.parent_id in ids else None
        tree.setdefault(parent, []).append(s)
    for kids in tree.values():
        kids.sort(key=lambda s: (s.start, s.span_id))
    return tree


def tree_lines(spans: Sequence[Span]) -> List[str]:
    """Canonical ASCII rendering of a span forest — the determinism
    artifact two seeded chaos runs are compared on, byte for byte."""
    tree = build_tree(spans)
    out: List[str] = []

    def walk(parent: Optional[str], depth: int):
        for sp in tree.get(parent, []):
            dur = ("open" if sp.open else f"{sp.duration_ms:.3f}ms")
            attrs = "".join(f" {k}={sp.attrs[k]}" for k in sorted(sp.attrs))
            out.append(f"{'  ' * depth}{sp.name} [{sp.kind}"
                       f"{'/' + sp.worker if sp.worker else ''}] "
                       f"{dur}{attrs}")
            walk(sp.span_id, depth + 1)

    walk(None, 0)
    return out


def breakdown(spans: Sequence[Span],
              stages: Sequence[str] = STAGES) -> Dict[str, float]:
    """Table-2-style stage decomposition: total milliseconds per stage
    over the *leaf* spans of the given set (non-leaf spans like a
    request's ``decode`` residency contain their children's time and
    would double-count).  Returns ``{stage: total_ms}`` for stages that
    appear, in taxonomy order.
    """
    has_child = {s.parent_id for s in spans if s.parent_id is not None}
    totals: Dict[str, float] = {}
    for s in spans:
        if s.open or s.span_id in has_child or s.name not in stages:
            continue
        totals[s.name] = totals.get(s.name, 0.0) + s.duration_ms
    return {st: totals[st] for st in stages if st in totals}


def request_breakdown(spans: Sequence[Span], trace_id: str
                      ) -> Dict[str, float]:
    """Per-request stage decomposition for one trace.  The leaf stages
    of a request tree partition its wall time (queue_wait + prefill +
    admit + decode ≈ finished − arrival), so ``sum(values)`` reconciles
    with the request's measured latency — the BENCH_trace gate asserts
    this within 10%."""
    return breakdown([s for s in spans if s.trace_id == trace_id])
