"""Typed metrics registry unifying the stack's telemetry counters.

Before this module each tier kept its own mutable ``self.stats`` dict
with ad-hoc keys and four divergent ``stats_snapshot()`` shapes
(``ServingRuntime``, ``FleetRouter``, ``SimWorker``, ``RpcWorker``).
Now every scalar lives in a :class:`MetricsRegistry` under one naming
scheme, and the old dicts survive as :class:`StatsDict` — a
``MutableMapping`` whose scalar entries are registry-backed, so code
like ``self.stats["retries"] += 1`` and every existing
``stats_snapshot()`` consumer keep working unchanged.

Naming scheme (documented in ``docs/api.md`` → Observability):

    <tier>.<metric>               e.g. serving.steps, fleet.router.routed
    <tier>.<metric>{label=value}  e.g. rpc.client.frames_in{worker="w0"}

* tiers: ``serving``, ``fleet.router``, ``fleet.worker``,
  ``rpc.client``, ``rpc.server``, ``session``, ``link``, ``codec``
* counters are monotonic event counts; gauges are last-value
  observations and may carry a ``provenance`` label
  (``modeled|estimated|measured``) — the bandwidth-unit fix routes both
  :meth:`~repro.utils.bandwidth.BandwidthEstimator.observe_transfer`
  (link Mbps) and codec calibration (decode bytes/s) through
  provenance-labelled gauges instead of per-file boolean flags;
* histograms keep a bounded, deterministic value buffer and expose
  streaming ``p50``/``p99``.

The Prometheus-style text dump lives in :mod:`repro.obs.export`.
"""
from __future__ import annotations

import bisect
from typing import Dict, Iterator, List, MutableMapping, Optional, Tuple

#: Allowed values of the ``provenance`` label on bandwidth-ish gauges.
PROVENANCES = ("modeled", "estimated", "measured")

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Optional[Dict[str, str]]) -> LabelKey:
    return tuple(sorted((str(k), str(v))
                        for k, v in (labels or {}).items()))


def format_name(name: str, labels: LabelKey) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class Metric:
    """Base: a named, labelled scalar (or distribution)."""

    typ = "untyped"

    def __init__(self, name: str, labels: LabelKey, help: str = ""):
        self.name, self.labels, self.help = name, labels, help

    @property
    def full_name(self) -> str:
        return format_name(self.name, self.labels)


class Counter(Metric):
    """Monotonic event count.  ``set`` exists only so :class:`StatsDict`
    can initialise/reset compatibility entries; instrumentation should
    use ``inc``."""

    typ = "counter"

    def __init__(self, name, labels, help=""):
        super().__init__(name, labels, help)
        self.value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def set(self, v: float) -> None:
        self.value = float(v)


class Gauge(Metric):
    """Last-value observation (queue depth, bandwidth, occupancy)."""

    typ = "gauge"

    def __init__(self, name, labels, help=""):
        super().__init__(name, labels, help)
        self.value: float = 0.0
        self.observations: int = 0

    def set(self, v: float) -> None:
        self.value = float(v)
        self.observations += 1


class Histogram(Metric):
    """Value distribution with streaming quantiles.

    Keeps a sorted buffer capped at ``max_samples``; past the cap, every
    second retained sample is dropped (deterministic decimation — no
    RNG, so virtual-clock runs stay reproducible) while ``count``/
    ``sum`` keep exact totals.  Quantiles interpolate over the buffer.
    """

    typ = "histogram"

    def __init__(self, name, labels, help="", max_samples: int = 4096):
        super().__init__(name, labels, help)
        self.count = 0
        self.sum = 0.0
        self.max_samples = max_samples
        self._vals: List[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        bisect.insort(self._vals, v)
        if len(self._vals) > self.max_samples:
            del self._vals[::2]

    def percentile(self, p: float) -> float:
        """p in [0, 100]; 0.0 when empty."""
        if not self._vals:
            return 0.0
        if len(self._vals) == 1:
            return self._vals[0]
        rank = (p / 100.0) * (len(self._vals) - 1)
        lo = int(rank)
        hi = min(lo + 1, len(self._vals) - 1)
        frac = rank - lo
        return self._vals[lo] * (1 - frac) + self._vals[hi] * frac

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """Flat registry of typed metrics keyed by ``(name, labels)``.

    ``counter``/``gauge``/``histogram`` get-or-create (type mismatch on
    an existing name is an error — one name, one type).  ``snapshot()``
    returns ``{formatted_name: value}`` for counters/gauges plus
    ``.../count|sum|p50|p99`` entries per histogram.
    """

    def __init__(self):
        self._metrics: Dict[Tuple[str, LabelKey], Metric] = {}

    def _get(self, cls, name: str, labels=None, help: str = "", **kw):
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, key[1], help=help, **kw)
            self._metrics[key] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.typ}, requested {cls.typ}")
        return m

    def counter(self, name: str, labels: Optional[Dict[str, str]] = None,
                help: str = "") -> Counter:
        return self._get(Counter, name, labels, help)

    def gauge(self, name: str, labels: Optional[Dict[str, str]] = None,
              help: str = "") -> Gauge:
        return self._get(Gauge, name, labels, help)

    def histogram(self, name: str, labels: Optional[Dict[str, str]] = None,
                  help: str = "", max_samples: int = 4096) -> Histogram:
        return self._get(Histogram, name, labels, help,
                         max_samples=max_samples)

    def observe_bandwidth(self, name: str, value: float, provenance: str,
                          **labels: str) -> Gauge:
        """The one gauge both link- and codec-bandwidth call sites route
        through: value + explicit provenance label, no boolean flags.
        Units live in the metric name (``..._mbps``, ``..._bytes_per_s``).
        """
        if provenance not in PROVENANCES:
            raise ValueError(f"provenance must be one of {PROVENANCES}, "
                             f"got {provenance!r}")
        g = self.gauge(name, {**labels, "provenance": provenance})
        g.set(value)
        return g

    def metrics(self) -> List[Metric]:
        return [self._metrics[k] for k in sorted(self._metrics)]

    def find(self, name: str) -> List[Metric]:
        return [m for m in self.metrics() if m.name == name]

    def snapshot(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for m in self.metrics():
            if isinstance(m, Histogram):
                out[m.full_name + "/count"] = m.count
                out[m.full_name + "/sum"] = m.sum
                out[m.full_name + "/p50"] = m.p50
                out[m.full_name + "/p99"] = m.p99
            else:
                out[m.full_name] = m.value
        return out


class StatsDict(MutableMapping):
    """Dict-compatible stats whose scalar entries live in a registry.

    The compatibility shim for the four legacy ``stats`` dicts: reads,
    writes, ``+=``, ``dict(...)`` copies and iteration behave exactly
    like the plain dict they replace, but every scalar entry is backed
    by a registry :class:`Counter` named ``<prefix>.<key>`` (with the
    component's labels, e.g. ``worker="edge-a"``), so one Prometheus
    dump sees every tier under the unified scheme.  Non-scalar entries
    (e.g. the router's ``rejections`` reason-dict) stay plain objects.
    """

    def __init__(self, registry: MetricsRegistry, prefix: str,
                 initial: Optional[Dict[str, object]] = None,
                 labels: Optional[Dict[str, str]] = None):
        self.registry = registry
        self.prefix = prefix
        self.labels = dict(labels or {})
        self._order: List[str] = []
        self._plain: Dict[str, object] = {}
        for k, v in (initial or {}).items():
            self[k] = v

    def _metric(self, key: str) -> Counter:
        return self.registry.counter(f"{self.prefix}.{key}", self.labels)

    def __getitem__(self, key: str):
        if key not in self._order:
            raise KeyError(key)
        if key in self._plain:
            return self._plain[key]
        v = self._metric(key).value
        return int(v) if v == int(v) else v

    def __setitem__(self, key: str, value) -> None:
        if key not in self._order:
            self._order.append(key)
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            self._plain[key] = value
            return
        self._plain.pop(key, None)
        self._metric(key).set(value)

    def __delitem__(self, key: str) -> None:
        if key not in self._order:
            raise KeyError(key)
        self._order.remove(key)
        self._plain.pop(key, None)

    def __iter__(self) -> Iterator[str]:
        return iter(list(self._order))

    def __len__(self) -> int:
        return len(self._order)

    def __repr__(self) -> str:
        return f"StatsDict({dict(self)!r})"
