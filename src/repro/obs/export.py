"""Exporters: JSONL span files, Prometheus-style text, breakdown lines.

Three consumers, three formats:

* ``--trace out.jsonl`` on the launchers → :func:`write_spans_jsonl`
  (one :func:`~repro.obs.trace.span_to_dict` row per line; reload with
  :func:`read_spans_jsonl` for offline analysis or
  ``calibrate(records=from_trace(...))``).
* ``--metrics`` → :func:`prometheus_text` — a Prometheus exposition
  dump of every registry metric (dots become underscores; histograms
  expand to ``_count``/``_sum``/``_p50``/``_p99`` samples).
* the per-stage breakdown line both launchers print at exit →
  :func:`format_breakdown`, the Table-2-style stage decomposition from
  :func:`repro.obs.trace.breakdown`.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence

from .metrics import Histogram, MetricsRegistry
from .trace import Span, breakdown, span_from_dict, span_to_dict


def write_spans_jsonl(spans: Iterable[Span], path: str) -> int:
    """One JSON object per line; returns the number of rows written.
    Sorted by (trace, start, span id) so the file is diffable across
    deterministic runs."""
    rows = sorted(spans, key=lambda s: (s.trace_id, s.start, s.span_id))
    with open(path, "w") as fh:
        for sp in rows:
            fh.write(json.dumps(span_to_dict(sp), sort_keys=True) + "\n")
    return len(rows)


def read_spans_jsonl(path: str) -> List[Span]:
    out: List[Span] = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(span_from_dict(json.loads(line)))
    return out


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def _prom_labels(labels) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{_prom_name(k)}="{v}"'
                          for k, v in labels) + "}"


def prometheus_text(*registries: MetricsRegistry) -> str:
    """Prometheus exposition format (text/plain; version 0.0.4-ish).
    Accepts several registries (client + per-worker) and merges them
    into one dump; duplicate full names keep the last value seen."""
    by_name: Dict[str, List] = {}
    types: Dict[str, str] = {}
    helps: Dict[str, str] = {}
    for reg in registries:
        for m in reg.metrics():
            n = _prom_name(m.name)
            by_name.setdefault(n, []).append(m)
            types[n] = "gauge" if m.typ == "gauge" else "counter" \
                if m.typ == "counter" else "histogram"
            if m.help:
                helps[n] = m.help
    lines: List[str] = []
    for n in sorted(by_name):
        if n in helps:
            lines.append(f"# HELP {n} {helps[n]}")
        lines.append(f"# TYPE {n} {types[n]}")
        for m in by_name[n]:
            lab = _prom_labels(m.labels)
            if isinstance(m, Histogram):
                lines.append(f"{n}_count{lab} {m.count}")
                lines.append(f"{n}_sum{lab} {m.sum:g}")
                lines.append(f"{n}_p50{lab} {m.p50:g}")
                lines.append(f"{n}_p99{lab} {m.p99:g}")
            else:
                lines.append(f"{n}{lab} {m.value:g}")
    return "\n".join(lines) + "\n"


def format_breakdown(spans: Sequence[Span],
                     wall_ms: Optional[float] = None) -> str:
    """The one-line stage decomposition both launchers print at exit:

        stages: queue_wait 1.2ms | prefill 40.3ms | ... (Σ 97% of wall)

    ``wall_ms`` (total measured request wall time, summed over
    requests) adds the reconciliation percentage the BENCH_trace gate
    asserts on."""
    bd = breakdown(spans)
    if not bd:
        return "stages: (no closed spans)"
    parts = [f"{k} {v:.1f}ms" for k, v in bd.items()]
    line = "stages: " + " | ".join(parts)
    total = sum(bd.values())
    if wall_ms:
        line += f"  (Σ {total:.1f}ms = {100.0 * total / wall_ms:.0f}% of " \
                f"{wall_ms:.1f}ms wall)"
    else:
        line += f"  (Σ {total:.1f}ms)"
    return line
