"""Elastic re-meshing: survive losing a pod (or shrinking the fleet).

PRISM's sequence-partition count P is a *runtime* parameter (the paper's
adaptive policy already varies execution shape per request), which makes the
whole system naturally elastic: on failure we rebuild the mesh from the
surviving devices, re-derive the sharding plan (P follows the model-axis
size), and re-shard the checkpointed state onto it — checkpoints store
global arrays, so restore-with-new-shardings is just ``jax.device_put`` with
the new specs (checkpoint/manager.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.exchange import ExchangeMode
from repro.sharding.specs import ShardingPlan, make_plan


@dataclasses.dataclass
class ElasticMeshManager:
    """Tracks the healthy device set and rebuilds mesh + plan on change."""
    cfg: ModelConfig
    mode: ExchangeMode
    L: int = 0
    devices: Optional[list] = None

    def __post_init__(self):
        self.devices = list(self.devices or jax.devices())

    def build(self, axis_shape: Tuple[int, ...], axis_names: Tuple[str, ...]):
        n = int(np.prod(axis_shape))
        devs = np.asarray(self.devices[:n]).reshape(axis_shape)
        mesh = jax.sharding.Mesh(devs, axis_names)
        return mesh, make_plan(mesh, self.cfg, self.mode, L=self.L)

    def drop(self, failed, rebuild: bool = True):
        """Remove failed devices and return the largest viable mesh.

        ``failed`` is either an iterable of failed devices (device objects
        — matched by identity/equality — or their ``.id``s) or — the
        legacy overload — an int count, which truncates the tail of the
        device list.  Passing explicit ids matters: the tail-truncation
        heuristic used to evict *healthy* devices whenever the failed one
        was not last.  ``rebuild=False`` skips mesh construction (callers
        that only track membership, e.g. tests without a real fleet).
        """
        if isinstance(failed, (int, np.integer)):
            if failed < 0 or failed > len(self.devices):
                raise ValueError(f"cannot drop {failed} of "
                                 f"{len(self.devices)} devices")
            self.devices = self.devices[:len(self.devices) - failed]
        else:
            failed = list(failed)
            dead_idx = set()
            for f in failed:
                hit = [i for i, d in enumerate(self.devices)
                       if d is f or d == f or getattr(d, "id", None) == f]
                if not hit:
                    raise ValueError(f"failed device {f!r} is not in the "
                                     "healthy device set")
                dead_idx.update(hit)
            self.devices = [d for i, d in enumerate(self.devices)
                            if i not in dead_idx]
        if not rebuild:
            return None
        return self.best_mesh()

    def best_mesh(self):
        n = len(self.devices)
        shape = largest_mesh_shape(n)
        names = ("data", "model") if len(shape) == 2 else ("pod", "data",
                                                           "model")
        return self.build(shape, names)


def largest_mesh_shape(n_devices: int) -> Tuple[int, ...]:
    """Largest (data, model) grid with model a power of two ≤ 16 that fits
    in ``n_devices`` — PRISM's P re-balances to the new model-axis size."""
    best = (1, 1)
    for model in (16, 8, 4, 2, 1):
        data = n_devices // model
        if data >= 1 and data * model > best[0] * best[1]:
            best = (data, model)
    return best


def replan_for_failure(cfg: ModelConfig, mode: ExchangeMode,
                       surviving: int, L: int = 0):
    """One-shot helper: mesh + plan for the surviving device count."""
    mgr = ElasticMeshManager(cfg, mode, L=L,
                             devices=jax.devices()[:surviving])
    return mgr.best_mesh()
