"""Straggler mitigation for the synchronous exchange.

The paper's profiling decomposition makes stragglers visible: per-device
step times are profiled; devices persistently slower than the fleet median
by ``threshold`` get their sequence partition shrunk (PRISM's partitions
need not be equal — the master re-balances the position-wise split), which
is the edge-appropriate analogue of backup workers. The rebalancer outputs
integer token counts per device summing to N, biased inversely to measured
speed, quantized to the segment size so L stays integral.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class StragglerMitigator:
    n_devices: int
    ema_alpha: float = 0.25
    threshold: float = 1.3         # flag if step_time > 1.3 × median
    history_len: int = 50

    def __post_init__(self):
        self._ema = np.ones(self.n_devices)
        self._seen = 0

    def observe(self, step_times: np.ndarray) -> None:
        """step_times: [n_devices] wall seconds for the last step."""
        t = np.asarray(step_times, float)
        if self._seen == 0:
            self._ema = t
        else:
            self._ema = self.ema_alpha * t + (1 - self.ema_alpha) * self._ema
        self._seen += 1

    def stragglers(self) -> List[int]:
        med = float(np.median(self._ema))
        return [i for i, t in enumerate(self._ema)
                if t > self.threshold * med]

    def rebalanced_partitions(self, n_tokens: int, seg_size: int
                              ) -> List[int]:
        """Token counts per device ∝ measured speed, quantized to segments.

        Every device keeps at least one segment and the counts always sum to
        ``(n_tokens // seg_size) · seg_size`` (= ``n_tokens`` when it is
        segment-aligned): rounding drift is repaired by largest-remainder
        allocation instead of dumping a possibly-negative correction on the
        fastest device (which under extreme skew used to drive its partition
        to zero or below).
        """
        total = n_tokens // seg_size
        if total < self.n_devices:
            raise ValueError(
                f"{n_tokens} tokens / seg_size {seg_size} yield {total} "
                f"segments — fewer than {self.n_devices} devices (every "
                "device needs at least one segment)")
        speed = 1.0 / np.maximum(self._ema, 1e-9)
        share = speed / speed.sum() * total
        segs = np.maximum(np.floor(share).astype(int), 1)
        frac = share - np.floor(share)
        # grant leftover segments by largest fractional remainder
        # (fastest-first on ties); reclaim overdraft from the devices with
        # the most segments (slowest-first on ties), never below one
        while segs.sum() < total:
            i = int(np.lexsort((-speed, -frac))[0])
            segs[i] += 1
            frac[i] = -1.0
        while segs.sum() > total:
            donors = np.where(segs > 1)[0]
            i = donors[int(np.lexsort((speed[donors], -segs[donors]))[0])]
            segs[i] -= 1
        return list(segs * seg_size)
