from repro.runtime.fault import FaultTolerantLoop, HeartbeatMonitor, FaultEvent
from repro.runtime.elastic import ElasticMeshManager, replan_for_failure
from repro.runtime.straggler import StragglerMitigator

__all__ = ["FaultTolerantLoop", "HeartbeatMonitor", "FaultEvent",
           "ElasticMeshManager", "replan_for_failure", "StragglerMitigator"]
