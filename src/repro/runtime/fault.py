"""Fault tolerance: heartbeats, failure detection, checkpoint-restart loop.

On a real fleet the heartbeat transport is the cluster controller (GKE / Borg
health checks) or a side-channel allreduce; here the monitor is transport-
agnostic (callers feed ``beat()``/``fail()``) and a ``FailureInjector`` drives
the same code paths in tests — the *loop logic* (detect → checkpoint-restore
→ re-mesh → replay data cursor) is exactly what runs at scale.

Determinism on restart: the data pipeline is cursor-addressable (seed +
step), so a restart replays from the last checkpoint step with identical
batches — verified in tests/test_fault.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class FaultEvent:
    kind: str                 # "node_down" | "straggler" | "restart"
    detail: str
    step: int
    wall: float = dataclasses.field(default_factory=time.time)


class HeartbeatMonitor:
    """Deadline-based liveness tracking for participant nodes."""

    def __init__(self, nodes: List[str], timeout_s: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self._clock = clock
        self._last: Dict[str, float] = {n: clock() for n in nodes}
        self._failed: set[str] = set()

    def beat(self, node: str, at: Optional[float] = None) -> None:
        if node not in self._failed:
            self._last[node] = self._clock() if at is None else at

    def fail(self, node: str) -> None:
        self._failed.add(node)

    def revive(self, node: str) -> None:
        """The controller replaced/recovered the node: clear its failure
        and restart its deadline."""
        self._failed.discard(node)
        self.beat(node)

    def remove(self, node: str) -> None:
        """Drop the node from tracking entirely (it left the fleet)."""
        self._failed.discard(node)
        self._last.pop(node, None)

    def dead_nodes(self) -> List[str]:
        now = self._clock()
        out = [n for n, t in self._last.items()
               if n in self._failed or now - t > self.timeout]
        return sorted(set(out))

    def healthy(self) -> bool:
        return not self.dead_nodes()

    @property
    def nodes(self) -> List[str]:
        return sorted(self._last)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff — shared by the fleet
    router (placement retries, give-up re-placement) and the workers
    (local re-dispatch after a transport error / timeout).

    Attempt ``k`` (0-based) waits ``backoff_base_s · backoff_mult**k``
    before retrying, capped at ``backoff_cap_s`` (a worker that fails for
    a long stretch must not back off past recovery — uncapped doubling
    turns a burst of failures into an astronomically long sleep); after
    ``max_retries`` failed attempts the work is handed back to the caller
    (the router re-places it, or sheds it)."""
    max_retries: int = 3
    backoff_base_s: float = 0.05
    backoff_mult: float = 2.0
    backoff_cap_s: float = 30.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_base_s < 0 or self.backoff_mult < 1.0:
            raise ValueError("backoff needs base >= 0 and mult >= 1")
        if self.backoff_cap_s <= 0:
            raise ValueError("backoff_cap_s must be > 0")

    def backoff_s(self, attempt: int) -> float:
        """Delay before retry number ``attempt`` (0-based)."""
        return min(self.backoff_base_s * self.backoff_mult
                   ** max(attempt, 0), self.backoff_cap_s)


class CircuitBreaker:
    """Per-worker dispatch-failure breaker (clock-injected, so it works
    identically on the virtual clock).

    ``closed`` → ``open`` after ``fail_threshold`` failures without an
    intervening success; ``open`` → ``half_open`` once
    ``reset_timeout_s`` has elapsed (the next placement is the probe);
    a ``half_open`` success closes, a ``half_open`` failure re-opens.
    Successes while ``open`` are ignored — draining old queue work is
    not evidence the *link* recovered.
    """

    def __init__(self, fail_threshold: int = 3,
                 reset_timeout_s: float = 1.0):
        if fail_threshold <= 0:
            raise ValueError("fail_threshold must be >= 1")
        self.fail_threshold = fail_threshold
        self.reset_timeout_s = reset_timeout_s
        self.state = "closed"              # "closed"|"open"|"half_open"
        self.failures = 0                  # since the last success
        self.opened_at = 0.0
        self.opened_total = 0

    def record_failure(self, now: float) -> bool:
        """Returns True iff this failure newly opened the breaker."""
        self.failures += 1
        if (self.state == "half_open"
                or (self.state == "closed"
                    and self.failures >= self.fail_threshold)):
            self.state = "open"
            self.opened_at = now
            self.opened_total += 1
            return True
        return False

    def record_success(self, now: float) -> None:
        if self.state == "half_open":
            self.state = "closed"
        if self.state == "closed":
            self.failures = 0

    def allows(self, now: float) -> bool:
        """May this worker receive new placements at ``now``?  Flips
        ``open`` → ``half_open`` when the reset window has elapsed."""
        if (self.state == "open"
                and now - self.opened_at >= self.reset_timeout_s):
            self.state = "half_open"
        return self.state != "open"

    def reset(self) -> None:
        """Administrative reset (worker re-admission)."""
        self.state, self.failures = "closed", 0

    def snapshot(self) -> Dict[str, Any]:
        return {"state": self.state, "failures": self.failures,
                "opened_total": self.opened_total}


class FaultTolerantLoop:
    """Checkpoint/restart training driver.

    step_fn(state, batch) → (state, metrics); batch_fn(step) → batch
    (cursor-addressable). On detected failure: restore newest checkpoint,
    optionally re-mesh (elastic.py), resume from the restored step.
    """

    def __init__(self, step_fn: Callable, batch_fn: Callable,
                 ckpt: CheckpointManager, monitor: HeartbeatMonitor,
                 ckpt_every: int = 50,
                 on_failure: Optional[Callable[[List[str]], Any]] = None):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = ckpt
        self.monitor = monitor
        self.ckpt_every = ckpt_every
        self.on_failure = on_failure
        self.events: List[FaultEvent] = []

    def run(self, state, start_step: int, n_steps: int,
            fail_at: Optional[Dict[int, str]] = None):
        """``fail_at``: {step: node} — test-injected failures."""
        step = start_step
        restored = self.ckpt.restore_or_none(state)
        if restored is not None and self.ckpt.latest is not None:
            state, step = restored, self.ckpt.latest
            self.events.append(FaultEvent("restart",
                                          f"resumed step {step}", step))
        end = start_step + n_steps
        fail_at = dict(fail_at) if fail_at else None
        while step < end:
            if fail_at and step in fail_at:
                # consume the injection: a node fails once and the
                # controller replaces it (otherwise restart → replay would
                # re-trigger it forever)
                self.monitor.fail(fail_at.pop(step))
            dead = self.monitor.dead_nodes()
            if dead:
                self.events.append(FaultEvent("node_down", ",".join(dead),
                                              step))
                if self.on_failure is not None:
                    self.on_failure(dead)
                # restore from newest checkpoint and resume
                latest = self.ckpt.latest
                if latest is not None:
                    state = self.ckpt.restore(state)
                    step = latest
                for n in dead:       # controller replaces / drops the node
                    self.monitor.revive(n)
                self.events.append(FaultEvent("restart",
                                              f"resume step {step}", step))
            batch = self.batch_fn(step)
            state, metrics = self.step_fn(state, batch)
            step += 1
            if step % self.ckpt_every == 0:
                self.ckpt.save_async(state, step)
        self.ckpt.wait()
        return state, step
