"""Cost models: (a) the Jetson/GLOO/WiFi edge simulator that reproduces the
paper's tables on this CPU-only container, and (b) the TPU v5e roofline used
by §Roofline.

Edge-simulator calibration (DESIGN.md §6) — constants are derived from
hardware specs and first principles, *not* fitted to the paper's result
tables:

* Jetson Orin Nano (8 GB, 15 W mode): 1024 Ampere CUDA cores × 2 FLOP ×
  0.625 GHz = 1.28 TFLOP/s fp32 peak; small-batch ViT kernels reach ~30-40 %
  → effective ≈ 0.44 TFLOP/s, plus a fixed per-inference launch overhead.
* GLOO staging: every communicated tensor crosses GPU→CPU then CPU→GPU.
  Pinned-copy bandwidth on LPDDR5 is high, but the many-small-tensor regime
  (one collective per transformer block) is latency-dominated: effective
  ≈ 80 MB/s + 1.5 ms fixed per collective call.
* WiFi wire time: bytes / BW, BW ∈ {200..900} Mbps (tc-netem analogue), plus
  ~2 ms RTT per collective round.
* Energy: 15 W board power while computing, 9 W while staging/waiting
  (≈40 % idle fraction during comm), × time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# ---------------------------------------------------------------------------
# TPU v5e roofline constants (per chip) — §Roofline of EXPERIMENTS.md
# ---------------------------------------------------------------------------

TPU_PEAK_FLOPS = 197e12          # bf16 FLOP/s
TPU_HBM_BW = 819e9               # bytes/s
TPU_ICI_BW = 50e9                # bytes/s per link (≈ per-chip usable 2D ring)
TPU_HBM_GB = 16.0


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_terms(hlo_flops: float, hlo_bytes: float,
                   collective_bytes: float, n_chips: int) -> RooflineTerms:
    return RooflineTerms(
        compute_s=hlo_flops / (n_chips * TPU_PEAK_FLOPS),
        memory_s=hlo_bytes / (n_chips * TPU_HBM_BW),
        collective_s=collective_bytes / (n_chips * TPU_ICI_BW),
    )


# ---------------------------------------------------------------------------
# Edge (Jetson) simulator — reproduces paper Tables 2/4 & Fig. 6 mechanics
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EdgeConstants:
    """Calibration (DESIGN.md §6): the compute-efficiency curve is anchored
    to the paper's *single-device* measurements (its own 'profile, do not
    estimate' doctrine — the local column is calibration input, the
    distributed tables are validation output); staging/wire/energy constants
    come from hardware specs."""
    # effective FLOP/s saturates with occupancy: eff(B) = e_inf - e_slope/B
    eff_inf: float = 0.62e12
    eff_slope: float = 0.19e12
    launch_overhead_ms: float = 6.0     # per-inference fixed cost
    coord_overhead_ms: float = 30.0     # master-worker partition/assemble
    voltage_eff_penalty: float = 0.70   # staging copies pollute SM occupancy
    # GLOO pinned-copy bandwidth ramps with transfer size (DMA setup
    # amortization): bw(x) = base + extra·x/(x+knee)
    staging_bw_base: float = 100e6
    staging_bw_extra: float = 410e6
    staging_knee_bytes: float = 5e6
    staging_fixed_ms: float = 1.6       # per collective call
    wire_rtt_ms: float = 1.0            # per collective round (WiFi)
    power_active_w: float = 5.8         # incremental board power, computing
    power_comm_w: float = 0.25          # incremental during staging/wire
    sync_overhead_ms: float = 4.0       # barrier/straggler per block set

    def eff(self, b_eff: float) -> float:
        return max(self.eff_inf - self.eff_slope / max(b_eff, 0.25), 0.05e12)

    def staging_ms(self, bytes_per_call: float, n_calls: int) -> float:
        bw = (self.staging_bw_base + self.staging_bw_extra *
              bytes_per_call / (bytes_per_call + self.staging_knee_bytes))
        per_call = self.staging_fixed_ms + bytes_per_call / bw * 1e3
        return per_call * n_calls + self.sync_overhead_ms


@dataclasses.dataclass(frozen=True)
class EdgeWorkload:
    """ViT-style workload description (per sample)."""
    n_layers: int = 12
    d_model: int = 768
    d_ff: int = 3072
    n_tokens: int = 197                 # full sequence
    bytes_per_el: int = 4               # fp32 on Jetson


def vit_flops_per_sample(w: EdgeWorkload, n_tokens: Optional[int] = None,
                         kv_tokens: Optional[int] = None) -> float:
    """Dense transformer forward FLOPs for one sample.

    ``n_tokens`` = query tokens processed on this device; ``kv_tokens`` =
    attention context length (≠ n_tokens under PRISM partitioning).
    """
    N = w.n_tokens if n_tokens is None else n_tokens
    K = N if kv_tokens is None else kv_tokens
    d, f = w.d_model, w.d_ff
    per_layer = (
        2 * N * d * (3 * d)            # QKV projections
        + 2 * N * K * d * 2            # scores + weighted sum
        + 2 * N * d * d                # output projection
        + 2 * N * d * f * 2            # MLP up+down
    )
    return w.n_layers * per_layer


class EdgeCostModel:
    """Latency/energy simulator for the 2-board Jetson prototype."""

    def __init__(self, consts: EdgeConstants = EdgeConstants(),
                 workload: EdgeWorkload = EdgeWorkload()):
        self.c = consts
        self.w = workload

    # -- execution modes ----------------------------------------------------

    def local(self, batch: int) -> Dict[str, float]:
        """Single-device inference (paper's lower-bound baseline)."""
        fl = vit_flops_per_sample(self.w) * batch
        compute_ms = fl / self.c.eff(batch) * 1e3 + self.c.launch_overhead_ms
        return self._pack(batch, compute_ms, 0.0, 0.0, boards=1)

    def distributed(self, batch: int, bandwidth_mbps: float, P: int = 2,
                    L: Optional[int] = None) -> Dict[str, float]:
        """Voltage (L=None → full exchange) or PRISM (L segment means).

        Per block each device stages+sends its share and stages the received
        share: Voltage moves (P-1)/P·N·D per device, PRISM (P-1)·L·D.
        """
        w, c = self.w, self.c
        Np = w.n_tokens // P + (w.n_tokens % P > 0)
        if L is None:                      # Voltage: full-tensor exchange
            recv_el = (P - 1) * Np * w.d_model
            flops = vit_flops_per_sample(w, Np, w.n_tokens)
            # Voltage re-projects gathered K/V on every device (the redundant
            # recompute PRISM's reformulation removes):
            flops += w.n_layers * 2 * (w.n_tokens - Np) * w.d_model * (2 * w.d_model)
            eff_pen = c.voltage_eff_penalty
        else:                              # PRISM
            recv_el = (P - 1) * L * w.d_model
            flops = vit_flops_per_sample(w, Np, Np + (P - 1) * L)
            eff_pen = 1.0

        staged_bytes = 2 * recv_el * w.bytes_per_el * batch   # D2H + H2D
        wire_bytes = recv_el * w.bytes_per_el * batch
        n_coll = w.n_layers

        # per-device occupancy scales with its token share → b_eff = B·Np/N
        b_eff = batch * Np / w.n_tokens
        compute_ms = (flops * batch / (c.eff(b_eff) * eff_pen) * 1e3
                      + c.launch_overhead_ms + c.coord_overhead_ms)
        staging_ms = c.staging_ms(staged_bytes, n_coll)
        # Mbps → bytes/ms = BW·125e3 / 1e3
        wire_ms = (wire_bytes * n_coll / (bandwidth_mbps * 125.0)
                   + n_coll * c.wire_rtt_ms)
        return self._pack(batch, compute_ms, staging_ms, wire_ms, boards=P)

    # -- packing -------------------------------------------------------------

    def pack(self, batch, compute_ms, staging_ms, wire_ms, boards):
        """Compose a latency decomposition + energy into one result row —
        public so profiling backends can mix measured and modeled terms."""
        return self._pack(batch, compute_ms, staging_ms, wire_ms, boards)

    def _pack(self, batch, compute_ms, staging_ms, wire_ms, boards):
        total = compute_ms + staging_ms + wire_ms
        energy_j = boards * (self.c.power_active_w * compute_ms
                             + self.c.power_comm_w * (staging_ms + wire_ms)
                             ) / 1e3
        return {"total_ms": total, "compute_ms": compute_ms,
                "staging_ms": staging_ms, "comm_ms": wire_ms,
                "per_sample_ms": total / batch,
                "per_sample_j": energy_j / batch}
