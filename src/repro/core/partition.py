"""Position-wise partitioning (master–worker view) and single-host oracles.

The paper's terminal device splits ``X ∈ R^{N×D}`` into ``P`` equal parts
along the sequence dimension.  These helpers provide (a) the partitioning /
reassembly math and (b) a *single-host simulation* of the P-device
computation — the oracle the distributed (shard_map) implementation and the
Pallas kernels are validated against, and the engine the edge latency
simulator drives.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.prism_attention import prism_attention, reference_attention
from repro.core.segment_means import segment_means


def partition_sequence(x: jnp.ndarray, P: int, axis: int = 1) -> jnp.ndarray:
    """Split [..., N, ...] into [P, ..., N/P, ...] along ``axis``."""
    axis = axis % x.ndim
    N = x.shape[axis]
    if N % P != 0:
        raise ValueError(f"sequence length {N} not divisible by P={P}")
    parts = jnp.split(x, P, axis=axis)
    return jnp.stack(parts, axis=0)


def unpartition_sequence(parts: jnp.ndarray, axis: int = 1) -> jnp.ndarray:
    """Inverse of :func:`partition_sequence`: [P, ..., N/P, ...] → [..., N, ...]."""
    P = parts.shape[0]
    return jnp.concatenate([parts[p] for p in range(P)], axis=axis)


def simulate_prism_attention(
    q: jnp.ndarray,   # [B, N, H, dh]  full-sequence projected queries
    k: jnp.ndarray,   # [B, N, Hk, dh] full-sequence projected keys
    v: jnp.ndarray,   # [B, N, Hk, dh]
    P: int,
    L: int,
    *,
    causal: bool = False,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Single-host oracle of the P-device PRISM attention.

    Computes what every device p would produce (local full K/V + remote
    segment means, scaling-aware softmax) and concatenates the outputs back
    into the full sequence.  Matches the shard_map implementation exactly.
    """
    B, N, H, dh = q.shape
    Np = N // P
    seg = Np // L
    qp = partition_sequence(q, P)     # [P, B, Np, H, dh]
    kp = partition_sequence(k, P)
    vp = partition_sequence(v, P)
    # [P, B, L, Hk, dh] — means of *projected* K/V (linearity; no re-projection)
    km = jax.vmap(lambda t: segment_means(t, L, axis=1))(kp)
    vm = jax.vmap(lambda t: segment_means(t, L, axis=1))(vp)
    km_all = km.transpose(1, 0, 2, 3, 4)   # [B, P, L, Hk, dh]
    vm_all = vm.transpose(1, 0, 2, 3, 4)

    outs = []
    for p in range(P):
        outs.append(
            prism_attention(
                qp[p], kp[p], vp[p], km_all, vm_all, p, seg,
                causal=causal, logit_softcap=logit_softcap, scale=scale,
            )
        )
    return jnp.concatenate(outs, axis=1)


def simulate_voltage_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, P: int, *,
    causal: bool = False, logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Single-host oracle of Voltage (full-tensor exchange).

    Voltage's AllGather reconstructs the complete K/V on every device, so the
    math is *exactly* full attention — partitioning only changes where the
    FLOPs run. We still walk the partitions to mirror the distributed code.
    """
    B, N, H, dh = q.shape
    Np = N // P
    qp = partition_sequence(q, P)
    outs = []
    for p in range(P):
        outs.append(
            reference_attention(
                qp[p], k, v, causal=causal, q_offset=p * Np,
                logit_softcap=logit_softcap, scale=scale,
            )
        )
    return jnp.concatenate(outs, axis=1)
