"""Offline profiling sweep (paper §3.3, Fig. 2).

Sweeps batch size × compression rate × bandwidth and fills the performance
map. Two backends:

* ``profile_simulated`` — the edge cost model (Jetson/GLOO/WiFi constants);
  reproduces the paper's sweep (~200 inference passes equivalent) instantly.
* ``profile_measured`` — actually runs the JAX ViT partition forward on this
  host (batch-swept wall clock via ``timeit_jax``) for the compute term and
  composes it with the modeled staging/wire terms; this is what a real
  deployment would run once per fleet.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

from repro.core.costmodel import EdgeCostModel, EdgeWorkload
from repro.core.perfmap import PerfEntry, PerfKey, PerfMap
from repro.core.segment_means import cr_to_L

PAPER_BATCHES = (1, 2, 4, 8, 16, 32)
PAPER_CRS = (3.3, 4.95, 9.9)
PAPER_BWS = (200, 300, 400, 500, 600, 700, 800, 900)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    batches: Sequence[int] = PAPER_BATCHES
    crs: Sequence[float] = PAPER_CRS
    bandwidths_mbps: Sequence[float] = PAPER_BWS
    P: int = 2
    warmup_runs: int = 20          # T in the paper's cost estimate


def sweep_cost(spec: SweepSpec) -> int:
    """|B|·|CR|·|BW|·T inference passes (paper's one-time profiling cost)."""
    return (len(spec.batches) * len(spec.crs) * len(spec.bandwidths_mbps)
            * spec.warmup_runs)


def profile_simulated(model: Optional[EdgeCostModel] = None,
                      spec: SweepSpec = SweepSpec()) -> PerfMap:
    model = model or EdgeCostModel()
    pm = PerfMap()
    N = model.w.n_tokens
    for B in spec.batches:
        r = model.local(B)
        pm.put(PerfKey("local", B, 0.0, 0.0), _entry(r))
        for bw in spec.bandwidths_mbps:
            rv = model.distributed(B, bw, spec.P, L=None)
            pm.put(PerfKey("voltage", B, 0.0, bw), _entry(rv))
            for cr in spec.crs:
                L = cr_to_L(N, spec.P, cr)
                rp = model.distributed(B, bw, spec.P, L=L)
                pm.put(PerfKey("prism", B, cr, bw), _entry(rp, {"L": L}))
    return pm


def profile_measured(spec: SweepSpec = SweepSpec(),
                     n_layers: int = 12, iters: int = 3) -> PerfMap:
    """Measure the compute term by running the real JAX ViT partition forward
    on this host, scaled to Jetson via the spec ratio; staging/wire modeled."""
    import jax
    import jax.numpy as jnp
    from repro.utils.timing import timeit_jax
    from repro.configs import get_config
    from repro.core.exchange import ExchangeConfig, ExchangeMode
    from repro.models import registry

    cfg = get_config("vit-base-16")
    params = registry.init_params(cfg, seed=0)
    fwd = registry.forward_fn(cfg)
    model = EdgeCostModel()
    pm = PerfMap()
    xloc = ExchangeConfig(ExchangeMode.LOCAL)

    # host-measured compute curve (arbitrary units) → normalized so B=1
    # matches the Jetson-calibrated model; shape of the curve is measured.
    t1 = None
    for B in spec.batches:
        imgs = jnp.zeros((B, 224, 224, 3), jnp.float32)
        jit_fwd = jax.jit(lambda p, im: fwd(p, {"images": im}, xloc)[0])
        t = timeit_jax(jit_fwd, params, imgs, iters=iters, warmup=1)
        t1 = t if t1 is None else t1
        scale = model.local(1)["compute_ms"] / 1e3 / t1
        compute_ms = t * scale * 1e3
        r = dict(model.local(B))
        r["compute_ms"] = compute_ms
        r["total_ms"] = compute_ms
        r["per_sample_ms"] = compute_ms / B
        r["per_sample_j"] = model.c.power_active_w * compute_ms / 1e3 / B
        pm.put(PerfKey("local", B, 0.0, 0.0), _entry(r, {"measured": True}))
        for bw in spec.bandwidths_mbps:
            rv = model.distributed(B, bw, spec.P, L=None)
            pm.put(PerfKey("voltage", B, 0.0, bw), _entry(rv))
            for cr in spec.crs:
                L = cr_to_L(model.w.n_tokens, spec.P, cr)
                rp = model.distributed(B, bw, spec.P, L=L)
                pm.put(PerfKey("prism", B, cr, bw), _entry(rp, {"L": L}))
    return pm


def _entry(r: dict, meta: Optional[dict] = None) -> PerfEntry:
    return PerfEntry(total_ms=r["total_ms"], per_sample_ms=r["per_sample_ms"],
                     per_sample_j=r["per_sample_j"],
                     compute_ms=r["compute_ms"], staging_ms=r["staging_ms"],
                     comm_ms=r["comm_ms"], meta=meta or {})
