"""Offline profiling sweep (paper §3.3, Fig. 2) — back-compat surface.

The canonical implementation now lives in :mod:`repro.profiling` (pluggable
``ProfileBackend`` registry: ``simulated`` / ``measured`` / ``trace``); this
module re-exports the sweep grids and keeps the two historic free functions:

* :func:`profile_simulated` — supported thin wrapper over the ``simulated``
  backend (the paper's instant cost-model sweep).
* :func:`profile_measured` — **deprecated** shim forwarding to the
  ``measured`` backend.  It used to hard-code the ``vit-base-16`` forward;
  profile through ``InferenceSession.profile(backend="measured")`` to
  measure the session's own config and registered plan executables.  The
  dead ``n_layers`` parameter (accepted, never used) is gone.
"""
from __future__ import annotations

import warnings
from typing import Optional

from repro.core.costmodel import EdgeCostModel
from repro.core.perfmap import PerfMap
from repro.profiling.sweep import (PAPER_BATCHES, PAPER_BWS, PAPER_CRS,
                                   SweepSpec, sweep_cost)

__all__ = ["PAPER_BATCHES", "PAPER_CRS", "PAPER_BWS", "SweepSpec",
           "sweep_cost", "profile_simulated", "profile_measured"]


def profile_simulated(model: Optional[EdgeCostModel] = None,
                      spec: SweepSpec = SweepSpec()) -> PerfMap:
    from repro.profiling.backends import ProfileContext, get_backend
    return get_backend("simulated").profile(ProfileContext(), spec,
                                            model=model)


def profile_measured(spec: SweepSpec = SweepSpec(), iters: int = 3,
                     **legacy) -> PerfMap:
    """Deprecated: measure through the ``measured`` backend on a fresh
    ``vit-base-16`` session (the seed's hard-coded behaviour)."""
    warnings.warn(
        "profile_measured is deprecated; use InferenceSession.profile("
        "backend='measured') to profile the session's own config and plans",
        DeprecationWarning, stacklevel=2)
    unknown = set(legacy) - {"n_layers"}
    if unknown:
        raise TypeError(f"profile_measured got unexpected keyword arguments "
                        f"{sorted(unknown)}")
    if "n_layers" in legacy:
        warnings.warn("profile_measured(n_layers=...) was never used and has "
                      "been removed; the value is ignored",
                      DeprecationWarning, stacklevel=2)
    from repro.api import ExecutionPlan, InferenceSession
    from repro.core.segment_means import cr_to_L
    from repro.profiling.sweep import VIT_SEQ_LEN
    plans = [ExecutionPlan.local()]
    for cr in spec.crs:
        plans.append(ExecutionPlan.prism_sim(
            L=cr_to_L(VIT_SEQ_LEN, spec.P, cr), cr=cr,
            seq_shards=spec.P))
    session = InferenceSession.from_config("vit-base-16", reduced=False,
                                           plans=plans)
    return session.profile(spec, backend="measured", iters=iters)
