"""Scaling-aware softmax attention over Segment-Means-augmented keys (PRISM).

The reference (pure ``jnp``) semantics of the paper's attention:

  * Queries come from the local partition ``X_p``.
  * Keys/Values are the local partition's full K/V **plus** the Segment Means
    of every other partition (Eq. 2).  Because projections are linear,
    ``mean(X_seg)·W_k == mean(X_seg·W_k)`` — so devices exchange *projected*
    segment means and never re-project remote features (this is the
    "eliminates redundant Key/Value recomputation" part of the paper's
    scaling-aware softmax reformulation).
  * Scaling-aware softmax: a mean key standing in for a segment of ``s`` real
    keys receives an additive logit bias ``log(s)`` so that
    ``s·exp(q·k̄) ≈ Σ_{i∈seg} exp(q·k_i)`` — one compressed key carries the
    attention mass of its whole segment.

Exactness property (tested): with segment size 1 (``CR·P == 1`` per
partition) the bias is ``log 1 = 0`` and the means are the tokens themselves,
so PRISM attention equals full (Voltage) attention bit-for-bit in f32.

Causal extension (ours; the paper evaluates bidirectional ViT): a segment
mean is visible to a query iff its *entire* segment lies in the query's past,
which at partition granularity means "partition index strictly less than the
query's partition".  Local keys use the ordinary causal mask.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _softcap(logits: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def _expand_kv(kv: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """Broadcast grouped KV heads [..., Hk, d] to query heads [..., H, d]."""
    hk = kv.shape[-2]
    if hk == n_heads:
        return kv
    assert n_heads % hk == 0, f"GQA heads {n_heads} not a multiple of {hk}"
    return jnp.repeat(kv, n_heads // hk, axis=-2)


def _grouped_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q [B,Nq,H,dh] · k [B,Nk,Hk,dh] → [B,H,Nq,Nk] f32 without
    materializing the GQA head repeat or f32 input copies (bf16 operands,
    f32 accumulation via preferred_element_type — MXU-native)."""
    B, Nq, H, dh = q.shape
    Hk = k.shape[2]
    if Hk == H:
        return jnp.einsum("bqhd,bkhd->bhqk", q, k,
                          preferred_element_type=jnp.float32)
    g = H // Hk
    qg = q.reshape(B, Nq, Hk, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32)
    return s.reshape(B, H, Nq, k.shape[1])


def _grouped_values(p: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """p [B,H,Nq,Nk] f32 · v [B,Nk,Hk,dh] → [B,Nq,H,dh] f32 (grouped)."""
    B, H, Nq, Nk = p.shape
    Hk, dh = v.shape[2], v.shape[3]
    if Hk == H:
        return jnp.einsum("bhqk,bkhd->bqhd", p, v,
                          preferred_element_type=jnp.float32)
    g = H // Hk
    pg = p.reshape(B, Hk, g, Nq, Nk)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pg, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Nq, H, dh)


def reference_attention(
    q: jnp.ndarray,               # [B, Nq, H, dh]
    k: jnp.ndarray,               # [B, Nk, Hk, dh]
    v: jnp.ndarray,               # [B, Nk, Hk, dh]
    *,
    causal: bool = False,
    q_offset: int = 0,            # global position of q[0] (sequence sharding)
    kv_offset: int = 0,           # global position of k[0]
    window: Optional[int] = None,  # sliding-window size (gemma2 local layers)
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    bias: Optional[jnp.ndarray] = None,   # [..., Nq, Nk] additive logit bias
    kv_mask: Optional[jnp.ndarray] = None,  # [B, Nk] bool; False → masked
) -> jnp.ndarray:
    """Plain full attention — the oracle for every optimized path."""
    B, Nq, H, dh = q.shape
    Nk = k.shape[1]
    scale = (dh ** -0.5) if scale is None else scale
    logits = _grouped_scores(q, k) * scale
    logits = _softcap(logits, logit_softcap)
    if bias is not None:
        logits = logits + bias
    qpos = q_offset + jnp.arange(Nq)[:, None]
    kpos = kv_offset + jnp.arange(Nk)[None, :]
    mask = jnp.ones((Nq, Nk), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    if kv_mask is not None:
        logits = jnp.where(kv_mask[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = _grouped_values(p, v)
    return out.astype(q.dtype)


def chunked_reference_attention(
    q: jnp.ndarray,               # [B, Nq, H, dh]
    k: jnp.ndarray,               # [B, Nk, Hk, dh]
    v: jnp.ndarray,
    *,
    chunk: Optional[int] = None,
    causal: bool = False,
    q_offset: int = 0,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    kv_mask: Optional[jnp.ndarray] = None,
    target_bytes: float = 0.5e9,
) -> jnp.ndarray:
    """``reference_attention`` evaluated in query chunks via ``lax.map``.

    Bounds the live score matrix to [B, H, chunk, Nk] (flash-style memory
    behaviour without a kernel — the Pallas kernel is the TPU fast path);
    backward recomputes per chunk. Exact same math as the unchunked oracle.
    The chunk size adapts so the f32 score block stays under
    ``target_bytes``.
    """
    B, Nq, H, dh = q.shape
    if chunk is None:
        per_row = B * H * k.shape[1] * 4.0
        chunk = max(int(target_bytes / max(per_row, 1.0)), 16)
        chunk = 1 << (chunk.bit_length() - 1)          # floor pow2
    C = min(chunk, Nq)
    if Nq % C:
        return reference_attention(q, k, v, causal=causal, q_offset=q_offset,
                                   window=window, logit_softcap=logit_softcap,
                                   scale=scale, kv_mask=kv_mask)
    nc = Nq // C
    qc = jnp.moveaxis(q.reshape(B, nc, C, H, dh), 1, 0)    # [nc, B, C, H, dh]
    offs = q_offset + jnp.arange(nc, dtype=jnp.int32) * C

    def one(args):
        qi, off = args
        return reference_attention(qi, k, v, causal=causal, q_offset=off,
                                   window=window, logit_softcap=logit_softcap,
                                   scale=scale, kv_mask=kv_mask)

    out = jax.lax.map(one, (qc, offs))                 # [nc, B, C, H, dv]
    return jnp.moveaxis(out, 0, 1).reshape(B, Nq, H, out.shape[-1])


def prism_attention(
    q: jnp.ndarray,        # [B, Np, H, dh]   local queries (partition p)
    k_local: jnp.ndarray,  # [B, Np, Hk, dh]  local full keys
    v_local: jnp.ndarray,  # [B, Np, Hk, dh]
    k_means: jnp.ndarray,  # [B, P, L, Hk, dh] segment-mean keys, ALL partitions
    v_means: jnp.ndarray,  # [B, P, L, Hk, dh]
    part_idx,              # scalar int — this device's partition index p
    seg_size: int,         # tokens represented by each segment mean
    *,
    causal: bool = False,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    kv_mask: Optional[jnp.ndarray] = None,      # [B, Np] bool; False → pad
    mean_counts: Optional[jnp.ndarray] = None,  # [B, P, L] real tokens per mean
    q_offset=0,                                 # local offset (chunking)
) -> jnp.ndarray:
    """Scaling-aware softmax attention over [local full ‖ remote means].

    ``k_means[:, p]`` (own partition) is always masked out — the local full
    keys already cover it.  Under ``causal=True`` only partitions strictly
    before ``part_idx`` contribute their means.  Padded sequences pass
    ``kv_mask`` (local keys) and ``mean_counts`` (mask-aware means; the
    scaling bias becomes ``log(count)`` and empty segments are dropped).
    Long query blocks are processed in chunks (bounded f32 score memory).
    """
    B, Nq, H, dh = q.shape
    Nk_loc = k_local.shape[1]
    P, L = k_means.shape[1], k_means.shape[2]
    scale = (dh ** -0.5) if scale is None else scale

    # q-chunking: bound the [B, H, Nq, Nk_loc + P·L] f32 score block
    total_k = Nk_loc + P * L
    if (isinstance(q_offset, int) and q_offset == 0
            and B * H * Nq * total_k * 4 > 0.5e9
            and Nq % 2 == 0 and Nq >= 256):
        C = max(Nq // 2, 128)
        while B * H * C * total_k * 4 > 0.5e9 and C % 2 == 0 and C > 128:
            C //= 2
        if Nq % C == 0:
            nc = Nq // C
            qc = jnp.moveaxis(q.reshape(B, nc, C, H, dh), 1, 0)
            offs = jnp.arange(nc, dtype=jnp.int32) * C

            def one(args):
                qi, off = args
                return prism_attention(
                    qi, k_local, v_local, k_means, v_means, part_idx,
                    seg_size, causal=causal, logit_softcap=logit_softcap,
                    scale=scale, kv_mask=kv_mask, mean_counts=mean_counts,
                    q_offset=off)
            out = jax.lax.map(one, (qc, offs))
            return jnp.moveaxis(out, 0, 1).reshape(B, Nq, H, out.shape[-1])

    km_flat = k_means.reshape(B, P * L, *k_means.shape[3:])
    vm_flat = v_means.reshape(B, P * L, *v_means.shape[3:])

    # --- local block: ordinary (optionally causal) attention within X_p ---
    logits_loc = _grouped_scores(q, k_local) * scale
    logits_loc = _softcap(logits_loc, logit_softcap)
    if causal:
        qpos = q_offset + jnp.arange(Nq)[:, None]
        cmask = qpos >= jnp.arange(Nk_loc)[None, :]
        logits_loc = jnp.where(cmask[None, None], logits_loc, NEG_INF)
    if kv_mask is not None:
        logits_loc = jnp.where(kv_mask[:, None, None, :], logits_loc, NEG_INF)

    # --- segment-means block: scaling-aware softmax ---
    logits_mean = _grouped_scores(q, km_flat) * scale
    logits_mean = _softcap(logits_mean, logit_softcap)
    # scaling-aware bias: one mean key carries the mass of its segment.
    if mean_counts is None:
        logits_mean = logits_mean + jnp.log(float(seg_size))
        nonempty = jnp.ones((B, P * L), dtype=bool)
    else:
        counts = mean_counts.reshape(B, P * L)
        logits_mean = logits_mean + jnp.log(jnp.maximum(counts, 1.0)
                                            )[:, None, None, :]
        nonempty = counts > 0
    part_of_mean = jnp.repeat(jnp.arange(P), L)             # [P*L]
    if causal:
        visible = part_of_mean < part_idx                   # strictly past
    else:
        visible = part_of_mean != part_idx                  # everyone else
    logits_mean = jnp.where(visible[None, None, None, :], logits_mean, NEG_INF)
    logits_mean = jnp.where(nonempty[:, None, None, :], logits_mean, NEG_INF)

    logits = jnp.concatenate([logits_loc, logits_mean], axis=-1)
    p_attn = jax.nn.softmax(logits, axis=-1)
    out = (_grouped_values(p_attn[..., :Nk_loc], v_local)
           + _grouped_values(p_attn[..., Nk_loc:], vm_flat))
    return out.astype(q.dtype)


def prism_attention_dense_oracle(
    x: jnp.ndarray,        # [B, N, D] full (unpartitioned) sequence features
    wq, wk, wv,            # projection fns or matrices applied outside
    **_,
):  # pragma: no cover - placeholder guard
    raise NotImplementedError(
        "Use repro.core.partition.simulate_partitioned_forward for the "
        "single-host oracle of the distributed computation.")


@partial(jax.jit, static_argnames=("L", "seg_size", "causal"))
def prism_attention_from_projected(
    q, k, v, part_idx, *, L: int, seg_size: int, causal: bool = False
):
    """Convenience wrapper: derive means from the local projected K/V then
    run PRISM attention for a single partition against provided means of all
    partitions being just its own (P=1 degenerate case used in unit tests)."""
    km = segment_means_nd(k, L)[:, None]
    vm = segment_means_nd(v, L)[:, None]
    return prism_attention(q, k, v, km, vm, part_idx, seg_size, causal=causal)


def segment_means_nd(x: jnp.ndarray, L: int) -> jnp.ndarray:
    """Segment means over the token axis of [B, N, Hk, dh] → [B, L, Hk, dh]."""
    from repro.core.segment_means import segment_means
    return segment_means(x, L, axis=1)
