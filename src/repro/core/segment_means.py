"""Segment Means compression (PRISM Eq. 1) and compression-rate math.

Each sequence partition ``X_p ∈ R^{N_p×D}`` is divided into ``L`` equal,
non-overlapping segments; the column-wise mean of each segment forms the
compact representation ``Z_p ∈ R^{L×D}`` exchanged between devices.

Compression rate: ``CR = N / (L · P)`` — the paper's primary tuning knob,
because it directly controls staged/communicated volume.
"""
from __future__ import annotations

import jax.numpy as jnp


def segment_sizes(n_p: int, L: int) -> int:
    """Tokens per segment. Requires equal segments (paper keeps them integer)."""
    if L <= 0:
        raise ValueError(f"L must be positive, got {L}")
    if n_p % L != 0:
        raise ValueError(f"partition length {n_p} not divisible into {L} segments")
    return n_p // L


def segment_means(x: jnp.ndarray, L: int, axis: int = -2) -> jnp.ndarray:
    """Column-wise means of ``L`` equal segments along ``axis`` (Eq. 1).

    Works for any rank; the segmented axis defaults to the token axis of a
    ``[..., N_p, D]`` tensor. Output has length ``L`` on that axis.
    """
    axis = axis % x.ndim
    n_p = x.shape[axis]
    s = segment_sizes(n_p, L)
    new_shape = x.shape[:axis] + (L, s) + x.shape[axis + 1:]
    # Mean in f32 for numerical robustness, cast back.
    xr = x.reshape(new_shape)
    return xr.astype(jnp.float32).mean(axis=axis + 1).astype(x.dtype)


def segment_means_masked(x: jnp.ndarray, L: int, mask: jnp.ndarray,
                         axis: int = -2):
    """Mask-aware segment means for padded sequences.

    ``mask`` is boolean over the segmented axis (broadcastable to x's shape
    with trailing dims removed); padded positions are excluded from the mean.
    Returns ``(means, counts)`` where ``counts`` is the number of real tokens
    per segment — the scaling-aware softmax uses ``log(count)`` as the bias
    and masks segments with ``count == 0``.
    """
    axis = axis % x.ndim
    n_p = x.shape[axis]
    s = segment_sizes(n_p, L)
    new_shape = x.shape[:axis] + (L, s) + x.shape[axis + 1:]
    xr = x.reshape(new_shape).astype(jnp.float32)
    mshape = mask.shape[:axis] + (L, s)
    mr = mask.reshape(mshape).astype(jnp.float32)
    counts = mr.sum(axis=axis + 1)                        # [..., L]
    mexp = mr.reshape(mr.shape + (1,) * (xr.ndim - mr.ndim))
    total = (xr * mexp).sum(axis=axis + 1)
    means = total / jnp.maximum(counts.reshape(
        counts.shape + (1,) * (total.ndim - counts.ndim)), 1.0)
    return means.astype(x.dtype), counts


def cr_to_L(n_tokens: int, P: int, cr: float) -> int:
    """Invert ``CR = N/(L·P)`` to the (integer) number of segment means."""
    L = int(round(n_tokens / (cr * P)))
    return max(L, 1)


def L_to_cr(n_tokens: int, P: int, L: int) -> float:
    return n_tokens / (L * P)


def comm_elements_voltage(P: int, N: int, D: int) -> int:
    """Per-device received elements for full-tensor exchange (Voltage)."""
    return (P - 1) * N * D // P


def comm_elements_prism(P: int, L: int, D: int) -> int:
    """Per-device received elements for Segment Means exchange (PRISM)."""
    return (P - 1) * L * D


def comm_reduction(P: int, N: int, L: int) -> float:
    """Communication speed-up factor of PRISM over Voltage (≈ CR)."""
    return comm_elements_voltage(P, N, 1) / max(comm_elements_prism(P, L, 1), 1)
