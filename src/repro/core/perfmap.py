"""Performance map — the paper's profiling artifact (§3.3).

A lightweight JSON store keyed by (mode, batch, CR, bandwidth) holding the
profiled totals and the three-way latency decomposition (computation,
communication, CPU–GPU staging — on TPU: compute / wire / staging-or-DCN).
Decoded ``PerfKey`` objects are cached alongside the string store, so
iterating ``entries()``/``candidates()`` never re-parses key strings.

Schema v2 embeds the hardware the map was profiled on (a
``HardwareProfile``/``LinkProfile`` block, see ``repro.profiling.hardware``)
so a map is self-describing; v1 and the pre-versioning flat format still
load (with ``hardware``/``link`` left ``None``).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional, Tuple


SCHEMA_VERSION = 2
_READABLE_VERSIONS = (1, SCHEMA_VERSION)


@dataclasses.dataclass(frozen=True)
class PerfKey:
    mode: str            # "local" | "voltage" | "prism"
    batch: int
    cr: float            # 0.0 for local / voltage
    bandwidth_mbps: float
    codec: str = ""      # exchange codec; "" = the mode's default
                         # (segment_means for prism — pre-codec maps load
                         # unchanged)

    def __post_init__(self):
        for field, val in (("mode", self.mode), ("codec", self.codec)):
            if "|" in val:
                raise ValueError(f"{field} {val!r} must not contain '|' "
                                 "(it is the key-encoding separator)")

    def encode(self) -> str:
        base = f"{self.mode}|{self.batch}|{self.cr:g}|{self.bandwidth_mbps:g}"
        return f"{base}|{self.codec}" if self.codec else base

    @staticmethod
    def decode(s: str) -> "PerfKey":
        parts = s.split("|")
        if len(parts) not in (4, 5):
            raise ValueError(f"malformed PerfKey string {s!r}: expected "
                             "'mode|batch|cr|bandwidth[|codec]'")
        m, b, c, w = (p.strip() for p in parts[:4])
        codec = parts[4].strip() if len(parts) == 5 else ""
        batch = float(b)           # tolerate "8.0"-style batch strings
        if batch != int(batch):
            raise ValueError(f"non-integer batch {b!r} in PerfKey {s!r}")
        return PerfKey(m, int(batch), float(c), float(w), codec)


@dataclasses.dataclass
class PerfEntry:
    total_ms: float
    per_sample_ms: float
    per_sample_j: float
    compute_ms: float
    staging_ms: float        # "Other" column of paper Table 2
    comm_ms: float           # wire time
    meta: Dict = dataclasses.field(default_factory=dict)

    def to_dict(self):
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d) -> "PerfEntry":
        return PerfEntry(**d)


class PerfMap:
    """The on-terminal-device JSON performance map."""

    def __init__(self):
        self._d: Dict[str, PerfEntry] = {}
        self._keys: Dict[str, PerfKey] = {}    # decoded-key cache
        self.hardware = None   # Optional[repro.profiling.HardwareProfile]
        self.link = None       # Optional[repro.profiling.LinkProfile]

    def put(self, key: PerfKey, entry: PerfEntry) -> None:
        enc = key.encode()
        self._d[enc] = entry
        self._keys[enc] = key

    def get(self, key: PerfKey) -> Optional[PerfEntry]:
        return self._d.get(key.encode())

    def entries(self) -> Iterable[Tuple[PerfKey, PerfEntry]]:
        for k, v in self._d.items():
            pk = self._keys.get(k)
            if pk is None:                     # key written via raw access
                pk = self._keys[k] = PerfKey.decode(k)
            yield pk, v

    # --- runtime queries -----------------------------------------------

    def candidates(self, batch: int, bandwidth_mbps: float
                   ) -> List[Tuple[PerfKey, PerfEntry]]:
        """All profiled modes at this batch, nearest profiled bandwidth."""
        bws = sorted({k.bandwidth_mbps for k, _ in self.entries()
                      if k.batch == batch})
        if not bws:
            return []
        bw = min(bws, key=lambda b: abs(b - bandwidth_mbps))
        return [(k, v) for k, v in self.entries()
                if k.batch == batch and
                (k.bandwidth_mbps == bw or k.mode == "local")]

    def batches(self) -> List[int]:
        return sorted({k.batch for k, _ in self.entries()})

    # --- persistence ------------------------------------------------------

    def to_doc(self) -> Dict:
        """The JSON-able document form — shared by ``save`` and the RPC
        ``Profile`` reply (``repro.rpc``), so a map measured in a worker
        process round-trips byte-identically to one read from disk."""
        doc = {"schema_version": SCHEMA_VERSION,
               "entries": {k: e.to_dict() for k, e in self._d.items()}}
        hw = {}
        if self.hardware is not None:
            hw["device"] = self.hardware.to_dict()
        if self.link is not None:
            hw["link"] = self.link.to_dict()
        if hw:
            doc["hardware"] = hw
        return doc

    @staticmethod
    def from_doc(data: Dict, *, source: str = "<doc>") -> "PerfMap":
        pm = PerfMap()
        if "schema_version" in data:
            ver = data["schema_version"]
            if ver not in _READABLE_VERSIONS:
                raise ValueError(
                    f"{source}: performance-map schema version {ver!r} is "
                    f"not supported (this build reads versions "
                    f"{list(_READABLE_VERSIONS)}); re-run the profiling "
                    "sweep to regenerate it")
            entries = data["entries"]
            if data.get("hardware") is not None:
                pm._load_hardware(data["hardware"], source)
        else:                      # pre-versioning flat map (v0 seed format)
            entries = data
        for k, d in entries.items():
            key = PerfKey.decode(k)    # validate + cache in one pass
            pm._d[k] = PerfEntry.from_dict(d)
            pm._keys[k] = key
        return pm

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_doc(), f, indent=1)
        os.replace(tmp, path)      # atomic

    @staticmethod
    def load(path: str) -> "PerfMap":
        with open(path) as f:
            data = json.load(f)
        return PerfMap.from_doc(data, source=path)

    def _load_hardware(self, block, path: str) -> None:
        from repro.profiling.hardware import HardwareProfile, LinkProfile
        try:
            if not isinstance(block, dict):
                raise ValueError(f"expected a JSON object, got "
                                 f"{type(block).__name__}")
            if "device" in block:
                self.hardware = HardwareProfile.from_dict(block["device"])
            if "link" in block:
                self.link = LinkProfile.from_dict(block["link"])
        except (TypeError, ValueError) as e:
            raise ValueError(
                f"{path}: corrupt hardware block in performance map: {e}"
            ) from e

    def __len__(self) -> int:
        return len(self._d)
