"""Runtime adaptive execution policy (paper §3.3).

Given an arriving batch size and the observed bandwidth, query the perf map
and pick the execution mode — ``local`` or ``distributed(best CR)`` —
minimizing per-sample latency or energy. Includes the derived artifacts the
paper reports: the batch crossover point and the bandwidth crossover.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple

from repro.core.perfmap import PerfEntry, PerfKey, PerfMap

Objective = Literal["latency", "energy"]


@dataclasses.dataclass(frozen=True)
class Decision:
    mode: str                  # "local" | "prism" | "voltage"
    cr: float                  # 0.0 unless prism
    expected: PerfEntry
    objective: Objective

    @property
    def distributed(self) -> bool:
        return self.mode != "local"


class AdaptivePolicy:
    def __init__(self, perfmap: PerfMap,
                 allow_modes: Tuple[str, ...] = ("local", "prism")):
        """``allow_modes`` defaults to the paper's deployment (voltage is
        profiled for reporting but never selected — it loses everywhere)."""
        self.pm = perfmap
        self.allow = allow_modes

    def decide(self, batch: int, bandwidth_mbps: float,
               objective: Objective = "latency") -> Decision:
        batch_key = self.nearest_batch(batch)
        cands = [(k, e) for k, e in self.pm.candidates(batch_key,
                                                       bandwidth_mbps)
                 if k.mode in self.allow]
        if not cands:
            raise LookupError("empty performance map")
        metric = (lambda e: e.per_sample_ms) if objective == "latency" else \
                 (lambda e: e.per_sample_j)
        k, e = min(cands, key=lambda kv: metric(kv[1]))
        return Decision(mode=k.mode, cr=k.cr, expected=e, objective=objective)

    def nearest_batch(self, batch: int) -> int:
        """Snap an arriving batch size to the nearest profiled one (ties
        toward the smaller batch) — the same snapping ``decide()`` uses."""
        bs = self.pm.batches()
        return min(bs, key=lambda b: (abs(b - batch), b))

    _nearest_batch = nearest_batch          # deprecated pre-PR2 spelling

    # --- paper-reported artifacts -----------------------------------------

    def batch_crossover(self, bandwidth_mbps: float,
                        objective: Objective = "latency") -> Optional[int]:
        """Smallest profiled batch at which distributed wins (paper: 8)."""
        for b in self.pm.batches():
            if self.decide(b, bandwidth_mbps, objective).distributed:
                return b
        return None

    def bandwidth_crossover(self, batch: int,
                            objective: Objective = "latency"
                            ) -> Optional[float]:
        """Smallest profiled bandwidth at which distributed wins at
        ``batch`` (paper: ≈340 Mbps at B=8)."""
        bws = sorted({k.bandwidth_mbps for k, _ in self.pm.entries()
                      if k.mode != "local"})
        for bw in bws:
            if self.decide(batch, bw, objective).distributed:
                return bw
        return None
