"""Runtime adaptive execution policy (paper §3.3).

Given an arriving batch size and the observed bandwidth, pick the execution
mode — ``local`` or ``distributed(best CR)`` — minimizing the configured
:class:`~repro.profiling.objectives.Objective` (latency, energy, weighted
tradeoff, or SLO-constrained; the legacy ``"latency"``/``"energy"`` strings
still work).

``AdaptivePolicy`` compiles the performance map into a dense
:class:`~repro.profiling.table.PolicyTable` per objective (one map walk,
then O(1) ``decide()`` with bandwidth interpolation between profiled grid
points) and exposes the paper-reported crossover artifacts derived from it.
Out-of-grid batches snap to the nearest profiled batch and the decision is
flagged ``extrapolated``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.core.perfmap import PerfMap
from repro.profiling.objectives import (EnergyObjective, LatencyObjective,
                                        Objective, ObjectiveLike,
                                        SLOObjective, WeightedObjective,
                                        resolve_objective)
from repro.profiling.table import BatchPlan, Decision, PolicyTable

__all__ = ["AdaptivePolicy", "BatchPlan", "Decision", "Objective",
           "ObjectiveLike", "LatencyObjective", "EnergyObjective",
           "WeightedObjective", "SLOObjective", "resolve_objective",
           "PolicyTable"]


class AdaptivePolicy:
    def __init__(self, perfmap: PerfMap,
                 allow_modes: Tuple[str, ...] = ("local", "prism")):
        """``allow_modes`` defaults to the paper's deployment (voltage is
        profiled for reporting but never selected — it loses everywhere)."""
        self.pm = perfmap
        self.allow = allow_modes
        self._tables: Dict[Tuple, PolicyTable] = {}

    def table(self, objective: ObjectiveLike = "latency") -> PolicyTable:
        """The compiled decision table for one objective (cached)."""
        obj = resolve_objective(objective)
        key = obj.cache_key()
        t = self._tables.get(key)
        if t is None:
            t = self._tables[key] = PolicyTable.compile(self.pm, self.allow,
                                                        obj)
        return t

    def invalidate(self) -> None:
        """Drop compiled tables (call after mutating the perf map, e.g. a
        calibration pass)."""
        self._tables.clear()

    def decide(self, batch: int, bandwidth_mbps: float,
               objective: ObjectiveLike = "latency") -> Decision:
        return self.table(objective).decide(batch, bandwidth_mbps)

    def nearest_batch(self, batch: int) -> int:
        """Snap an arriving batch size to the nearest profiled one (ties
        toward the smaller batch) — the same snapping ``decide()`` uses."""
        return self.table().nearest_batch(batch)

    _nearest_batch = nearest_batch          # deprecated pre-PR2 spelling

    # --- paper-reported artifacts (table-derived) --------------------------

    def batch_crossover(self, bandwidth_mbps: float,
                        objective: ObjectiveLike = "latency"
                        ) -> Optional[int]:
        """Smallest profiled batch at which distributed wins (paper: 8)."""
        return self.table(objective).batch_crossover(bandwidth_mbps)

    def bandwidth_crossover(self, batch: int,
                            objective: ObjectiveLike = "latency"
                            ) -> Optional[float]:
        """Smallest profiled bandwidth at which distributed wins at
        ``batch`` (paper: ≈340 Mbps at B=8)."""
        return self.table(objective).bandwidth_crossover(batch)
