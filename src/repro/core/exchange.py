"""Distributed exchange strategies: LOCAL / VOLTAGE / PRISM.

This is the paper's communication layer mapped onto JAX-native constructs:
``torch.distributed`` AllGather over GLOO  →  ``jax.lax.all_gather`` over a
named mesh axis inside ``jax.shard_map`` (manual over the *sequence* axis
only; every other mesh axis — `model` TP, `pod`/`data` batch — stays under
GSPMD auto-sharding).

Per Transformer block and device p:
  * VOLTAGE  — one all_gather of the full projected K/V:
               (P-1)/P · N · D received elements per device.
  * PRISM    — one all_gather of L projected segment means per partition:
               (P-1) · L · D received elements — smaller by the compression
               rate CR = N/(L·P); scaling-aware softmax consumes them.
  * LOCAL    — no sequence sharding; attention is ordinary full attention.

Decode-time analogue: the KV cache is sequence-sharded and partial attention
results merge with a numerically-stable log-sum-exp reduction (flash-decoding
style `psum`) — position-wise partitioning for autoregressive steps.
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.prism_attention import (
    NEG_INF,
    _expand_kv,
    _grouped_scores,
    _grouped_values,
    _softcap,
    reference_attention,
)
from repro.kernels import dispatch as kdsp
from repro.utils import compat


def all_gather_grad_safe(x: jnp.ndarray, axis_name: str, *, axis: int = 0,
                         tiled: bool = False) -> jnp.ndarray:
    """``jax.lax.all_gather`` whose backward reduce-scatters in f32.

    Rationale: XLA-CPU's AllReducePromotion pass crashes on bf16
    reduce-scatter reducers that carry layout copies ("Invalid binary
    instruction opcode copy"). Doing the cotangent reduce-scatter in f32
    sidesteps the promotion pass entirely; it is numerically a strict
    improvement and on TPU costs one extra cast pair. The forward collective
    is unchanged (bf16 wire bytes — what the roofline counts).
    """
    dtype = x.dtype

    @jax.custom_vjp
    def ag(v):
        return jax.lax.all_gather(v, axis_name, axis=axis, tiled=tiled)

    def fwd(v):
        return ag(v), None

    def bwd(_, ct):
        ct32 = ct.astype(jnp.float32)
        out = jax.lax.psum_scatter(ct32, axis_name, scatter_dimension=axis,
                                   tiled=tiled)
        return (out.astype(dtype),)

    ag.defvjp(fwd, bwd)
    return ag(x)


class ExchangeMode(str, enum.Enum):
    LOCAL = "local"          # no sequence partitioning (single-device analogue)
    VOLTAGE = "voltage"      # full-tensor exchange (Hu & Li, ICDCS'24)
    PRISM = "prism"          # Segment Means exchange + scaling-aware softmax
    PRISM_SIM = "prism_sim"  # PRISM math on unpartitioned tensors (training /
                             # finetuning / single-host validation)


@dataclass(frozen=True)
class ExchangeConfig:
    """How attention communicates across the sequence-partition axis."""
    mode: ExchangeMode = ExchangeMode.LOCAL
    seq_axis: Optional[str] = None   # mesh axis carrying sequence partitions
    seq_shards: int = 1              # P — number of sequence partitions
    L: int = 0                       # segment means per partition (PRISM)
    batch_axes: tuple = ()           # mesh axes sharding the batch dim
    strategy: Optional[str] = None   # registry name when it differs from the
                                     # mode (custom strategies reusing a
                                     # built-in ExchangeMode); None → mode
    codec: str = ""                  # repro.transport codec; "" = the
                                     # strategy's default (segment_means
                                     # for PRISM)
    codec_param: int = 0             # codec knob (quant tile / topk k)
    overlap_chunks: int = 0          # >0: ring exchange with this many
                                     # ppermute chunks per block transfer
                                     # (compute/comm overlap); 0 = gather

    def with_mode(self, mode: ExchangeMode) -> "ExchangeConfig":
        return dataclasses.replace(self, mode=mode, strategy=None)


def pin_activations(x: jnp.ndarray, cfg: ExchangeConfig) -> jnp.ndarray:
    """Pin [B, N, D...] activations to (batch over data axes, sequence over
    the partition axis, features replicated). Re-asserted at block
    boundaries so GSPMD never drifts into batch-replicated layouts."""
    if x.ndim < 2 or (not cfg.batch_axes and cfg.seq_axis is None):
        return x
    if not compat.SHARDING_HINTS_SAFE:    # 0.4.x: hint can corrupt values
        return x
    try:
        mesh = compat.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return x
        bax = tuple(a for a in cfg.batch_axes if a in mesh.axis_names)
        bsize = 1
        for a in bax:
            bsize *= mesh.shape[a]
        b_spec = (bax if (bax and x.shape[0] % bsize == 0) else
                  P.UNCONSTRAINED)
        seq_ok = (cfg.seq_axis is not None and x.shape[1] > 1 and
                  x.shape[1] % mesh.shape.get(cfg.seq_axis, 1) == 0)
        s_spec = cfg.seq_axis if seq_ok else P.UNCONSTRAINED
        spec = P(b_spec, s_spec, *([None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, AttributeError, TypeError):
        return x


def _attn_local_block(q, k, v, part_idx, Np, *, causal, window, softcap, scale):
    """Attention of local queries against gathered/global K/V."""
    q_off = part_idx * Np
    return reference_attention(
        q, k, v, causal=causal, q_offset=q_off, kv_offset=0,
        window=window, logit_softcap=softcap, scale=scale)


def exchange_attention(
    q: jnp.ndarray,   # [B, N, H, dh]  (N sharded over cfg.seq_axis unless LOCAL)
    k: jnp.ndarray,   # [B, N, Hk, dh]
    v: jnp.ndarray,   # [B, N, Hk, dh]
    cfg: ExchangeConfig,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    kv_mask: Optional[jnp.ndarray] = None,  # [B, N] bool; False → padding
) -> jnp.ndarray:
    """Attention with the configured cross-partition exchange.

    Dispatches through the ``repro.api.strategies`` registry — each registered
    ``ExchangeStrategy`` binds one of the ``*_prefill_attention`` functions
    below. Returns [B, N, H, dh] with the same sequence sharding as inputs.
    """
    from repro.api.strategies import get_strategy
    try:
        strategy = get_strategy(cfg.strategy or cfg.mode.value)
    except KeyError as e:                  # preserve the old contract
        raise ValueError(f"unknown exchange mode {cfg.mode}") from e
    return strategy.prefill_attention(
        q, k, v, cfg, causal=causal, window=window,
        logit_softcap=logit_softcap, scale=scale, kv_mask=kv_mask)


def local_prefill_attention(q, k, v, cfg, *, causal=False, window=None,
                            logit_softcap=None, scale=None, kv_mask=None):
    """No sequence partitioning: ordinary full attention (chunked above a
    memory threshold)."""
    B, Nq, H = q.shape[0], q.shape[1], q.shape[2]
    if B * H * Nq * k.shape[1] * 4 > 0.5e9:
        from repro.core.prism_attention import chunked_reference_attention
        return chunked_reference_attention(
            q, k, v, causal=causal, window=window,
            logit_softcap=logit_softcap, scale=scale, kv_mask=kv_mask)
    return reference_attention(
        q, k, v, causal=causal, window=window,
        logit_softcap=logit_softcap, scale=scale, kv_mask=kv_mask)


def prism_sim_prefill_attention(q, k, v, cfg, *, causal=False, window=None,
                                logit_softcap=None, scale=None, kv_mask=None):
    """PRISM math on unpartitioned tensors (training / single-host)."""
    from repro.core.partition import simulate_prism_attention
    if window is not None:
        raise NotImplementedError("PRISM_SIM with sliding window")
    return simulate_prism_attention(
        q, k, v, cfg.seq_shards, cfg.L, causal=causal,
        logit_softcap=logit_softcap, scale=scale)


def voltage_prefill_attention(q, k, v, cfg, *, causal=False, window=None,
                              logit_softcap=None, scale=None, kv_mask=None):
    """Full-tensor K/V all-gather (the paper's Voltage baseline).

    With ``cfg.overlap_chunks > 0`` (and no sliding window) the exchange
    runs through the chunked ring executor instead: ``ppermute`` block
    transfers double-buffered under per-block attention compute."""
    if cfg.overlap_chunks > 0 and window is None:
        from repro.transport.executor import ring_prefill_attention
        return ring_prefill_attention(q, k, v, cfg, causal=causal,
                                      logit_softcap=logit_softcap,
                                      scale=scale, kv_mask=kv_mask)
    axis = cfg.seq_axis
    if kv_mask is None:
        kv_mask = jnp.ones(k.shape[:2], dtype=bool)
    # Pin the projections to (batch-propagated, seq-sharded, replicated
    # heads): without this, GSPMD sometimes picks a partial head sharding
    # (e.g. 8-way on 40 heads) for the QKV matmuls and then involuntarily
    # replicates the stacked scan weights to reshard — catastrophic.
    q, k, v = (_pin_seq_sharding(t, axis) for t in (q, k, v))

    def volt(qs, ks, vs, ms):
        p = jax.lax.axis_index(axis)
        Np = qs.shape[1]
        # full-tensor exchange: the paper's Voltage baseline
        kg = all_gather_grad_safe(ks, axis, axis=1, tiled=True)
        vg = all_gather_grad_safe(vs, axis, axis=1, tiled=True)
        mg = jax.lax.all_gather(ms, axis, axis=1, tiled=True)  # bool: no grad
        from repro.core.prism_attention import chunked_reference_attention
        return chunked_reference_attention(
            qs, kg, vg, causal=causal, q_offset=p * Np,
            window=window, logit_softcap=logit_softcap, scale=scale,
            kv_mask=mg)
    bax = _manual_batch_axes(q.shape[0], cfg)
    return _seq_shard_map(volt, axis, n_masks=1, batch_axes=bax)(
        q, k, v, kv_mask)


def prism_prefill_attention(q, k, v, cfg, *, causal=False, window=None,
                            logit_softcap=None, scale=None, kv_mask=None):
    """Segment-Means exchange + scaling-aware softmax (the paper's PRISM)."""
    axis = cfg.seq_axis
    Pn = cfg.seq_shards
    had_mask = kv_mask is not None      # no mask → unmasked segment means
    if kv_mask is None:                 # (kernel-eligible) and exact log(seg)
        kv_mask = jnp.ones(k.shape[:2], dtype=bool)
    q, k, v = (_pin_seq_sharding(t, axis) for t in (q, k, v))

    L = cfg.L
    if window is not None:
        # Windowed layers: segment means of far context are invisible
        # under the window anyway, so exchange only the HALO — the
        # ceil(window / shard_len) preceding shards, fetched by
        # collective_permute — instead of a full gather. Comm drops from
        # (P-1)/P*N*D to n_halo/P*N*D per device.
        Np_g = q.shape[1] // Pn
        n_halo = min(-(-window // max(Np_g, 1)), Pn - 1)
        if causal and n_halo < Pn - 1:
            def halo(qs, ks, vs, ms):
                p = jax.lax.axis_index(axis)
                Np = qs.shape[1]
                parts_k, parts_v = [], []
                for sft in range(n_halo, 0, -1):
                    perm = [(i, i + sft) for i in range(Pn - sft)]
                    parts_k.append(jax.lax.ppermute(ks, axis, perm))
                    parts_v.append(jax.lax.ppermute(vs, axis, perm))
                kg = jnp.concatenate(parts_k + [ks], axis=1)
                vg = jnp.concatenate(parts_v + [vs], axis=1)
                base = (p - n_halo) * Np
                gpos = base + jnp.arange((n_halo + 1) * Np)
                valid = (gpos >= 0)[None, :]
                from repro.core.prism_attention import (
                    chunked_reference_attention)
                return chunked_reference_attention(
                    qs, kg, vg, causal=True, q_offset=n_halo * Np,
                    window=window, logit_softcap=logit_softcap,
                    scale=scale,
                    kv_mask=jnp.broadcast_to(
                        valid, (qs.shape[0], gpos.shape[0])))
            bax = _manual_batch_axes(q.shape[0], cfg)
            return _seq_shard_map(halo, axis, n_masks=1,
                                  batch_axes=bax)(q, k, v, kv_mask)
        return exchange_attention(
            q, k, v, cfg.with_mode(ExchangeMode.VOLTAGE), causal=causal,
            window=window, logit_softcap=logit_softcap, scale=scale,
            kv_mask=kv_mask)

    def prism(qs, ks, vs, ms):
        p = jax.lax.axis_index(axis)
        Np = qs.shape[1]
        seg = Np // L
        # L projected segment means per partition (linearity: no
        # re-projection of remote features — scaling-aware reformulation)
        if had_mask:
            km, cnt = kdsp.segment_means_masked(ks, L, ms, axis=1)
            vm, _ = kdsp.segment_means_masked(vs, L, ms, axis=1)
            cnt_all = jnp.moveaxis(jax.lax.all_gather(cnt, axis), 0, 1)
        else:
            km = kdsp.segment_means(ks, L, axis=1)    # [B, L, Hk, dh]
            vm = kdsp.segment_means(vs, L, axis=1)
            cnt_all = None                # exact log(seg) scaling bias
        km_all = all_gather_grad_safe(km, axis)       # [P, B, L, Hk, dh]
        vm_all = all_gather_grad_safe(vm, axis)
        km_all = jnp.moveaxis(km_all, 0, 1)         # [B, P, L, Hk, dh]
        vm_all = jnp.moveaxis(vm_all, 0, 1)
        return kdsp.prism_attention(qs, ks, vs, km_all, vm_all, p, seg,
                                    causal=causal,
                                    logit_softcap=logit_softcap,
                                    scale=scale,
                                    kv_mask=ms if had_mask else None,
                                    mean_counts=cnt_all)
    bax = _manual_batch_axes(q.shape[0], cfg)
    return _seq_shard_map(prism, axis, n_masks=1, batch_axes=bax)(
        q, k, v, kv_mask)



def _pin_seq_sharding(t: jnp.ndarray, axis: str) -> jnp.ndarray:
    """with_sharding_constraint: dim1 (sequence) on ``axis``, dim0 (batch)
    left to propagation, all trailing dims replicated."""
    U = P.UNCONSTRAINED
    try:
        spec = P(*([U] + [axis] + [None] * (t.ndim - 2)))
        return jax.lax.with_sharding_constraint(t, spec)
    except (ValueError, RuntimeError):
        return t      # no mesh context (single-host tests)


def _manual_batch_axes(batch: int, cfg: ExchangeConfig):
    """Batch axes to make manual in the exchange shard_map (device-local
    view = the paper's per-device partition). Empty when indivisible so
    small-batch tests keep working under GSPMD auto handling."""
    if not cfg.batch_axes:
        return ()
    try:
        mesh = compat.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return ()
        bax = tuple(a for a in cfg.batch_axes if a in mesh.axis_names)
        size = 1
        for a in bax:
            size *= mesh.shape[a]
        return bax if (bax and batch % size == 0) else ()
    except (AttributeError, RuntimeError, TypeError):
        return ()


def _seq_shard_map(fn, axis: str, n_masks: int = 0, batch_axes=()):
    """shard_map wrapper: manual over the sequence axis (+ batch axes when
    divisible, giving each device its true [B_loc, N_p, H, dh] partition);
    q/k/v share the [B, N, heads, dh] layout with N split over ``axis``;
    optional trailing [B, N] masks."""
    b = batch_axes if batch_axes else None
    spec = P(b, axis, None, None)
    in_specs = (spec, spec, spec) + (P(b, axis),) * n_masks
    manual = set((axis,) + tuple(batch_axes))
    return compat.shard_map(fn, in_specs=in_specs, out_specs=spec,
                         axis_names=manual, check_vma=False)


# ---------------------------------------------------------------------------
# Cross-attention exchange (whisper encoder memory, VLM image tokens)
# ---------------------------------------------------------------------------

def exchange_cross_attention(
    q: jnp.ndarray,       # [B, Nq, H, dh] — Nq sharded over cfg.seq_axis
    k_mem: jnp.ndarray,   # [B, M, Hk, dh] — memory, M sharded likewise
    v_mem: jnp.ndarray,
    mem_mask: jnp.ndarray,  # [B, M] bool — False for padding
    cfg: ExchangeConfig,
    *,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Cross-attention where the memory is position-partitioned.

    The paper's scheme applied to an encoder/image memory: each device owns a
    memory partition; PRISM broadcasts only mask-aware segment means of the
    other partitions (comm (P-1)·L·D vs Voltage's (P-1)/P·M·D).
    """
    if (cfg.mode in (ExchangeMode.LOCAL, ExchangeMode.PRISM_SIM)
            or cfg.seq_axis is None or cfg.seq_shards == 1):
        # PRISM_SIM never uses real collectives; these paths have no
        # simulation analogue (unsharded cache / memory), so run exact
        return reference_attention(q, k_mem, v_mem, kv_mask=mem_mask,
                                   logit_softcap=logit_softcap, scale=scale)
    axis, Pn, L = cfg.seq_axis, cfg.seq_shards, cfg.L
    q, k_mem, v_mem = (_pin_seq_sharding(t, axis) for t in (q, k_mem, v_mem))

    if cfg.mode == ExchangeMode.VOLTAGE:
        def volt(qs, ks, vs, ms):
            kg = all_gather_grad_safe(ks, axis, axis=1, tiled=True)
            vg = all_gather_grad_safe(vs, axis, axis=1, tiled=True)
            mg = jax.lax.all_gather(ms, axis, axis=1, tiled=True)  # bool: no grad
            return reference_attention(qs, kg, vg, kv_mask=mg,
                                       logit_softcap=logit_softcap, scale=scale)
        bax = _manual_batch_axes(q.shape[0], cfg) or None
        manual = {axis} | set(bax or ())
        return compat.shard_map(
            volt,
            in_specs=(P(bax, axis, None, None), P(bax, axis, None, None),
                      P(bax, axis, None, None), P(bax, axis)),
            out_specs=P(bax, axis, None, None),
            axis_names=manual, check_vma=False)(q, k_mem, v_mem, mem_mask)

    def prism_x(qs, ks, vs, ms):
        p = jax.lax.axis_index(axis)
        km, cnt = kdsp.segment_means_masked(ks, L, ms, axis=1)  # [B,L,Hk,dh]
        vm, _ = kdsp.segment_means_masked(vs, L, ms, axis=1)
        km_all = jnp.moveaxis(jax.lax.all_gather(km, axis), 0, 1)
        vm_all = jnp.moveaxis(jax.lax.all_gather(vm, axis), 0, 1)
        cnt_all = jnp.moveaxis(jax.lax.all_gather(cnt, axis), 0, 1)  # [B,P,L]
        return kdsp.prism_attention(qs, ks, vs, km_all, vm_all, p,
                                    seg_size=ks.shape[1] // L, causal=False,
                                    logit_softcap=logit_softcap, scale=scale,
                                    kv_mask=ms, mean_counts=cnt_all)
    bax = _manual_batch_axes(q.shape[0], cfg) or None
    manual = {axis} | set(bax or ())
    return compat.shard_map(
        prism_x,
        in_specs=(P(bax, axis, None, None), P(bax, axis, None, None),
                  P(bax, axis, None, None), P(bax, axis)),
        out_specs=P(bax, axis, None, None),
        axis_names=manual, check_vma=False)(q, k_mem, v_mem, mem_mask)


# ---------------------------------------------------------------------------
# MLA latent exchange (DeepSeek-V2): compress-then-exchange the latent c_kv
# ---------------------------------------------------------------------------

def exchange_attention_mla(
    q: jnp.ndarray,        # [B, N, H, dq]  (dq = nope+rope), N seq-sharded
    c_kv: jnp.ndarray,     # [B, N, r]      latent KV (post-norm)
    k_pe: jnp.ndarray,     # [B, N, dr]     shared rotary key
    w_uk: jnp.ndarray,     # [r, H, d_nope] up-projection for keys
    w_uv: jnp.ndarray,     # [r, H, d_v]    up-projection for values
    cfg: ExchangeConfig,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """PRISM over the MLA latent: devices exchange segment means of
    ``[c_kv ‖ k_pe]`` (r+dr floats/token — MLA's own compression compounds
    with PRISM's CR), then expand locally. Linearity of the up-projections
    makes mean-then-expand == expand-then-mean, so remote K/V are never
    re-projected (the paper's reformulation, in latent space).
    """
    B, N, H, dq = q.shape
    r = c_kv.shape[-1]
    d_nope = w_uk.shape[-1]
    d_v = w_uv.shape[-1]

    def expand(c, pe):
        # c: [B, n, r], pe: [B, n, dr] → k: [B, n, H, dq], v: [B, n, H, d_v]
        k_nope = jnp.einsum("bnr,rhd->bnhd", c, w_uk)
        pe_b = jnp.broadcast_to(pe[:, :, None, :], (*k_nope.shape[:3], pe.shape[-1]))
        k = jnp.concatenate([k_nope, pe_b], axis=-1)
        v = jnp.einsum("bnr,rhd->bnhd", c, w_uv)
        return k, v

    if (cfg.mode in (ExchangeMode.LOCAL, ExchangeMode.PRISM_SIM)
            or cfg.seq_axis is None or cfg.seq_shards == 1):
        # PRISM_SIM never uses real collectives; these paths have no
        # simulation analogue (unsharded cache / memory), so run exact
        k, v = expand(c_kv, k_pe)
        B_, Nq_, H_ = q.shape[0], q.shape[1], q.shape[2]
        if B_ * H_ * Nq_ * k.shape[1] * 4 > 0.5e9:
            from repro.core.prism_attention import chunked_reference_attention
            return chunked_reference_attention(q, k, v, causal=causal,
                                               scale=scale)
        return reference_attention(q, k, v, causal=causal, scale=scale)

    axis, Pn, L = cfg.seq_axis, cfg.seq_shards, cfg.L
    q = _pin_seq_sharding(q, axis)
    c_kv = _pin_seq_sharding(c_kv, axis)
    k_pe = _pin_seq_sharding(k_pe, axis)

    if cfg.mode == ExchangeMode.VOLTAGE:
        def volt(qs, cs, ps):
            p = jax.lax.axis_index(axis)
            Np = qs.shape[1]
            cg = all_gather_grad_safe(cs, axis, axis=1, tiled=True)
            pg = all_gather_grad_safe(ps, axis, axis=1, tiled=True)
            k, v = expand(cg, pg)   # full re-expansion on every device
            from repro.core.prism_attention import chunked_reference_attention
            return chunked_reference_attention(qs, k, v, causal=causal,
                                               q_offset=p * Np, scale=scale)
        bax = _manual_batch_axes(q.shape[0], cfg) or None
        manual = {axis} | set(bax or ())
        return compat.shard_map(
            volt, in_specs=(P(bax, axis, None, None), P(bax, axis, None),
                            P(bax, axis, None)),
            out_specs=P(bax, axis, None, None),
            axis_names=manual, check_vma=False)(q, c_kv, k_pe)

    def prism_mla(qs, cs, ps):
        p = jax.lax.axis_index(axis)
        Bl, Np = cs.shape[0], cs.shape[1]     # local (manual-region) shapes
        seg = Np // L
        cm = kdsp.segment_means(cs, L, axis=1)       # [Bl, L, r]
        pm = kdsp.segment_means(ps, L, axis=1)       # [Bl, L, dr]
        cm_all = jnp.moveaxis(all_gather_grad_safe(cm, axis), 0, 1)
        pm_all = jnp.moveaxis(all_gather_grad_safe(pm, axis), 0, 1)
        k_loc, v_loc = expand(cs, ps)
        km, vm = expand(cm_all.reshape(Bl, Pn * L, r),
                        pm_all.reshape(Bl, Pn * L, -1))
        km = km.reshape(Bl, Pn, L, H, dq)
        vm = vm.reshape(Bl, Pn, L, H, d_v)
        return kdsp.prism_attention(qs, k_loc, v_loc, km, vm, p, seg,
                                    causal=causal, scale=scale)
    bax = _manual_batch_axes(q.shape[0], cfg) or None
    manual = {axis} | set(bax or ())
    return compat.shard_map(
        prism_mla, in_specs=(P(bax, axis, None, None), P(bax, axis, None),
                             P(bax, axis, None)),
        out_specs=P(bax, axis, None, None),
        axis_names=manual, check_vma=False)(q, c_kv, k_pe)


def mla_decode_attention_sharded(
    q_lat: jnp.ndarray,    # [B, 1, H, r]  absorbed no-pe query
    q_pe: jnp.ndarray,     # [B, 1, H, dr] rotary query
    c_cache: jnp.ndarray,  # [B, S, r]     latent cache, S sharded over seq axis
    pe_cache: jnp.ndarray, # [B, S, dr]
    cache_len,             # scalar int32 — global valid prefix
    cfg: ExchangeConfig,
    *,
    scale: float,
) -> jnp.ndarray:
    """One-token absorbed MLA attention over a position-sharded latent cache.

    Exact flash-decoding merge: per-shard partial softmax in the latent space
    followed by a global LSE-weighted psum of [B, H, r]-sized partials.
    """
    def partial_attn(ql, qp, c, pe, off):
        # logits [B, H, 1, S]
        lg = (jnp.einsum("bqhr,bsr->bhqs", ql.astype(jnp.float32),
                         c.astype(jnp.float32))
              + jnp.einsum("bqhd,bsd->bhqs", qp.astype(jnp.float32),
                           pe.astype(jnp.float32))) * scale
        S = c.shape[1]
        gpos = off + jnp.arange(S)
        lg = jnp.where((gpos < cache_len)[None, None, None, :], lg, NEG_INF)
        return lg

    if (cfg.mode in (ExchangeMode.LOCAL, ExchangeMode.PRISM_SIM)
            or cfg.seq_axis is None or cfg.seq_shards == 1):
        # PRISM_SIM never uses real collectives; these paths have no
        # simulation analogue (unsharded cache / memory), so run exact
        lg = partial_attn(q_lat, q_pe, c_cache, pe_cache, 0)
        p = jax.nn.softmax(lg, axis=-1)
        o = jnp.einsum("bhqs,bsr->bqhr", p, c_cache.astype(jnp.float32))
        return o.astype(q_lat.dtype)

    axis = cfg.seq_axis

    def shard_fn(ql, qp, c, pe):
        i = jax.lax.axis_index(axis)
        Sp = c.shape[1]
        lg = partial_attn(ql, qp, c, pe, i * Sp)
        m_p = jnp.max(lg, axis=-1, keepdims=True)
        m_g = jax.lax.pmax(m_p, axis)
        w = jnp.exp(lg - m_g)
        l_p = jnp.sum(w, axis=-1)                                  # [B,H,1]
        o_p = jnp.einsum("bhqs,bsr->bqhr", w, c.astype(jnp.float32))
        l_g = jax.lax.psum(l_p, axis)
        o_g = jax.lax.psum(o_p, axis)
        return (o_g / l_g.transpose(0, 2, 1)[..., None]).astype(ql.dtype)

    return compat.shard_map(
        shard_fn,
        in_specs=(P(None, None, None, None), P(None, None, None, None),
                  P(None, axis, None), P(None, axis, None)),
        out_specs=P(None, None, None, None),
        axis_names={axis}, check_vma=False)(q_lat, q_pe, c_cache, pe_cache)


# ---------------------------------------------------------------------------
# Decode-time attention over a sequence-sharded KV cache
# ---------------------------------------------------------------------------

def decode_attention_sharded(
    q: jnp.ndarray,        # [B, 1, H, dh] — replicated over seq axis
    k_cache: jnp.ndarray,  # [B, S, Hk, dh] — S sharded over seq axis
    v_cache: jnp.ndarray,  # [B, S, Hk, dh]
    cache_len,             # [B] or scalar — valid prefix length (global)
    cfg: ExchangeConfig,
    *,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,           # sliding-window validity
    k_means: Optional[jnp.ndarray] = None,  # [B, P, L, Hk, dh] PRISM-decode
    v_means: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """One-token attention against a position-sharded cache.

    VOLTAGE/exact: per-shard partial softmax + global LSE merge (one psum of
    [B, H, dh]-sized partials — tiny; this is the flash-decoding scheme).
    PRISM-decode (beyond-paper): each shard holds locally-refreshed segment
    means of *remote* shards, so no collective is needed on the seq axis.
    """
    def _valid(gpos, clen):
        ok = gpos[None, :] < jnp.reshape(clen, (-1, 1))
        if window is not None:
            ok &= gpos[None, :] >= jnp.reshape(clen, (-1, 1)) - window
        return ok

    if (cfg.mode in (ExchangeMode.LOCAL, ExchangeMode.PRISM_SIM)
            or cfg.seq_axis is None or cfg.seq_shards == 1):
        # PRISM_SIM never uses real collectives; these paths have no
        # simulation analogue (unsharded cache / memory), so run exact.
        # Routed through the kernel-dispatch layer: the flash-decode Pallas
        # kernel when the backend supports it, masked reference otherwise.
        return kdsp.decode_attention(q, k_cache, v_cache, cache_len,
                                     window=window,
                                     logit_softcap=logit_softcap,
                                     scale=scale)

    axis = cfg.seq_axis
    Pn = cfg.seq_shards
    use_prism = cfg.mode == ExchangeMode.PRISM and k_means is not None

    def shard_fn(qs, ks, vs, clen, km, vm):
        p = jax.lax.axis_index(axis)
        B, Sp, Hk, dh = ks.shape
        H = qs.shape[2]
        scl = (dh ** -0.5) if scale is None else scale
        f32 = jnp.float32
        # local logits (grouped-GQA, bf16 operands, f32 accumulation),
        # masked by global validity of each cache slot
        logits = _grouped_scores(qs, ks) * scl
        logits = _softcap(logits, logit_softcap)
        gpos = p * Sp + jnp.arange(Sp)
        valid = _valid(gpos, clen)
        logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)

        if use_prism:
            # attend additionally to locally stored means of remote shards
            km_f = km.reshape(B, -1, Hk, dh)
            vm_f = vm.reshape(B, -1, Hk, dh)
            Lm = km.shape[2]
            seg = jnp.maximum(Sp // max(Lm, 1), 1)
            mlog = _grouped_scores(qs, km_f) * scl
            mlog = _softcap(mlog, logit_softcap) + jnp.log(
                jnp.asarray(seg, f32))
            owner = jnp.repeat(jnp.arange(Pn), Lm)
            mlog = jnp.where((owner != p)[None, None, None, :], mlog, NEG_INF)
            logits = jnp.concatenate([logits, mlog], axis=-1)
            # no collective: summaries already local
            m = jnp.max(logits, axis=-1, keepdims=True)
            w = jnp.exp(logits - m)
            o = (_grouped_values(w[..., :Sp], vs)
                 + _grouped_values(w[..., Sp:], vm_f))
            denom = jnp.sum(w, axis=-1).transpose(0, 2, 1)[..., None]
            return (o / denom).astype(qs.dtype)

        # exact flash-decoding merge across shards
        m_p = jnp.max(logits, axis=-1, keepdims=True)          # [B,H,1,1]
        m_g = jax.lax.pmax(m_p, axis)
        w = jnp.exp(logits - m_g)
        l_p = jnp.sum(w, axis=-1)                              # [B,H,1]
        o_p = _grouped_values(w, vs)                           # [B,1,H,dh]
        l_g = jax.lax.psum(l_p, axis)
        o_g = jax.lax.psum(o_p, axis)
        denom = l_g.transpose(0, 2, 1)[..., None]
        return (o_g / denom).astype(qs.dtype)

    bax = _manual_batch_axes(q.shape[0], cfg) or None
    manual = {axis} | set(bax or ())
    cache_spec = P(bax, axis, None, None)
    q_spec = P(bax, None, None, None)
    mean_spec = P(bax, None, None, None, None)
    clen = jnp.atleast_1d(cache_len)
    clen_spec = P(bax) if (bax and clen.shape[0] == q.shape[0]) else P(None)
    in_specs = (q_spec, cache_spec, cache_spec, clen_spec,
                mean_spec, mean_spec)
    if not use_prism:
        B0 = q.shape[0]
        k_means = (jnp.zeros((B0, Pn, 1, k_cache.shape[2], k_cache.shape[3]),
                             q.dtype) if k_means is None else k_means)
        v_means = (jnp.zeros((B0, Pn, 1, k_cache.shape[2], k_cache.shape[3]),
                             q.dtype) if v_means is None else v_means)
    out = compat.shard_map(shard_fn, in_specs=in_specs, out_specs=q_spec,
                        axis_names=manual, check_vma=False)(
        q, k_cache, v_cache, clen, k_means, v_means)
    return out
