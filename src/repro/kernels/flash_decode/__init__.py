from repro.kernels.flash_decode.ops import flash_decode_op
from repro.kernels.flash_decode.paged import (flash_decode_paged_op,
                                              flash_decode_paged_ref,
                                              gather_pages)
from repro.kernels.flash_decode.ref import flash_decode_ref

__all__ = ["flash_decode_op", "flash_decode_ref", "flash_decode_paged_op",
           "flash_decode_paged_ref", "gather_pages"]
