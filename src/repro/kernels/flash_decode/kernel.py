"""Pallas-TPU flash-decode: one-token partial attention over a
sequence-sharded KV-cache shard, emitting (o·l, m, l) for the cross-shard
LSE merge (one tiny psum — ``repro.core.exchange.decode_attention_sharded``).

Tiling: grid (B, H, S/TS). The S axis is the *minor-most sequential* grid
dim, so the (m, l, acc) online-softmax state lives in VMEM scratch across
S-blocks of the same (b, h) — the cache streams HBM→VMEM once, q stays
resident. Validity/window masking arrives as an additive bias [B, S]
(computed outside from cache_len — keeps the kernel branch-free).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref,
            acc_ref, mm_ref, ll_ref, *, scale: float,
            softcap: Optional[float], n_s_blocks: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        mm_ref[...] = jnp.full_like(mm_ref, NEG_INF)
        ll_ref[...] = jnp.zeros_like(ll_ref)

    q = q_ref[0, 0, :].astype(jnp.float32) * scale          # [dh]
    k = k_ref[0, :, 0, :].astype(jnp.float32)               # [TS, dh]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    bias = bias_ref[0, :].astype(jnp.float32)               # [TS]

    s = k @ q                                               # [TS]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = s + bias
    m_prev = mm_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                  # [TS]
    ll_ref[0] = ll_ref[0] * alpha + jnp.sum(p)
    acc_ref[0, :] = acc_ref[0, :] * alpha + p @ v
    mm_ref[0] = m_new

    @pl.when(si == n_s_blocks - 1)
    def _flush():
        o_ref[0, 0, :] = acc_ref[0, :].astype(o_ref.dtype)
        m_ref[0, 0] = mm_ref[0]
        l_ref[0, 0] = ll_ref[0]


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "s_block",
                                             "interpret"))
def flash_decode_pallas(q: jnp.ndarray,       # [B, H, dh]
                        k: jnp.ndarray,       # [B, S, Hk, dh]
                        v: jnp.ndarray,
                        kv_bias: jnp.ndarray,  # [B, S] f32
                        *, scale: Optional[float] = None,
                        softcap: Optional[float] = None,
                        s_block: int = 512,
                        interpret: bool = False):
    B, H, dh = q.shape
    S, Hk = k.shape[1], k.shape[2]
    scale = (dh ** -0.5) if scale is None else scale
    group = H // Hk
    ts = min(s_block, S)
    assert S % ts == 0, (S, ts)
    grid = (B, H, S // ts)

    out_shapes = (jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
                  jax.ShapeDtypeStruct((B, H), jnp.float32),
                  jax.ShapeDtypeStruct((B, H), jnp.float32))
    o, m, l = pl.pallas_call(
        functools.partial(_kernel, scale=scale, softcap=softcap,
                          n_s_blocks=S // ts),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda b, h, s: (b, h, 0)),
            pl.BlockSpec((1, ts, 1, dh), lambda b, h, s: (b, s, h // group, 0)),
            pl.BlockSpec((1, ts, 1, dh), lambda b, h, s: (b, s, h // group, 0)),
            pl.BlockSpec((1, ts), lambda b, h, s: (b, s)),
        ],
        out_specs=(pl.BlockSpec((1, 1, dh), lambda b, h, s: (b, h, 0)),
                   pl.BlockSpec((1, 1), lambda b, h, s: (b, h)),
                   pl.BlockSpec((1, 1), lambda b, h, s: (b, h))),
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((1, dh), jnp.float32),   # acc
                        pltpu.VMEM((1,), jnp.float32),      # m
                        pltpu.VMEM((1,), jnp.float32)],     # l
        interpret=interpret,
    )(q, k, v, kv_bias)
    return o, m, l
