"""Paged flash-decode: one-token attention gathered through a page table.

The KV cache lives in a shared pool of fixed-size pages
(``[n_pages, page_size, Hk, dh]``); each request owns a row of a
``[B, max_pages]`` int32 page table mapping its logical block ``p`` to a
physical page id.  The reference path materializes the gather with
``jnp.take``; the Pallas path never materializes it — the page table rides
in as a scalar-prefetch operand and the K/V block index maps read
``pt[b, p]`` directly, so each (b, h, p) grid step streams exactly one
physical page HBM→VMEM.  Grid (B, H, max_pages) with the page axis
minor-most sequential, so the online-softmax state in VMEM scratch is the
*same* ``_kernel`` body the dense flash-decode uses.

Validity masking arrives as an additive bias [B, max_pages·page_size]
built by ``ops.validity_bias`` — the ONE definition of cache validity,
shared with the dense op.  Free/overhanging table entries may point at a
trash page; the bias masks those positions so their values never count.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_decode.kernel import _kernel
from repro.kernels.flash_decode.ref import flash_decode_ref


def gather_pages(pool: jnp.ndarray,         # [P, ps, Hk, dh]
                 page_table: jnp.ndarray    # [B, MP] int32
                 ) -> jnp.ndarray:          # [B, MP*ps, Hk, dh]
    """Materialize a per-request contiguous KV view from the page pool."""
    B, MP = page_table.shape
    ps = pool.shape[1]
    return jnp.take(pool, page_table, axis=0).reshape(
        B, MP * ps, *pool.shape[2:])


def flash_decode_paged_ref(q: jnp.ndarray,           # [B, H, dh]
                           k_pool: jnp.ndarray,      # [P, ps, Hk, dh]
                           v_pool: jnp.ndarray,
                           page_table: jnp.ndarray,  # [B, MP] int32
                           kv_bias: jnp.ndarray,     # [B, MP*ps] f32
                           *, scale: Optional[float] = None,
                           softcap: Optional[float] = None
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """``jnp.take`` gather + the dense reference math → (o·l, m, l)."""
    k = gather_pages(k_pool, page_table)
    v = gather_pages(v_pool, page_table)
    return flash_decode_ref(q, k, v, kv_bias, scale=scale, softcap=softcap)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def flash_decode_paged_pallas(q: jnp.ndarray,           # [B, H, dh]
                              k_pool: jnp.ndarray,      # [P, ps, Hk, dh]
                              v_pool: jnp.ndarray,
                              page_table: jnp.ndarray,  # [B, MP] int32
                              kv_bias: jnp.ndarray,     # [B, MP*ps] f32
                              *, scale: Optional[float] = None,
                              softcap: Optional[float] = None,
                              interpret: bool = False):
    """Pallas paged flash-decode → (o·l, m, l) partials.

    The page table is the first operand (scalar prefetch), available to the
    K/V BlockSpec index maps: logical block ``p`` of row ``b`` resolves to
    physical page ``pt[b, p]`` of the pool, block shape (1, ps, 1, dh).
    """
    B, H, dh = q.shape
    ps, Hk = k_pool.shape[1], k_pool.shape[2]
    MP = page_table.shape[1]
    scale = (dh ** -0.5) if scale is None else scale
    group = H // Hk
    grid = (B, H, MP)

    def _paged_kernel(pt_ref, q_ref, k_ref, v_ref, bias_ref,
                      o_ref, m_ref, l_ref, acc_ref, mm_ref, ll_ref):
        del pt_ref  # consumed by the index maps
        _kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref,
                acc_ref, mm_ref, ll_ref, scale=scale, softcap=softcap,
                n_s_blocks=MP)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda b, h, p, pt: (b, h, 0)),
            pl.BlockSpec((1, ps, 1, dh),
                         lambda b, h, p, pt: (pt[b, p], 0, h // group, 0)),
            pl.BlockSpec((1, ps, 1, dh),
                         lambda b, h, p, pt: (pt[b, p], 0, h // group, 0)),
            pl.BlockSpec((1, ps), lambda b, h, p, pt: (b, p)),
        ],
        out_specs=(pl.BlockSpec((1, 1, dh), lambda b, h, p, pt: (b, h, 0)),
                   pl.BlockSpec((1, 1), lambda b, h, p, pt: (b, h)),
                   pl.BlockSpec((1, 1), lambda b, h, p, pt: (b, h))),
        scratch_shapes=[pltpu.VMEM((1, dh), jnp.float32),   # acc
                        pltpu.VMEM((1,), jnp.float32),      # m
                        pltpu.VMEM((1,), jnp.float32)],     # l
    )
    out_shapes = (jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
                  jax.ShapeDtypeStruct((B, H), jnp.float32),
                  jax.ShapeDtypeStruct((B, H), jnp.float32))
    o, m, l = pl.pallas_call(
        _paged_kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )(page_table.astype(jnp.int32), q, k_pool, v_pool, kv_bias)
    return o, m, l


def flash_decode_paged_op(q: jnp.ndarray,           # [B, 1, H, dh] / [B,H,dh]
                          k_pool: jnp.ndarray,      # [P, ps, Hk, dh]
                          v_pool: jnp.ndarray,
                          page_table: jnp.ndarray,  # [B, MP] int32
                          cache_len,                # [B] valid prefix length
                          *, scale: Optional[float] = None,
                          softcap: Optional[float] = None,
                          interpret: Optional[bool] = None
                          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Bias construction + Pallas paged kernel → (o·l, m, l) partials."""
    from repro.kernels.flash_decode.ops import _on_cpu, validity_bias
    interpret = _on_cpu() if interpret is None else interpret
    if q.ndim == 4:
        q = q[:, 0]
    B = q.shape[0]
    ps, MP = k_pool.shape[1], page_table.shape[1]
    bias = validity_bias(B, MP * ps, cache_len)
    return flash_decode_paged_pallas(q, k_pool, v_pool, page_table, bias,
                                     scale=scale, softcap=softcap,
                                     interpret=interpret)
