"""Pure-jnp oracle for the flash-decode partial-attention kernel."""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

NEG_INF = -1e30


def flash_decode_ref(q: jnp.ndarray,        # [B, H, dh]
                     k: jnp.ndarray,        # [B, S, Hk, dh] (local shard)
                     v: jnp.ndarray,
                     kv_bias: jnp.ndarray,  # [B, S] additive (0 / -inf)
                     *, scale: Optional[float] = None,
                     softcap: Optional[float] = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Partial attention over the local cache shard.

    Returns (o·l, m, l) — un-normalized weighted values plus the softmax
    stats, so shards merge exactly:  o = Σ e^{m_i - m*} o_i / Σ e^{m_i-m*} l_i.
    """
    B, H, dh = q.shape
    Hk = k.shape[2]
    scale = (dh ** -0.5) if scale is None else scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if Hk != H:
        kf = jnp.repeat(kf, H // Hk, axis=2)
        vf = jnp.repeat(vf, H // Hk, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32) * scale, kf)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = s + kv_bias[:, None, :]
    m = jnp.max(s, axis=-1)                                  # [B, H]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                                  # [B, H]
    o = jnp.einsum("bhs,bshd->bhd", p, vf)                   # un-normalized
    return o, m, l
