"""jit'd wrapper: builds the validity bias from (cache_len, offset, window)
and merges shard partials (the exact LSE combine used across devices)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.kernel import NEG_INF, flash_decode_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.lru_cache(maxsize=None)
def pick_s_block(S: int) -> int:
    """Largest power-of-two tile (≤512) dividing ``S``.  Cached per S — the
    divisor search used to rerun on every trace of ``flash_decode_op``, and
    the paged op shares the same selection for its page-size tiles."""
    if S % 512 == 0:
        return 512
    return max(t for t in (256, 128, 64, 32, 16, 8, 4, 2, 1) if S % t == 0)


def validity_mask(B: int, S: int, cache_len, offset=0,
                  window: Optional[int] = None) -> jnp.ndarray:
    """[B, S] bool: True where the (global) position is a valid cache slot
    and inside the sliding window.  The ONE definition of cache validity —
    the kernel bias and the reference fallback both derive from it."""
    gpos = offset + jnp.arange(S)[None, :]
    clen = jnp.broadcast_to(jnp.reshape(jnp.asarray(cache_len), (-1, 1)),
                            (B, 1))
    ok = gpos < clen
    if window is not None:
        ok &= gpos >= clen - window
    return ok


def validity_bias(B: int, S: int, cache_len, offset=0,
                  window: Optional[int] = None) -> jnp.ndarray:
    """[B, S] additive bias: 0 where valid, -inf where empty / outside the
    sliding window."""
    ok = validity_mask(B, S, cache_len, offset=offset, window=window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def flash_decode_op(q: jnp.ndarray,      # [B, 1, H, dh] or [B, H, dh]
                    k: jnp.ndarray,      # [B, S, Hk, dh]
                    v: jnp.ndarray,
                    cache_len,
                    *, offset=0, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    softcap: Optional[float] = None,
                    interpret: Optional[bool] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Partial attention over the local shard → (o_unnorm, m, l)."""
    interpret = _on_cpu() if interpret is None else interpret
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    B, H, dh = q.shape
    S = k.shape[1]
    bias = validity_bias(B, S, cache_len, offset=offset, window=window)
    return flash_decode_pallas(q, k, v, bias, scale=scale, softcap=softcap,
                               s_block=pick_s_block(S), interpret=interpret)


def merge_partials(o, m, l) -> jnp.ndarray:
    """Combine [n_shards, B, H, dh] partials exactly (flash-decoding)."""
    m_star = jnp.max(m, axis=0)                              # [B, H]
    w = jnp.exp(m - m_star[None])
    l_tot = jnp.sum(w * l, axis=0)
    o_tot = jnp.sum(w[..., None] * o, axis=0)
    return o_tot / l_tot[..., None]
