"""Pallas-TPU PRISM attention: flash-style softmax over [local K/V ‖
segment-mean K/V with additive log-count bias].

TPU adaptation of the paper's scaling-aware softmax (DESIGN.md §2): the
GPU prototype materializes the concatenated score matrix; here the two key
groups are processed as separate MXU tiles with one running (m, l, acc)
online-softmax state, so the augmented representation never exists in HBM
— the means ride along as one extra K-block.

Tiling: grid (B, H, Nq/TQ). Per program:
  q tile      [TQ, dh]           VMEM
  local K/V   [Nk, dh]           VMEM (per-partition Nk = N/P is small by
                                 construction — PRISM's partitioning is what
                                 makes full-KV residency viable; a streamed
                                 variant would kick in above ~8k tokens)
  mean K/V    [M, dh] + bias [M] VMEM (M = P·L)
MXU work: [TQ, dh]·[dh, Nk] and [TQ, dh]·[dh, M]; TQ, Nk, M padded to 128.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, km_ref, vm_ref, bias_ref, o_ref, *,
            scale: float, causal: bool, q_block: int,
            softcap: Optional[float]):
    qi = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # [TQ, dh]
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # [Nk, dh]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    km = km_ref[0, :, 0, :].astype(jnp.float32)            # [M, dh]
    vm = vm_ref[0, :, 0, :].astype(jnp.float32)
    bias = bias_ref[0, :].astype(jnp.float32)              # [M]

    def cap(x):
        return x if softcap is None else softcap * jnp.tanh(x / softcap)

    s_loc = cap(q @ k.T)                                   # [TQ, Nk]
    if causal:
        qpos = qi * q_block + jax.lax.broadcasted_iota(
            jnp.int32, s_loc.shape, 0)
        kpos = jax.lax.broadcasted_iota(jnp.int32, s_loc.shape, 1)
        s_loc = jnp.where(qpos >= kpos, s_loc, NEG_INF)

    s_mean = cap(q @ km.T) + bias[None, :]                 # [TQ, M]

    # one online-softmax state across both key groups
    m1 = jnp.max(s_loc, axis=-1)
    m2 = jnp.max(s_mean, axis=-1)
    m = jnp.maximum(jnp.maximum(m1, m2), -1e29)
    p_loc = jnp.exp(s_loc - m[:, None])
    p_mean = jnp.exp(s_mean - m[:, None])
    l = jnp.sum(p_loc, axis=-1) + jnp.sum(p_mean, axis=-1)
    acc = p_loc @ v + p_mean @ vm                          # [TQ, dh]
    o_ref[0, :, 0, :] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "softcap", "q_block",
                              "interpret"))
def prism_attention_pallas(
    q: jnp.ndarray,        # [B, Nq, H, dh]
    k_loc: jnp.ndarray,    # [B, Nk, Hk, dh]
    v_loc: jnp.ndarray,
    k_means: jnp.ndarray,  # [B, M, Hk, dh]
    v_means: jnp.ndarray,
    mean_bias: jnp.ndarray,   # [B, M] f32
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    softcap: Optional[float] = None,
    q_block: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Nq, H, dh = q.shape
    Hk = k_loc.shape[2]
    Nk, M = k_loc.shape[1], k_means.shape[1]
    scale = (dh ** -0.5) if scale is None else scale
    group = H // Hk
    tq = min(q_block, Nq)
    assert Nq % tq == 0, (Nq, tq)
    grid = (B, H, Nq // tq)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, q_block=tq,
                          softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, 1, dh), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, Nk, 1, dh), lambda b, h, i: (b, 0, h // group, 0)),
            pl.BlockSpec((1, Nk, 1, dh), lambda b, h, i: (b, 0, h // group, 0)),
            pl.BlockSpec((1, M, 1, dh), lambda b, h, i: (b, 0, h // group, 0)),
            pl.BlockSpec((1, M, 1, dh), lambda b, h, i: (b, 0, h // group, 0)),
            pl.BlockSpec((1, M), lambda b, h, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, 1, dh), lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Nq, H, dh), q.dtype),
        interpret=interpret,
    )(q, k_loc, v_loc, k_means, v_means, mean_bias)
