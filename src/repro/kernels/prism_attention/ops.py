"""jit'd wrapper for the PRISM attention kernel.

Builds the mean-bias vector from (part_idx, counts, visibility) — the same
semantics as ``repro.core.prism_attention.prism_attention`` — pads Nq to the
q-block, and interprets on CPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.prism_attention.kernel import (NEG_INF,
                                                  prism_attention_pallas)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def build_mean_bias(B: int, P: int, L: int, part_idx, seg_size: int,
                    *, causal: bool,
                    mean_counts: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """[B, P·L] additive bias: log(count) for visible means, -inf else."""
    part_of_mean = jnp.repeat(jnp.arange(P), L)            # [P*L]
    if causal:
        visible = part_of_mean < part_idx
    else:
        visible = part_of_mean != part_idx
    if mean_counts is None:
        counts = jnp.full((B, P * L), float(seg_size), jnp.float32)
    else:
        counts = mean_counts.reshape(B, P * L).astype(jnp.float32)
        visible = visible[None, :] & (counts > 0)
    bias = jnp.log(jnp.maximum(counts, 1.0))
    vis = visible if visible.ndim == 2 else visible[None, :]
    return jnp.where(vis, bias, NEG_INF)


def prism_attention_op(
    q: jnp.ndarray,            # [B, Nq, H, dh]
    k_loc: jnp.ndarray,
    v_loc: jnp.ndarray,
    k_means: jnp.ndarray,      # [B, P, L, Hk, dh]
    v_means: jnp.ndarray,
    part_idx,
    seg_size: int,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    softcap: Optional[float] = None,
    mean_counts: Optional[jnp.ndarray] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    interpret = _on_cpu() if interpret is None else interpret
    B, Nq, H, dh = q.shape
    P, L = k_means.shape[1], k_means.shape[2]
    km = k_means.reshape(B, P * L, *k_means.shape[3:])
    vm = v_means.reshape(B, P * L, *v_means.shape[3:])
    bias = build_mean_bias(B, P, L, part_idx, seg_size, causal=causal,
                           mean_counts=mean_counts)
    q_block = 128 if Nq % 128 == 0 else (
        max(t for t in (64, 32, 16, 8, 4, 2, 1) if Nq % t == 0))
    return prism_attention_pallas(
        q, k_loc, v_loc, km, vm, bias, causal=causal, scale=scale,
        softcap=softcap, q_block=q_block, interpret=interpret)
