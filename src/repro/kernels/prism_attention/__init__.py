from repro.kernels.prism_attention.ops import prism_attention_op
from repro.kernels.prism_attention.ref import prism_attention_ref

__all__ = ["prism_attention_op", "prism_attention_ref"]
