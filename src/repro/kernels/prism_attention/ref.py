"""Pure-jnp oracle for the PRISM attention kernel (device-local view).

Mirrors ``repro.core.prism_attention.prism_attention`` with the means
pre-flattened to [B, M, Hk, dh] and their visibility/scaling folded into an
additive bias [B, M] (log segment count; -inf to hide own/future
partitions) — exactly the contract the Pallas kernel implements.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _expand(kv: jnp.ndarray, H: int) -> jnp.ndarray:
    hk = kv.shape[-2]
    return kv if hk == H else jnp.repeat(kv, H // hk, axis=-2)


def prism_attention_ref(
    q: jnp.ndarray,        # [B, Nq, H, dh]
    k_loc: jnp.ndarray,    # [B, Nk, Hk, dh]
    v_loc: jnp.ndarray,
    k_means: jnp.ndarray,  # [B, M, Hk, dh]
    v_means: jnp.ndarray,
    mean_bias: jnp.ndarray,  # [B, M] additive (log counts / -inf)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    logit_softcap: Optional[float] = None,
) -> jnp.ndarray:
    B, Nq, H, dh = q.shape
    scale = (dh ** -0.5) if scale is None else scale
    f32 = jnp.float32
    kl = _expand(k_loc, H).astype(f32)
    vl = _expand(v_loc, H).astype(f32)
    km = _expand(k_means, H).astype(f32)
    vm = _expand(v_means, H).astype(f32)

    def cap(x):
        if logit_softcap is None:
            return x
        return logit_softcap * jnp.tanh(x / logit_softcap)

    l_loc = cap(jnp.einsum("bqhd,bkhd->bhqk", q.astype(f32), kl) * scale)
    if causal:
        Nk = k_loc.shape[1]
        mask = jnp.arange(Nq)[:, None] >= jnp.arange(Nk)[None, :]
        l_loc = jnp.where(mask[None, None], l_loc, NEG_INF)
    l_mean = cap(jnp.einsum("bqhd,bkhd->bhqk", q.astype(f32), km) * scale)
    l_mean = l_mean + mean_bias[:, None, None, :]
    logits = jnp.concatenate([l_loc, l_mean], axis=-1)
    p = jax.nn.softmax(logits, axis=-1)
    vals = jnp.concatenate([vl, vm], axis=1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vals)
    return out.astype(q.dtype)
