# Pallas kernels for the paper's compute hot-spots + the dispatch layer
# that routes the runtime's hot paths onto them (reference jnp fallback;
# REPRO_KERNEL_BACKEND env / set_backend() override).
from repro.kernels.dispatch import (backend_info, force_backend,
                                    resolve_backend, set_backend)

__all__ = ["set_backend", "force_backend", "resolve_backend", "backend_info"]
