"""jit'd wrapper: shape plumbing + CPU interpret fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.segment_means.kernel import segment_means_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def segment_means_op(x: jnp.ndarray, L: int,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Segment means over the token axis of [B, N, ...feature...].

    Flattens trailing feature dims, pads the feature dim to a 128 lane
    multiple, runs the kernel (interpret=True on CPU), and restores shape.
    """
    interpret = _on_cpu() if interpret is None else interpret
    B, N = x.shape[:2]
    feat = x.shape[2:]
    D = 1
    for f in feat:
        D *= int(f)
    xf = x.reshape(B, N, D)
    pad = (-D) % 128
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, 0), (0, pad)))
    block_d = 512 if (D + pad) % 512 == 0 else 128
    out = segment_means_pallas(xf, L, block_d=block_d, interpret=interpret)
    if pad:
        out = out[..., :D]
    return out.reshape(B, L, *feat)
