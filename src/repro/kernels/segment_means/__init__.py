from repro.kernels.segment_means.ops import segment_means_op
from repro.kernels.segment_means.ref import segment_means_ref

__all__ = ["segment_means_op", "segment_means_ref"]
