"""Pallas-TPU segment-means reduction (PRISM Eq. 1).

Tiling: grid (B, L, D/TD); each program reduces one [seg, TD] tile of one
segment in VMEM (f32 accumulation on the VPU) and writes a [1, TD] row.
``TD`` is lane-aligned (multiple of 128); ``seg`` rides the sublane dim.
The compute is a pure reduction — the kernel's value is avoiding an HBM
round-trip of the [B, L, seg, D] reshape view the jnp path materializes
inside fusions, and fusing the mean with the (1/seg) scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    x = x_ref[0].astype(jnp.float32)          # [seg, TD]
    o_ref[0, 0, :] = (jnp.sum(x, axis=0) / x.shape[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("L", "block_d", "interpret"))
def segment_means_pallas(x: jnp.ndarray, L: int, *, block_d: int = 512,
                         interpret: bool = False) -> jnp.ndarray:
    """[B, N, D] → [B, L, D]; requires N % L == 0 and D % block_d == 0
    (callers pad D to a lane multiple; ops.py picks block_d)."""
    B, N, D = x.shape
    seg = N // L
    td = min(block_d, D)
    assert D % td == 0, (D, td)
    grid = (B, L, D // td)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, seg, td), lambda b, l, d: (b, l, d))],
        out_specs=pl.BlockSpec((1, 1, td), lambda b, l, d: (b, l, d)),
        out_shape=jax.ShapeDtypeStruct((B, L, D), x.dtype),
        interpret=interpret,
    )(x)
