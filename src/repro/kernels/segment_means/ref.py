"""Pure-jnp oracle for the segment-means kernel."""
from __future__ import annotations

import jax.numpy as jnp


def segment_means_ref(x: jnp.ndarray, L: int) -> jnp.ndarray:
    """[B, N, D] → [B, L, D] column-wise means of L equal segments (f32
    accumulation, cast back to x.dtype) — PRISM Eq. (1)."""
    B, N, D = x.shape
    seg = N // L
    xr = x.reshape(B, L, seg, D).astype(jnp.float32)
    return xr.mean(axis=2).astype(x.dtype)
