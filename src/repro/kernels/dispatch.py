"""Kernel-dispatch layer: route hot ops to the Pallas kernels or the jnp
reference, per backend.

The compression and decode hot paths (``repro.core.exchange``,
``repro.models.layers``) call these wrappers instead of binding either
implementation directly.  Resolution order, first match wins:

1. ``set_backend("pallas" | "reference" | "auto")`` — process-global
   override (returns the previous value; also usable as a context manager
   via ``force_backend``).
2. ``REPRO_KERNEL_BACKEND`` environment variable (same values).
3. ``"auto"`` — Pallas on TPU, reference elsewhere.  On CPU the kernels
   only run under ``interpret=True`` (correct but slow), so auto never
   selects them there; parity tests opt in explicitly.

Every op degrades gracefully: shapes/arguments the kernel does not support
(non-token segment axes, masked local keys in PRISM attention) silently use
the reference path, so callers never need to special-case the backend.
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import prism_attention as ref_attn
from repro.core import segment_means as ref_sm

_VALID = ("auto", "pallas", "reference")
_OVERRIDE: Optional[str] = None
ENV_VAR = "REPRO_KERNEL_BACKEND"


def set_backend(name: Optional[str]) -> Optional[str]:
    """Set the process-global backend override; returns the previous one.
    ``None`` clears the override (environment / auto resolution applies)."""
    global _OVERRIDE
    if name is not None and name not in _VALID:
        raise ValueError(f"unknown kernel backend {name!r}; one of {_VALID}")
    prev, _OVERRIDE = _OVERRIDE, name
    return prev


@contextlib.contextmanager
def force_backend(name: str):
    """Temporarily force a backend (parity tests, benchmarks)."""
    prev = set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


def resolve_backend() -> str:
    """The backend that would execute right now: "pallas" or "reference"."""
    choice = _OVERRIDE or os.environ.get(ENV_VAR, "auto")
    if choice not in _VALID:
        raise ValueError(f"{ENV_VAR}={choice!r} invalid; one of {_VALID}")
    if choice == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    return choice


def _use_pallas() -> bool:
    return resolve_backend() == "pallas"


def _interpret() -> bool:
    """Pallas kernels interpret everywhere but real TPU backends."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Segment Means (PRISM Eq. 1) — compression hot path
# ---------------------------------------------------------------------------

def segment_means(x: jnp.ndarray, L: int, axis: int = -2) -> jnp.ndarray:
    """Column-wise means of L equal segments along ``axis``.

    Kernel path: token axis 1 of a [B, N, ...feature] tensor (the layout of
    every exchange call site); anything else falls back to the reference.
    """
    axis = axis % x.ndim
    if (_use_pallas() and axis == 1 and x.ndim >= 3
            and L > 0 and x.shape[1] % L == 0):
        from repro.kernels.segment_means.ops import segment_means_op
        return segment_means_op(x, L, interpret=_interpret())
    return ref_sm.segment_means(x, L, axis=axis)


def segment_means_masked(x: jnp.ndarray, L: int, mask: jnp.ndarray,
                         axis: int = -2
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mask-aware segment means → (means, counts); see the reference for
    semantics.  The kernel has no mask input, but masked means factor into
    an unmasked segment-sum (the kernel) and a cheap [B, N] count
    reduction:  mean = (seg · kernel_mean(x·mask)) / max(count, 1).
    """
    axis = axis % x.ndim
    if (_use_pallas() and axis == 1 and x.ndim >= 3
            and L > 0 and x.shape[1] % L == 0 and mask.ndim == 2):
        from repro.kernels.segment_means.ops import segment_means_op
        B, N = x.shape[:2]
        seg = N // L
        mf = mask.astype(jnp.float32)
        counts = mf.reshape(B, L, seg).sum(axis=-1)               # [B, L]
        mx = x.astype(jnp.float32) * mf.reshape(
            (B, N) + (1,) * (x.ndim - 2))
        sums = segment_means_op(mx, L, interpret=_interpret()) * float(seg)
        denom = jnp.maximum(counts, 1.0).reshape(
            (B, L) + (1,) * (x.ndim - 2))
        return (sums / denom).astype(x.dtype), counts
    return ref_sm.segment_means_masked(x, L, mask, axis=axis)


# ---------------------------------------------------------------------------
# One-token decode attention — the generation hot path
# ---------------------------------------------------------------------------

def decode_attention(q: jnp.ndarray,        # [B, 1, H, dh]
                     k_cache: jnp.ndarray,  # [B, S, Hk, dh]
                     v_cache: jnp.ndarray,
                     cache_len,             # [B] or scalar — valid prefix
                     *,
                     offset: int = 0,
                     window: Optional[int] = None,
                     logit_softcap: Optional[float] = None,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token attention against a (device-local) KV cache, masked to
    the valid ``cache_len`` prefix (optionally sliding-``window``-limited).

    Pallas path: the flash-decode kernel's (o·l, m, l) partials, normalized
    locally (the single-shard degenerate of the cross-shard LSE merge).
    """
    if _use_pallas():
        from repro.kernels.flash_decode.ops import flash_decode_op
        o, m, l = flash_decode_op(q, k_cache, v_cache, cache_len,
                                  offset=offset, window=window, scale=scale,
                                  softcap=logit_softcap,
                                  interpret=_interpret())
        out = o / jnp.maximum(l, 1e-38)[..., None]                # [B, H, dh]
        return out[:, None].astype(q.dtype)                       # [B,1,H,dh]
    from repro.kernels.flash_decode.ops import validity_mask
    valid = validity_mask(q.shape[0], k_cache.shape[1], cache_len,
                          offset=offset, window=window)
    return ref_attn.reference_attention(
        q, k_cache, v_cache, kv_mask=valid,
        logit_softcap=logit_softcap, scale=scale)


def decode_attention_paged(q: jnp.ndarray,           # [B, 1, H, dh]
                           k_pool: jnp.ndarray,      # [P, ps, Hk, dh]
                           v_pool: jnp.ndarray,
                           page_table: jnp.ndarray,  # [B, max_pages] int32
                           cache_len,                # [B] — valid prefix
                           *,
                           logit_softcap: Optional[float] = None,
                           scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token attention against a *paged* KV pool: each request's
    cache is the concatenation of the pool pages named by its page-table
    row, masked to the valid ``cache_len`` prefix.

    Reference path: materialize the gather with ``jnp.take`` and run the
    exact dense reference (CPU/interpret parity oracle).  Pallas path: the
    paged flash-decode kernel indexes pool pages through the scalar-
    prefetched table — no gather is ever materialized.
    """
    if _use_pallas():
        from repro.kernels.flash_decode.paged import flash_decode_paged_op
        o, m, l = flash_decode_paged_op(q, k_pool, v_pool, page_table,
                                        cache_len, scale=scale,
                                        softcap=logit_softcap,
                                        interpret=_interpret())
        out = o / jnp.maximum(l, 1e-38)[..., None]                # [B, H, dh]
        return out[:, None].astype(q.dtype)                       # [B,1,H,dh]
    from repro.kernels.flash_decode.ops import validity_mask
    from repro.kernels.flash_decode.paged import gather_pages
    k = gather_pages(k_pool, page_table)
    v = gather_pages(v_pool, page_table)
    valid = validity_mask(q.shape[0], k.shape[1], cache_len)
    return ref_attn.reference_attention(
        q, k, v, kv_mask=valid, logit_softcap=logit_softcap, scale=scale)


# ---------------------------------------------------------------------------
# PRISM prefill attention (scaling-aware softmax over local ‖ remote means)
# ---------------------------------------------------------------------------

def prism_attention(q, k_local, v_local, k_means, v_means, part_idx,
                    seg_size: int, *, causal: bool = False,
                    logit_softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    kv_mask: Optional[jnp.ndarray] = None,
                    mean_counts: Optional[jnp.ndarray] = None,
                    q_offset=0) -> jnp.ndarray:
    """Scaling-aware softmax attention (see ``repro.core.prism_attention``).

    The kernel supports unpadded local keys and a static q-offset of 0; the
    padded / chunk-recursed cases use the reference.
    """
    if (_use_pallas() and kv_mask is None
            and isinstance(q_offset, int) and q_offset == 0):
        from repro.kernels.prism_attention.ops import prism_attention_op
        return prism_attention_op(
            q, k_local, v_local, k_means, v_means, part_idx, seg_size,
            causal=causal, scale=scale, softcap=logit_softcap,
            mean_counts=mean_counts, interpret=_interpret())
    return ref_attn.prism_attention(
        q, k_local, v_local, k_means, v_means, part_idx, seg_size,
        causal=causal, logit_softcap=logit_softcap, scale=scale,
        kv_mask=kv_mask, mean_counts=mean_counts, q_offset=q_offset)


def backend_info() -> dict:
    """What would run right now (benchmarks / docs / bug reports)."""
    return {"resolved": resolve_backend(),
            "override": _OVERRIDE,
            "env": os.environ.get(ENV_VAR),
            "jax_backend": jax.default_backend(),
            "interpret": _interpret()}
