"""Sharded, atomic, rotating checkpointing (orbax-free, numpy .npz shards).

Design for the multi-pod deployment:
* every host writes only the shards it owns (`process_index` prefix) — at
  512 chips that is 64 hosts × their addressable shards, no host ever holds
  the full state;
* a manifest (JSON) records the pytree structure, global shapes and the
  sharding spec, so restore can re-shard onto a *different* mesh (elastic
  restart after losing a pod — runtime/elastic.py);
* writes go to ``<dir>.tmp`` then ``os.replace`` → atomic even on kill -9;
* ``save_async`` hands the host-transfer off to a thread so the train loop
  overlaps the next step with the write (double-buffered);
* rotation keeps the newest ``keep`` checkpoints.

On this single-process container the host owns every shard; the layout and
code paths are identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize bfloat16/fp8 — stored as a same-width integer view
# with the true dtype recorded in the manifest.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_storable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _VIEW_AS:
        return arr.view(_VIEW_AS[name]), name
    return arr, name


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_AS:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flat_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def save_pytree(tree, directory: str, step: int,
                process_index: Optional[int] = None) -> str:
    """Write one checkpoint atomically; returns the final path."""
    pidx = jax.process_index() if process_index is None else process_index
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp{pidx}"
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flat_with_paths(tree)
    arrays: Dict[str, np.ndarray] = {}
    manifest = {"step": step, "leaves": {}}
    for key, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        stored, dtype_name = _to_storable(arr)
        arrays[key.replace("/", "__")] = stored
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": dtype_name}
    np.savez(os.path.join(tmp, f"shards_{pidx:05d}.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, final)          # atomic publish
    return final


def load_pytree(template, directory: str, step: Optional[int] = None,
                shardings=None):
    """Restore into the structure of ``template`` (re-sharding if given)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    stored: Dict[str, np.ndarray] = {}
    for fn in sorted(os.listdir(path)):
        if fn.startswith("shards_") and fn.endswith(".npz"):
            with np.load(os.path.join(path, fn)) as z:
                for k in z.files:
                    key = k.replace("__", "/")
                    dtype_name = manifest["leaves"].get(key, {}).get(
                        "dtype", str(z[k].dtype))
                    stored[key] = _from_storable(z[k], dtype_name)
    flat, treedef = _flat_with_paths(template)
    leaves = []
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    for (key, leaf), shd in zip(flat, shard_flat):
        if key not in stored:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = stored[key]
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and "tmp" not in d]
    return max(steps) if steps else None


class CheckpointManager:
    """Rotation + async writes + restore-or-init."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def save(self, tree, step: int) -> str:
        path = save_pytree(tree, self.dir, step)
        self._rotate()
        return path

    def save_async(self, tree, step: int) -> None:
        """Device→host copy happens now; disk write on a worker thread."""
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda l: np.asarray(jax.device_get(l)), tree)
        self._pending = threading.Thread(
            target=lambda: (save_pytree(host_tree, self.dir, step),
                            self._rotate()))
        self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore(self, template, shardings=None, step: Optional[int] = None):
        return load_pytree(template, self.dir, step, shardings)

    def restore_or_none(self, template, shardings=None):
        try:
            return self.restore(template, shardings)
        except (FileNotFoundError, KeyError):
            return None

    def _rotate(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and "tmp" not in d)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    @property
    def latest(self) -> Optional[int]:
        return latest_step(self.dir)
