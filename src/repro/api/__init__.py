"""`repro.api` — the unified adaptive-inference surface.

One import for the paper's whole runtime loop:

* :class:`ExecutionPlan` — mode + CR/L + sequence-partition layout; converts
  to/from ``PerfKey`` and ``ExchangeConfig`` and replaces ad-hoc
  ``"mode@cr"`` strings.
* :class:`ExchangeStrategy` / :func:`register_strategy` — pluggable exchange
  registry (local / voltage / prism / prism_sim; open to new strategies).
* :class:`InferenceSession` — owns params, per-plan executables, bandwidth
  observation, profiling, policy, dispatch, generation, and closed-loop
  recalibration (``profile() / dispatch() / generate() / explain() /
  calibrate()``).

The profiling subsystem (``repro.profiling``: backend registry, hardware
profiles, objective classes, the compiled ``PolicyTable``) and the policy
primitives are re-exported so downstream code needs only ``repro.api``.
"""
from repro.api.plan import ExecutionPlan
from repro.api.session import (CalibrationReport, DispatchRecord,
                               Explanation, InferenceSession)
from repro.api.strategies import (ExchangeStrategy, get_strategy,
                                  list_strategies, register_strategy)
from repro.core.exchange import ExchangeConfig, ExchangeMode
from repro.core.perfmap import PerfEntry, PerfKey, PerfMap
from repro.core.policy import (AdaptivePolicy, BatchPlan, Decision,
                               EnergyObjective, LatencyObjective, Objective,
                               ObjectiveLike, PolicyTable, SLOObjective,
                               WeightedObjective, resolve_objective)
from repro.core.profiler import (PAPER_BATCHES, PAPER_BWS, PAPER_CRS,
                                 SweepSpec, profile_measured,
                                 profile_simulated, sweep_cost)
from repro.profiling import (JETSON_ORIN_NANO, TPU_ICI, TPU_V5E, WIFI_GLOO,
                             HardwareProfile, LinkProfile, ProfileBackend,
                             ProfileContext, get_backend, list_backends,
                             register_backend, workload_from_config)
from repro.transport import (CodecSpec, ExchangeCodec, LinkCost,
                             TransportLink, exchange_cost, get_codec,
                             get_link, list_codecs, list_links,
                             plan_wire_bytes, register_codec, register_link)

__all__ = [
    "ExecutionPlan", "InferenceSession", "DispatchRecord", "Explanation",
    "CalibrationReport",
    "ExchangeStrategy", "register_strategy", "get_strategy",
    "list_strategies",
    "ExchangeConfig", "ExchangeMode",
    "PerfKey", "PerfEntry", "PerfMap",
    "AdaptivePolicy", "Decision", "PolicyTable", "BatchPlan",
    "Objective", "ObjectiveLike", "LatencyObjective", "EnergyObjective",
    "WeightedObjective", "SLOObjective", "resolve_objective",
    "ProfileBackend", "ProfileContext", "register_backend", "get_backend",
    "list_backends",
    "HardwareProfile", "LinkProfile",
    "JETSON_ORIN_NANO", "WIFI_GLOO", "TPU_V5E", "TPU_ICI",
    "workload_from_config",
    "profile_simulated", "profile_measured", "SweepSpec", "sweep_cost",
    "PAPER_BATCHES", "PAPER_CRS", "PAPER_BWS",
    "ExchangeCodec", "CodecSpec", "register_codec", "get_codec",
    "list_codecs",
    "TransportLink", "LinkCost", "register_link", "get_link", "list_links",
    "exchange_cost", "plan_wire_bytes",
]
