"""`repro.api` — the unified adaptive-inference surface.

One import for the paper's whole runtime loop:

* :class:`ExecutionPlan` — mode + CR/L + sequence-partition layout; converts
  to/from ``PerfKey`` and ``ExchangeConfig`` and replaces ad-hoc
  ``"mode@cr"`` strings.
* :class:`ExchangeStrategy` / :func:`register_strategy` — pluggable exchange
  registry (local / voltage / prism / prism_sim; open to new strategies).
* :class:`InferenceSession` — owns params, per-plan executables, bandwidth
  observation, profiling, policy, dispatch, and generation
  (``profile() / dispatch() / generate() / explain()``).

The profiling/policy primitives (``PerfMap``, ``AdaptivePolicy``, sweep
helpers) are re-exported so downstream code needs only ``repro.api``.
"""
from repro.api.plan import ExecutionPlan
from repro.api.session import (DispatchRecord, Explanation, InferenceSession)
from repro.api.strategies import (ExchangeStrategy, get_strategy,
                                  list_strategies, register_strategy)
from repro.core.exchange import ExchangeConfig, ExchangeMode
from repro.core.perfmap import PerfEntry, PerfKey, PerfMap
from repro.core.policy import AdaptivePolicy, Decision, Objective
from repro.core.profiler import (PAPER_BATCHES, PAPER_BWS, PAPER_CRS,
                                 SweepSpec, profile_measured,
                                 profile_simulated, sweep_cost)

__all__ = [
    "ExecutionPlan", "InferenceSession", "DispatchRecord", "Explanation",
    "ExchangeStrategy", "register_strategy", "get_strategy",
    "list_strategies",
    "ExchangeConfig", "ExchangeMode",
    "PerfKey", "PerfEntry", "PerfMap",
    "AdaptivePolicy", "Decision", "Objective",
    "profile_simulated", "profile_measured", "SweepSpec", "sweep_cost",
    "PAPER_BATCHES", "PAPER_CRS", "PAPER_BWS",
]
