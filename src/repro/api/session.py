"""`InferenceSession` — the one supported way to run the adaptive runtime.

Owns the model params, one jitted executable per `ExecutionPlan`, the
bandwidth observer (EWMA probe), the profiled performance map, and the
adaptive policy — the paper's whole Fig. 1 loop behind a single object::

    session = InferenceSession.from_config(
        "vit-base-16",
        plans=[ExecutionPlan.local(),
               ExecutionPlan.prism_sim(L=20, cr=4.95)])
    session.profile(backend="simulated")       # offline sweep → perf map
    session.observe_bandwidth(400.0)
    out = session.dispatch({"images": imgs})   # policy-routed execution
    print(session.explain(batch=8, bandwidth_mbps=400.0).summary())
    session.calibrate()                        # fold observed walls back in

Profiling goes through the pluggable backend registry
(``repro.profiling``): ``backend="simulated"`` (cost model),
``"measured"`` (times this session's own registered plan executables),
``"trace"`` (replay a saved map).  Objectives accept the legacy
``"latency"``/``"energy"`` strings or any
:class:`~repro.profiling.objectives.Objective` instance.

Subsumes the legacy ``AdaptiveDispatcher`` + ``ServeEngine`` pair (both
now removed from ``repro.serving``; request traffic lives in
``repro.serving.ServingRuntime``).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.api.plan import ExecutionPlan
from repro.core.perfmap import PerfEntry, PerfKey, PerfMap
from repro.core.policy import (AdaptivePolicy, Decision, Objective,
                               ObjectiveLike, resolve_objective)
from repro.obs import MetricsRegistry
from repro.utils.bandwidth import BandwidthEstimator


@dataclasses.dataclass
class DispatchRecord:
    """One routed batch: what the policy decided and what actually ran."""
    batch: int
    bandwidth_mbps: float
    decision: Optional[Decision]   # None when rebuilt from a trace
    wall_ms: float
    exec_key: str = ""          # executable that actually ran
    substituted: bool = False   # True when the decided key had no executable
    extrapolated: bool = False  # batch was outside the profiled grid
    codec: str = ""             # exchange codec that ran ("" = no exchange)
    wire_bytes: int = 0         # modeled bytes-on-wire this dispatch moved


def from_trace(spans) -> List[DispatchRecord]:
    """Rebuild :class:`DispatchRecord` rows from ``dispatch`` spans, so a
    span file (or a live tracer buffer) can feed
    ``session.calibrate(records=from_trace(spans))`` — the trace becomes
    the recalibration stream the ROADMAP's drift item consumes."""
    out: List[DispatchRecord] = []
    for sp in spans:
        if sp.name != "dispatch" or sp.kind != "session" or sp.open:
            continue
        a = sp.attrs
        if "exec_key" not in a or "batch" not in a:
            continue
        out.append(DispatchRecord(
            batch=int(a["batch"]),
            bandwidth_mbps=float(a.get("bandwidth_mbps", 0.0)),
            decision=None, wall_ms=sp.duration_ms,
            exec_key=str(a["exec_key"]),
            substituted=bool(a.get("substituted", False)),
            extrapolated=bool(a.get("extrapolated", False)),
            codec=str(a.get("codec", "")),
            wire_bytes=int(a.get("wire_bytes", 0))))
    return out


@dataclasses.dataclass
class CalibrationReport:
    """What one ``session.calibrate()`` pass did to the performance map."""
    updated: int = 0                 # entries EWMA-folded
    skipped_extrapolated: int = 0    # out-of-grid batches (never folded)
    skipped_offgrid: int = 0         # in-range batches between grid points
    skipped_unprofiled: int = 0      # ran an executable with no map entry
    records: int = 0                 # dispatch records consumed
    bandwidth_updates: int = 0       # bytes/wall EWMA folds into the link
                                     # bandwidth estimate

    def __bool__(self) -> bool:
        return self.updated > 0


@dataclasses.dataclass(frozen=True)
class Explanation:
    """Why a (batch, bandwidth) pair routes the way it does — the paper's
    reported artifacts derived from the live policy."""
    batch: int
    bandwidth_mbps: float
    decision: Decision
    plan_key: str                                   # executable id chosen
    candidates: Tuple[Tuple[PerfKey, PerfEntry], ...]
    batch_crossover: Optional[int]                  # paper: 8 @ 400 Mbps
    bandwidth_crossover: Optional[float]            # paper: ≈340 Mbps @ B=8
    extrapolated: bool = False                      # batch off the grid
    codec: str = ""                                 # exchange codec chosen
    wire_bytes: int = 0                             # modeled bytes-on-wire

    def summary(self) -> str:
        lines = [f"B={self.batch} BW={self.bandwidth_mbps:g} Mbps → "
                 f"{self.decision.mode}"
                 + (f" CR={self.decision.cr:g}" if self.decision.cr else "")
                 + (f" codec={self.codec}" if self.codec else "")
                 + f"  ({self.decision.expected.per_sample_ms:.1f} ms/sample"
                 f" expected, plan {self.plan_key!r}"
                 + (f", {self.wire_bytes / 1e6:.2f} MB on wire"
                    if self.wire_bytes else "") + ")"
                 + (" [EXTRAPOLATED: batch outside the profiled grid]"
                    if self.extrapolated else "")]
        for k, e in sorted(self.candidates,
                           key=lambda kv: kv[1].per_sample_ms):
            mark = "→" if (k.mode, k.cr, k.codec) == (
                self.decision.mode, self.decision.cr,
                self.decision.codec) else " "
            label = f"{k.mode}+{k.codec}" if k.codec else k.mode
            lines.append(f"  {mark} {label:<13} CR={k.cr:<5g} "
                         f"{e.per_sample_ms:8.1f} ms/sample "
                         f"{e.per_sample_j:7.2f} J/sample")
        lines.append(f"  batch crossover @ {self.bandwidth_mbps:g} Mbps: "
                     f"{self.batch_crossover} (paper: 8)")
        lines.append(f"  bandwidth crossover @ B={self.batch}: "
                     f"{self.bandwidth_crossover} Mbps (paper: ≈340)")
        return "\n".join(lines)


class InferenceSession:
    """Facade over params + per-plan executables + profiling + policy."""

    def __init__(self, cfg, params, plans: Sequence[ExecutionPlan] = (),
                 perfmap: Optional[PerfMap] = None,
                 objective: ObjectiveLike = "latency",
                 allow_modes: Optional[Tuple[str, ...]] = None,
                 bandwidth_alpha: float = 0.3,
                 initial_bandwidth_mbps: float = 400.0,
                 temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.plans: Dict[str, ExecutionPlan] = {}
        self._execs: Dict[str, Any] = {}
        # plan → {(B, T0, n_new, T, prefill_mode): compiled generate fn}
        self._decode_execs: Dict[Any, Dict] = {}
        self.objective: Objective = resolve_objective(objective)
        self.temperature = temperature
        self._allow = allow_modes
        self._policy: Optional[AdaptivePolicy] = None
        # observability: the session owns a registry (link-bandwidth
        # provenance gauges land here); a tracer is attached opt-in
        self.metrics = MetricsRegistry()
        self.tracer = None
        self._bwest = BandwidthEstimator(initial_bandwidth_mbps,
                                         bandwidth_alpha,
                                         metrics=self.metrics)
        # plan → {(kind, *shape): compiled slot-pool executable}
        self._serve_execs: Dict[Any, Dict] = {}
        self._admit_fn = None
        self._paged_admit_fn = None
        self._paged_hit_fn = None
        self.history: List[DispatchRecord] = []
        self._calibrated_upto = 0
        self.perfmap = perfmap
        for p in (plans or [ExecutionPlan.local()]):
            self.add_plan(p)

    @classmethod
    def from_config(cls, arch: str, plans: Sequence[ExecutionPlan] = (),
                    *, perfmap: Optional[PerfMap] = None, reduced=True,
                    seed: int = 0, params=None, **kw) -> "InferenceSession":
        """Build from an architecture id (e.g. "vit-base-16", "llama3.2-1b").

        ``reduced``: True → CPU smoke-test variant; a dict → kwargs for
        ``cfg.reduced(**reduced)``; False → full-size config.
        """
        from repro.configs import get_config
        from repro.models import registry
        cfg = get_config(arch)
        if reduced:
            cfg = cfg.reduced(**(reduced if isinstance(reduced, dict) else {}))
        if params is None:
            params = registry.init_params(cfg, seed=seed)
        return cls(cfg, params, plans, perfmap=perfmap, **kw)

    # -- plans & executables -------------------------------------------------

    def add_plan(self, plan: ExecutionPlan) -> str:
        """Register a plan and jit its forward executable; returns its key."""
        import jax
        from repro.api.strategies import get_strategy
        from repro.models import registry
        key = plan.key
        if key in self.plans:
            raise ValueError(f"plan {key!r} already registered")
        if (get_strategy(plan.mode).requires_L and plan.L <= 0
                and not plan.codec):
            # a cr-only plan (e.g. from parse()/from_perf_key without
            # n_tokens) has no physical segment count to execute with;
            # non-default codecs carry their own parameters instead of L
            raise ValueError(
                f"plan {key!r} has cr={plan.cr:g} but no physical L; call "
                "plan.resolve_L(n_tokens) before registering it")
        fwd = registry.forward_fn(self.cfg)
        xcfg = plan.to_exchange_config()
        self.plans[key] = plan
        self._execs[key] = jax.jit(
            lambda batch: fwd(self.params, batch, xcfg)[0])
        return key

    def run(self, plan_key: str, batch_inputs: Any):
        """Run one specific plan's executable (no policy involved)."""
        if plan_key not in self._execs:
            raise KeyError(f"no executable for plan {plan_key!r}; "
                           f"registered: {sorted(self._execs)}")
        return self._execs[plan_key](batch_inputs)

    # -- profiling -----------------------------------------------------------

    def profile_context(self, *, hardware=None, link=None, workload=None,
                        cost_model=None, seq_len: int = 0):
        """This session's view for a profiling backend: config, params, and
        the registered plan executables (what ``measured`` actually times)."""
        from repro.profiling.backends import ProfileContext
        ctx = ProfileContext(cfg=self.cfg, params=self.params,
                             plans=dict(self.plans),
                             execs=dict(self._execs),
                             workload=workload, cost_model=cost_model,
                             seq_len=seq_len)
        if hardware is not None:
            ctx.hardware = hardware
        if link is not None:
            ctx.link = link
        return ctx

    def profile(self, spec=None, *, backend: Optional[str] = None,
                hardware=None, link=None, workload=None, seq_len: int = 0,
                measured: bool = False, model=None,
                save_path: Optional[str] = None, **backend_opts) -> PerfMap:
        """Offline sweep (paper §3.3) through a registered profiling backend
        → performance map, installed on the session (and optionally saved as
        the on-device JSON artifact).

        ``backend`` names a ``repro.profiling`` backend (default
        ``"simulated"``); extra keyword arguments are forwarded to it (e.g.
        ``path=`` for ``"trace"``, ``iters=`` for ``"measured"``).
        ``hardware``/``link`` select the profiled hardware description
        (embedded in the map, schema v2).
        """
        from repro.profiling import SweepSpec, get_backend
        if measured:
            warnings.warn("profile(measured=True) is deprecated; use "
                          "profile(backend='measured')", DeprecationWarning,
                          stacklevel=2)
            backend = backend or "measured"
        if model is not None and backend in (None, "simulated"):
            backend_opts.setdefault("model", model)
        ctx = self.profile_context(hardware=hardware, link=link,
                                   workload=workload, seq_len=seq_len)
        pm = get_backend(backend or "simulated").profile(
            ctx, spec or SweepSpec(), **backend_opts)
        self.set_perfmap(pm)
        if save_path:
            pm.save(save_path)
        return pm

    def set_perfmap(self, pm: PerfMap) -> None:
        self.perfmap = pm
        self._policy = None            # rebuilt lazily against the new map

    @property
    def policy(self) -> AdaptivePolicy:
        if self.perfmap is None:
            raise RuntimeError("no performance map: call session.profile() "
                               "or pass perfmap= / set_perfmap() first")
        if self._policy is None:
            self._policy = (AdaptivePolicy(self.perfmap, self._allow)
                            if self._allow else AdaptivePolicy(self.perfmap))
        return self._policy

    # -- bandwidth observation ----------------------------------------------

    def observe_bandwidth(self, mbps: float) -> None:
        """EWMA bandwidth probe update (the caller measures the link)."""
        self._bwest.observe(mbps)

    @property
    def bandwidth(self) -> float:
        return self._bwest.mbps

    # `_bw` predates BandwidthEstimator; tests pin the EWMA state through it
    @property
    def _bw(self) -> float:
        return self._bwest.mbps

    @_bw.setter
    def _bw(self, mbps: float) -> None:
        self._bwest.reset(mbps)

    @property
    def _alpha(self) -> float:
        return self._bwest.alpha

    # -- adaptive dispatch ---------------------------------------------------

    def decide(self, batch: int, bandwidth_mbps: Optional[float] = None,
               objective: Optional[ObjectiveLike] = None) -> Decision:
        return self.policy.decide(batch,
                                  self._bw if bandwidth_mbps is None
                                  else bandwidth_mbps,
                                  objective or self.objective)

    def plan_for_key(self, exec_key: str) -> Tuple[str, ExecutionPlan]:
        """Executable id → registered plan, with the canonical fallback
        order: exact key, then a same-mode+codec plan at another CR, then
        any same-mode plan, then any registered plan (used by dispatch and
        the serving runtime)."""
        from repro.api.plan import split_key
        if exec_key in self.plans:
            return exec_key, self.plans[exec_key]
        mode, _, codec = split_key(exec_key)
        for match in (lambda k: split_key(k)[::2] == (mode, codec),
                      lambda k: split_key(k)[0] == mode):
            found = next((k for k in self.plans if match(k)), None)
            if found is not None:
                return found, self.plans[found]
        if not self.plans:
            raise LookupError("no executables registered")
        key = next(iter(self.plans))
        return key, self.plans[key]

    def _exec_key_for(self, d: Decision) -> Tuple[str, bool]:
        """Decision → registered executable key + whether a fallback plan
        was substituted for the decided one."""
        key, _ = self.plan_for_key(d.exec_key)
        return key, key != d.exec_key

    def _input_tokens(self, batch_inputs: Any) -> int:
        """Token count of one request batch: dim 1 of the token input (or
        of a rank-2 array); 0 → the accounting falls back to the profiled
        workload's sequence length (images etc. have no token dim)."""
        lead = batch_inputs
        if isinstance(batch_inputs, dict):
            if "tokens" not in batch_inputs:
                return 0
            lead = batch_inputs["tokens"]
        shape = getattr(lead, "shape", ())
        return int(shape[1]) if len(shape) == 2 else 0

    def dispatch(self, batch_inputs: Any,
                 batch_size: Optional[int] = None) -> Any:
        """Route one batch per the profiled policy and run it."""
        import jax
        from repro.transport import plan_wire_bytes
        if batch_size is None:
            batch_size = int(next(iter(batch_inputs.values())).shape[0]
                             if isinstance(batch_inputs, dict)
                             else batch_inputs.shape[0])
        d = self.decide(batch_size)
        key, substituted = self._exec_key_for(d)
        plan = self.plans[key]
        t0 = time.perf_counter()
        out = self._execs[key](batch_inputs)
        # wall_ms must cover execution, not just the async dispatch —
        # otherwise PerfMap-vs-observed comparisons flatter the runtime
        jax.tree_util.tree_map(
            lambda a: a.block_until_ready()
            if hasattr(a, "block_until_ready") else a, out)
        wall = (time.perf_counter() - t0) * 1e3
        wire = plan_wire_bytes(plan, self.cfg, batch_size,
                               self._input_tokens(batch_inputs))
        codec = plan.effective_codec if wire else ""
        self.history.append(DispatchRecord(
            batch_size, self._bw, d, wall, exec_key=key,
            substituted=substituted, extrapolated=d.extrapolated,
            codec=codec, wire_bytes=wire))
        self.metrics.histogram("session.dispatch_ms").observe(wall)
        if self.tracer is not None:
            self._trace_dispatch(d, key, batch_size, wall, wire, codec,
                                 substituted)
        return out

    def _trace_dispatch(self, d: Decision, key: str, batch: int,
                        wall_ms: float, wire: int, codec: str,
                        substituted: bool) -> None:
        """Record one closed ``dispatch`` span (carrying everything
        :func:`from_trace` needs to rebuild a :class:`DispatchRecord`) plus
        the decision's *modeled* staging/wire children — per-stage link
        costs with ``modeled`` provenance, distinguishable from measured
        spans by the ``modeled=True`` attr."""
        tr = self.tracer
        end = tr.clock()
        start = end - wall_ms / 1e3
        sp = tr.record("dispatch", start=start, end=end, kind="session",
                       batch=batch, exec_key=key, codec=codec,
                       wire_bytes=wire, bandwidth_mbps=self._bw,
                       extrapolated=d.extrapolated, substituted=substituted)
        exp = d.expected
        if exp is not None and wire:
            t = start
            for name, ms in (("staging", exp.staging_ms),
                             ("wire", exp.comm_ms)):
                if ms and ms > 0:
                    tr.record(name, start=t, end=t + ms / 1e3,
                              kind="transport", trace_id=sp.trace_id,
                              parent_id=sp.span_id, modeled=True)
                    t += ms / 1e3

    # -- closed-loop recalibration -------------------------------------------

    def calibrate(self, alpha: float = 0.3,
                  records: Optional[Sequence[DispatchRecord]] = None
                  ) -> CalibrationReport:
        """Fold observed dispatch wall times back into the performance map
        (EWMA per profiled entry) so the profile tracks runtime drift.

        ``records`` overrides the consumption of ``self.history``: pass
        ``from_trace(spans)`` to calibrate from a span stream (live tracer
        or a reloaded ``--trace`` JSONL file) instead of this session's own
        dispatch history; the history cursor is left untouched.

        Each uncalibrated :class:`DispatchRecord` whose batch size sits
        **exactly on the profiled grid** updates the entry of the executable
        that **actually ran** (``exec_key``, so substituted dispatches
        inform the right plan) at the nearest profiled bandwidth:
        ``total_ms ← (1-α)·total_ms + α·wall_ms``, with the latency
        decomposition and energy rescaled proportionally (the map receives a
        fresh entry — past ``Decision.expected`` references keep the values
        the policy actually predicted).  Off-grid batches — extrapolated or
        between grid points — are skipped: a B=24 wall must not corrupt the
        B=32 cell it would snap to.  Compiled policy tables are invalidated
        when anything changed.  Callers should warm executables up first
        (the first dispatch per shape pays jit compilation).
        """
        if self.perfmap is None:
            raise RuntimeError("no performance map to calibrate: call "
                               "session.profile() first")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        from repro.api.plan import split_key
        rep = CalibrationReport()
        table = self.policy.table(self.objective)
        own_history = records is None
        if own_history:
            records = self.history[self._calibrated_upto:]
        for rec in records:
            rep.records += 1
            if rec.extrapolated:
                rep.skipped_extrapolated += 1
                continue
            if table.nearest_batch(rec.batch) != rec.batch:
                rep.skipped_offgrid += 1
                continue
            mode, cr, codec = split_key(rec.exec_key)
            if mode == "local":
                key = PerfKey("local", rec.batch, 0.0, 0.0)
            else:
                bw = table.nearest_bandwidth(rec.bandwidth_mbps)
                if bw is None:
                    rep.skipped_unprofiled += 1
                    continue
                key = PerfKey(mode, rec.batch, cr, bw, codec)
            entry = self.perfmap.get(key)
            if entry is None and codec and mode != "local":
                # codec plans register at cr=0 but the sweep keys them at
                # the achieved ratio — fold into the unique profiled cell
                # with the same (mode, batch, bandwidth, codec)
                matches = [(k2, e2) for k2, e2 in self.perfmap.entries()
                           if (k2.mode, k2.batch, k2.codec,
                               k2.bandwidth_mbps) == (mode, rec.batch,
                                                      codec, bw)]
                if len(matches) == 1:
                    key, entry = matches[0]
            if entry is None or entry.total_ms <= 0:
                rep.skipped_unprofiled += 1
                continue
            # bytes-on-wire refine the LINK estimate, not just the map:
            # the entry's profiled comm share apportions the observed wall
            # to wire time, and bytes/wall EWMA-folds into the bandwidth
            # probe the policy queries
            if rec.wire_bytes > 0 and entry.comm_ms > 0:
                comm_wall = rec.wall_ms * entry.comm_ms / entry.total_ms
                if comm_wall > 0:
                    self._bwest.observe_transfer(rec.wire_bytes, comm_wall)
                    rep.bandwidth_updates += 1
            new_total = (1 - alpha) * entry.total_ms + alpha * rec.wall_ms
            f = new_total / entry.total_ms
            self.perfmap.put(key, dataclasses.replace(
                entry, total_ms=new_total,
                per_sample_ms=new_total / rec.batch,
                compute_ms=entry.compute_ms * f,
                staging_ms=entry.staging_ms * f,
                comm_ms=entry.comm_ms * f,
                per_sample_j=entry.per_sample_j * f,
                meta=dict(entry.meta,
                          calibrations=entry.meta.get("calibrations", 0) + 1)))
            rep.updated += 1
        if own_history:
            self._calibrated_upto = len(self.history)
        if rep.updated:
            self._policy = None        # recompile tables against new costs
        return rep

    # -- generation (subsumes ServeEngine) -----------------------------------

    def generate(self, prompt_tokens, n_new: int,
                 plan: Optional[ExecutionPlan] = None,
                 batch_extras: Optional[Dict[str, Any]] = None,
                 seed: int = 0, temperature: Optional[float] = None,
                 prefill_mode: str = "auto"):
        """Greedy/temperature generation: prompt [B, T0] → [B, n_new].

        Compiled fast path: single-pass prefill (or a teacher-forced
        ``lax.scan`` fallback — see ``repro.api.generation``) plus one
        scanned decode loop with on-device sampling, all inside ONE jitted
        executable — a constant number of dispatches regardless of prompt
        length and token count.  Executables are cached per
        (plan, shape, temperature); ``plan`` defaults to the local plan
        (or the first registered one).
        """
        from repro.api import generation as gen
        from repro.obs import maybe_span
        plan = self._plan_or_default(plan)
        T = self.temperature if temperature is None else temperature
        # cache by the full plan, not plan.key: distinct plans (e.g. two
        # prism_sim L values) can share a key but need distinct executables
        with maybe_span(self.tracer, "generate", kind="session",
                        plan=plan.key, n_new=n_new):
            return gen.generate(self.params, prompt_tokens, n_new, self.cfg,
                                plan.to_exchange_config(),
                                batch_extras=batch_extras, seed=seed,
                                temperature=T, prefill_mode=prefill_mode,
                                _cache=self._decode_execs.setdefault(plan,
                                                                     {}))

    # -- slot-pool serving primitives (used by repro.serving) ----------------

    def _plan_or_default(self, plan: Optional[ExecutionPlan]) -> ExecutionPlan:
        return (plan or self.plans.get("local")
                or next(iter(self.plans.values())))

    def _serve_exec(self, plan: ExecutionPlan, key: Tuple, build):
        fns = self._serve_execs.setdefault(plan, {})
        if key not in fns:
            fns[key] = build()
        return fns[key]

    def init_slot_pool(self, n_slots: int, max_len: int):
        """Pooled decode cache with one slot (batch row) per in-flight
        request — the state `prime_slot`/`decode_chunk` operate on."""
        from repro.api import generation as gen
        from repro.models import transformer as tfm
        if not gen.supports_slot_pool(self.cfg):
            raise NotImplementedError(
                f"family {self.cfg.family!r} cannot share a slot pool "
                f"(supported: {gen.SLOT_POOL_FAMILIES})")
        return tfm.init_decode_cache(self.cfg, n_slots, max_len)

    def prime_slot(self, prompt_tokens, *, total_len: int,
                   plan: Optional[ExecutionPlan] = None, seed: int = 0,
                   temperature: Optional[float] = None,
                   prefill_mode: str = "auto", with_logits: bool = False):
        """Prefill ONE request (prompt ``[1, T0]``) against a fresh cache of
        the pool's length → ``(tok0 [1,1], cache, key)`` — exactly the front
        half of :meth:`generate`, compiled per (plan, T0, total_len).
        ``with_logits=True`` appends the last-position logits (the paged
        prefix cache stores them for full-hit first-token sampling)."""
        import jax
        from repro.api import generation as gen
        if not gen.supports_slot_pool(self.cfg):
            raise NotImplementedError(
                f"family {self.cfg.family!r} cannot be slot-primed "
                f"(supported: {gen.SLOT_POOL_FAMILIES}); audio/vlm need "
                "per-request memory extras — use session.generate")
        plan = self._plan_or_default(plan)
        T = self.temperature if temperature is None else temperature
        B, T0 = prompt_tokens.shape
        # temperature is a traced argument, NOT part of the cache key —
        # per-request temperatures must not recompile the prefill
        fn = self._serve_exec(
            plan, ("prefill", B, T0, int(total_len), prefill_mode,
                   with_logits),
            lambda: gen.build_prefill_fn(self.cfg, plan.to_exchange_config(),
                                         total_len=total_len,
                                         prefill_mode=prefill_mode,
                                         with_logits=with_logits))
        return fn(self.params, prompt_tokens, {}, jax.random.key(seed),
                  float(T))

    def admit_slot(self, pool, tok, lengths, keys, temps, request_cache,
                   slot: int, tok0, length0: int, key0, temp0: float):
        """Fused admission (cache scatter + per-slot state updates) in one
        jitted executable → ``(pool, tok, lengths, keys, temps)``."""
        from repro.api import generation as gen
        if self._admit_fn is None:
            self._admit_fn = gen.build_admit_fn(self.cfg)
        return self._admit_fn(pool, tok, lengths, keys, temps,
                              request_cache, slot, tok0, length0, key0,
                              temp0)

    def decode_chunk(self, pool, tok, lengths, keys, temps, *,
                     n_steps: int, plan: Optional[ExecutionPlan] = None,
                     max_len: Optional[int] = None):
        """``n_steps`` continuous-batching decode steps over every slot →
        ``(tokens [S, n_steps], pool, lengths, keys)``; compiled once per
        (plan, slot-count, n_steps) and reused across admissions.
        ``temps [S]`` carries each slot's sampling temperature (≤0 =
        greedy), so requests with different temperatures share one pool."""
        from repro.api import generation as gen
        plan = self._plan_or_default(plan)
        fn = self._serve_exec(
            plan, ("chunk", int(tok.shape[0]), int(n_steps), max_len),
            lambda: gen.build_decode_chunk_fn(
                self.cfg, plan.to_exchange_config(), n_steps=n_steps,
                max_len=max_len))
        return fn(self.params, pool, tok, lengths, keys, temps)

    # -- paged-pool serving primitives (used by repro.serving.pages) ---------

    def init_page_pool(self, n_pages: int, page_size: int):
        """Shared paged KV pool (``[n_layers, n_pages, page_size, Hk, dh]``
        leaves) — the state the paged admission/decode executables operate
        on.  Raises for families without a paged decode path."""
        from repro.models import transformer as tfm
        return tfm.init_page_pool(self.cfg, n_pages, page_size)

    def admit_paged(self, pool, tok, lengths, keys, temps, request_cache,
                    page_ids, row: int, tok0, length0: int, key0,
                    temp0: float):
        """Fused paged admission: scatter a primed (page-aligned) request
        cache into pool pages ``page_ids`` + set the row state, in one
        jitted executable → ``(pool, tok, lengths, keys, temps)``."""
        from repro.api import generation as gen
        if self._paged_admit_fn is None:
            self._paged_admit_fn = gen.build_paged_admit_fn(self.cfg)
        return self._paged_admit_fn(pool, tok, lengths, keys, temps,
                                    request_cache, page_ids, row, tok0,
                                    length0, key0, temp0)

    def hit_paged(self, tok, lengths, keys, temps, row: int, logits,
                  length0: int, key0, temp0: float):
        """Full-prefix-hit admission: sample the first token from cached
        prefill logits with the request's own key + set the row state →
        ``(tok, lengths, keys, temps)`` (no prefill, no cache writes)."""
        from repro.api import generation as gen
        if self._paged_hit_fn is None:
            self._paged_hit_fn = gen.build_paged_hit_fn(self.cfg)
        return self._paged_hit_fn(tok, lengths, keys, temps, row, logits,
                                  length0, key0, temp0)

    def suffix_paged(self, pool, row_table, suffix, start_len, key0,
                     temp0: float, *, plan: Optional[ExecutionPlan] = None):
        """Partial-prefix-hit admission: teacher-force the ``suffix``
        [1, n] prompt tail through the paged pool from position
        ``start_len`` → ``(tok0 [1,1], pool, key', logits)``; compiled per
        (plan, n_suffix, max_pages)."""
        from repro.api import generation as gen
        plan = self._plan_or_default(plan)
        n = int(suffix.shape[1])
        fn = self._serve_exec(
            plan, ("paged_suffix", n, int(row_table.shape[1])),
            lambda: gen.build_paged_suffix_fn(
                self.cfg, plan.to_exchange_config(), n_suffix=n))
        return fn(self.params, pool, row_table, suffix, start_len, key0,
                  float(temp0))

    def paged_decode_chunk(self, pool, page_table, caps, tok, lengths, keys,
                           temps, *, n_steps: int,
                           plan: Optional[ExecutionPlan] = None):
        """``n_steps`` continuous-batching decode steps over every page-
        table row → ``(tokens [S, n_steps], pool, lengths, keys)``;
        compiled once per (plan, rows, max_pages, n_steps) and reused
        across admissions — page tables/caps/lengths are traced inputs."""
        from repro.api import generation as gen
        plan = self._plan_or_default(plan)
        fn = self._serve_exec(
            plan, ("paged_chunk", int(tok.shape[0]), int(n_steps),
                   int(page_table.shape[1])),
            lambda: gen.build_paged_decode_chunk_fn(
                self.cfg, plan.to_exchange_config(), n_steps=n_steps))
        return fn(self.params, pool, page_table, caps, tok, lengths, keys,
                  temps)

    # -- explanation (the paper's reported artifacts) ------------------------

    def explain(self, batch: int, bandwidth_mbps: Optional[float] = None,
                objective: Optional[ObjectiveLike] = None) -> Explanation:
        """Decision + candidate table + both crossover artifacts for one
        (batch, bandwidth) operating point."""
        from repro.core.policy import PolicyTable
        from repro.transport import plan_wire_bytes
        bw = self._bw if bandwidth_mbps is None else bandwidth_mbps
        obj = objective or self.objective
        pol = self.policy
        d = pol.decide(batch, bw, obj)
        key, _ = self._exec_key_for(d)
        plan = self.plans[key]
        # candidate rows over ALL profiled modes (voltage included for the
        # paper's "full exchange loses everywhere" artifact), interpolated
        # at the queried bandwidth exactly like decide() — never a snapped
        # column the decision did not actually compare
        modes = tuple(sorted({k.mode for k, _ in self.perfmap.entries()}))
        cands = tuple(PolicyTable.compile(self.perfmap, modes, obj)
                      .candidates(batch, bw))
        wire = plan_wire_bytes(plan, self.cfg, batch) or d.wire_bytes
        return Explanation(
            batch=batch, bandwidth_mbps=bw, decision=d, plan_key=key,
            candidates=cands,
            batch_crossover=pol.batch_crossover(bw, obj),
            bandwidth_crossover=pol.bandwidth_crossover(batch, obj),
            extrapolated=d.extrapolated,
            codec=plan.effective_codec if plan.distributed else "",
            wire_bytes=wire)
