"""`InferenceSession` — the one supported way to run the adaptive runtime.

Owns the model params, one jitted executable per `ExecutionPlan`, the
bandwidth observer (EWMA probe), the profiled performance map, and the
adaptive policy — the paper's whole Fig. 1 loop behind a single object::

    session = InferenceSession.from_config(
        "vit-base-16",
        plans=[ExecutionPlan.local(),
               ExecutionPlan.prism_sim(L=20, cr=4.95)])
    session.profile()                      # offline sweep → perf map
    session.observe_bandwidth(400.0)
    out = session.dispatch({"images": imgs})   # policy-routed execution
    print(session.explain(batch=8, bandwidth_mbps=400.0).summary())

Subsumes the legacy ``AdaptiveDispatcher`` + ``ServeEngine`` pair (both kept
as deprecation shims in ``repro.serving``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.api.plan import ExecutionPlan
from repro.core.perfmap import PerfEntry, PerfKey, PerfMap
from repro.core.policy import AdaptivePolicy, Decision, Objective


@dataclasses.dataclass
class DispatchRecord:
    """One routed batch: what the policy decided and what actually ran."""
    batch: int
    bandwidth_mbps: float
    decision: Decision
    wall_ms: float
    exec_key: str = ""          # executable that actually ran
    substituted: bool = False   # True when the decided key had no executable


@dataclasses.dataclass(frozen=True)
class Explanation:
    """Why a (batch, bandwidth) pair routes the way it does — the paper's
    reported artifacts derived from the live policy."""
    batch: int
    bandwidth_mbps: float
    decision: Decision
    plan_key: str                                   # executable id chosen
    candidates: Tuple[Tuple[PerfKey, PerfEntry], ...]
    batch_crossover: Optional[int]                  # paper: 8 @ 400 Mbps
    bandwidth_crossover: Optional[float]            # paper: ≈340 Mbps @ B=8

    def summary(self) -> str:
        lines = [f"B={self.batch} BW={self.bandwidth_mbps:g} Mbps → "
                 f"{self.decision.mode}"
                 + (f" CR={self.decision.cr:g}" if self.decision.cr else "")
                 + f"  ({self.decision.expected.per_sample_ms:.1f} ms/sample"
                 f" expected, plan {self.plan_key!r})"]
        for k, e in sorted(self.candidates,
                           key=lambda kv: kv[1].per_sample_ms):
            mark = "→" if (k.mode, k.cr) == (self.decision.mode,
                                             self.decision.cr) else " "
            lines.append(f"  {mark} {k.mode:<8} CR={k.cr:<5g} "
                         f"{e.per_sample_ms:8.1f} ms/sample "
                         f"{e.per_sample_j:7.2f} J/sample")
        lines.append(f"  batch crossover @ {self.bandwidth_mbps:g} Mbps: "
                     f"{self.batch_crossover} (paper: 8)")
        lines.append(f"  bandwidth crossover @ B={self.batch}: "
                     f"{self.bandwidth_crossover} Mbps (paper: ≈340)")
        return "\n".join(lines)


class InferenceSession:
    """Facade over params + per-plan executables + profiling + policy."""

    def __init__(self, cfg, params, plans: Sequence[ExecutionPlan] = (),
                 perfmap: Optional[PerfMap] = None,
                 objective: Objective = "latency",
                 allow_modes: Optional[Tuple[str, ...]] = None,
                 bandwidth_alpha: float = 0.3,
                 initial_bandwidth_mbps: float = 400.0,
                 temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.plans: Dict[str, ExecutionPlan] = {}
        self._execs: Dict[str, Any] = {}
        # plan → {(B, T0, n_new, T, prefill_mode): compiled generate fn}
        self._decode_execs: Dict[Any, Dict] = {}
        self.objective: Objective = objective
        self.temperature = temperature
        self._allow = allow_modes
        self._policy: Optional[AdaptivePolicy] = None
        self._bw = initial_bandwidth_mbps
        self._alpha = bandwidth_alpha
        self.history: List[DispatchRecord] = []
        self.perfmap = perfmap
        for p in (plans or [ExecutionPlan.local()]):
            self.add_plan(p)

    @classmethod
    def from_config(cls, arch: str, plans: Sequence[ExecutionPlan] = (),
                    *, perfmap: Optional[PerfMap] = None, reduced=True,
                    seed: int = 0, params=None, **kw) -> "InferenceSession":
        """Build from an architecture id (e.g. "vit-base-16", "llama3.2-1b").

        ``reduced``: True → CPU smoke-test variant; a dict → kwargs for
        ``cfg.reduced(**reduced)``; False → full-size config.
        """
        from repro.configs import get_config
        from repro.models import registry
        cfg = get_config(arch)
        if reduced:
            cfg = cfg.reduced(**(reduced if isinstance(reduced, dict) else {}))
        if params is None:
            params = registry.init_params(cfg, seed=seed)
        return cls(cfg, params, plans, perfmap=perfmap, **kw)

    # -- plans & executables -------------------------------------------------

    def add_plan(self, plan: ExecutionPlan) -> str:
        """Register a plan and jit its forward executable; returns its key."""
        import jax
        from repro.api.strategies import get_strategy
        from repro.models import registry
        key = plan.key
        if key in self.plans:
            raise ValueError(f"plan {key!r} already registered")
        if get_strategy(plan.mode).requires_L and plan.L <= 0:
            # a cr-only plan (e.g. from parse()/from_perf_key without
            # n_tokens) has no physical segment count to execute with
            raise ValueError(
                f"plan {key!r} has cr={plan.cr:g} but no physical L; call "
                "plan.resolve_L(n_tokens) before registering it")
        fwd = registry.forward_fn(self.cfg)
        xcfg = plan.to_exchange_config()
        self.plans[key] = plan
        self._execs[key] = jax.jit(
            lambda batch: fwd(self.params, batch, xcfg)[0])
        return key

    def run(self, plan_key: str, batch_inputs: Any):
        """Run one specific plan's executable (no policy involved)."""
        if plan_key not in self._execs:
            raise KeyError(f"no executable for plan {plan_key!r}; "
                           f"registered: {sorted(self._execs)}")
        return self._execs[plan_key](batch_inputs)

    # -- profiling -----------------------------------------------------------

    def profile(self, spec=None, *, measured: bool = False,
                model=None, save_path: Optional[str] = None) -> PerfMap:
        """Offline sweep (paper §3.3) → performance map, installed on the
        session (and optionally saved as the on-device JSON artifact)."""
        from repro.core.profiler import (SweepSpec, profile_measured,
                                         profile_simulated)
        spec = spec or SweepSpec()
        pm = (profile_measured(spec=spec) if measured
              else profile_simulated(model=model, spec=spec))
        self.set_perfmap(pm)
        if save_path:
            pm.save(save_path)
        return pm

    def set_perfmap(self, pm: PerfMap) -> None:
        self.perfmap = pm
        self._policy = None            # rebuilt lazily against the new map

    @property
    def policy(self) -> AdaptivePolicy:
        if self.perfmap is None:
            raise RuntimeError("no performance map: call session.profile() "
                               "or pass perfmap= / set_perfmap() first")
        if self._policy is None:
            self._policy = (AdaptivePolicy(self.perfmap, self._allow)
                            if self._allow else AdaptivePolicy(self.perfmap))
        return self._policy

    # -- bandwidth observation ----------------------------------------------

    def observe_bandwidth(self, mbps: float) -> None:
        """EWMA bandwidth probe update (the caller measures the link)."""
        self._bw = self._alpha * mbps + (1 - self._alpha) * self._bw

    @property
    def bandwidth(self) -> float:
        return self._bw

    # -- adaptive dispatch ---------------------------------------------------

    def decide(self, batch: int, bandwidth_mbps: Optional[float] = None,
               objective: Optional[Objective] = None) -> Decision:
        return self.policy.decide(batch,
                                  self._bw if bandwidth_mbps is None
                                  else bandwidth_mbps,
                                  objective or self.objective)

    def _exec_key_for(self, d: Decision) -> Tuple[str, bool]:
        """Decision → registered executable key, with recorded fallback:
        same-mode executable at another CR first, then any executable."""
        key = "local" if d.mode == "local" else f"{d.mode}@{d.cr:g}"
        if key in self._execs:
            return key, False
        same_mode = next((k for k in self._execs if k.split("@")[0] == d.mode),
                         None)
        if same_mode is not None:
            return same_mode, True
        if not self._execs:
            raise LookupError("no executables registered")
        return next(iter(self._execs)), True

    def dispatch(self, batch_inputs: Any,
                 batch_size: Optional[int] = None) -> Any:
        """Route one batch per the profiled policy and run it."""
        import jax
        if batch_size is None:
            batch_size = int(next(iter(batch_inputs.values())).shape[0]
                             if isinstance(batch_inputs, dict)
                             else batch_inputs.shape[0])
        d = self.decide(batch_size)
        key, substituted = self._exec_key_for(d)
        t0 = time.perf_counter()
        out = self._execs[key](batch_inputs)
        # wall_ms must cover execution, not just the async dispatch —
        # otherwise PerfMap-vs-observed comparisons flatter the runtime
        jax.tree_util.tree_map(
            lambda a: a.block_until_ready()
            if hasattr(a, "block_until_ready") else a, out)
        wall = (time.perf_counter() - t0) * 1e3
        self.history.append(DispatchRecord(batch_size, self._bw, d, wall,
                                           exec_key=key,
                                           substituted=substituted))
        return out

    # -- generation (subsumes ServeEngine) -----------------------------------

    def generate(self, prompt_tokens, n_new: int,
                 plan: Optional[ExecutionPlan] = None,
                 batch_extras: Optional[Dict[str, Any]] = None,
                 seed: int = 0, temperature: Optional[float] = None,
                 prefill_mode: str = "auto"):
        """Greedy/temperature generation: prompt [B, T0] → [B, n_new].

        Compiled fast path: single-pass prefill (or a teacher-forced
        ``lax.scan`` fallback — see ``repro.api.generation``) plus one
        scanned decode loop with on-device sampling, all inside ONE jitted
        executable — a constant number of dispatches regardless of prompt
        length and token count.  Executables are cached per
        (plan, shape, temperature); ``plan`` defaults to the local plan
        (or the first registered one).
        """
        from repro.api import generation as gen
        plan = plan or self.plans.get("local") or next(iter(self.plans.values()))
        T = self.temperature if temperature is None else temperature
        # cache by the full plan, not plan.key: distinct plans (e.g. two
        # prism_sim L values) can share a key but need distinct executables
        return gen.generate(self.params, prompt_tokens, n_new, self.cfg,
                            plan.to_exchange_config(),
                            batch_extras=batch_extras, seed=seed,
                            temperature=T, prefill_mode=prefill_mode,
                            _cache=self._decode_execs.setdefault(plan, {}))

    # -- explanation (the paper's reported artifacts) ------------------------

    def explain(self, batch: int, bandwidth_mbps: Optional[float] = None,
                objective: Optional[Objective] = None) -> Explanation:
        """Decision + candidate table + both crossover artifacts for one
        (batch, bandwidth) operating point."""
        bw = self._bw if bandwidth_mbps is None else bandwidth_mbps
        obj = objective or self.objective
        pol = self.policy
        d = pol.decide(batch, bw, obj)
        key, _ = self._exec_key_for(d)
        batch_key = pol.nearest_batch(batch)    # same snapping as decide()
        cands = tuple(self.perfmap.candidates(batch_key, bw))
        return Explanation(
            batch=batch, bandwidth_mbps=bw, decision=d, plan_key=key,
            candidates=cands,
            batch_crossover=pol.batch_crossover(bw, obj),
            bandwidth_crossover=pol.bandwidth_crossover(batch, obj))
