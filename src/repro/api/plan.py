"""`ExecutionPlan` — the one description of *how* a batch executes.

The paper's runtime chooses between *local* execution and *distributed(CR)*
execution per batch.  Before this module, that choice was smeared over three
ad-hoc encodings: raw ``ExchangeConfig`` dataclasses (physical exchange
parameters), ``PerfKey`` strings (profiling identity), and ``"mode@cr"``
dispatcher keys (executable identity).  ``ExecutionPlan`` unifies them: it
carries mode + compression + sequence-partition layout and converts to/from
each legacy encoding.

Key identities:

* ``plan.key``   — canonical executable id, e.g. ``"local"``/``"prism@9.9"``.
  ``prism_sim`` shares the ``prism`` key family because it is PRISM math run
  on unpartitioned tensors (profiled identically).
* ``plan.to_exchange_config()`` — physical exchange parameters for model code.
* ``plan.to_perf_key(batch, bw)`` — profiling identity for the perf map.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.exchange import ExchangeConfig, ExchangeMode
from repro.core.perfmap import PerfKey
from repro.core.segment_means import L_to_cr, cr_to_L


def split_key(key: str) -> Tuple[str, float, str]:
    """Decompose an executable id ``"mode[@cr][+codec]"`` → (mode, cr,
    codec) — the ONE parser for the key convention (used by
    ``ExecutionPlan.parse``, ``InferenceSession.plan_for_key`` and
    ``calibrate``)."""
    mode, _, cr_s = key.partition("@")
    if cr_s:
        try:
            # a codec-less key first: "%g" can emit an exponent whose '+'
            # (e.g. "prism@1e+06") must not be read as a codec separator
            # — codec names start with a letter (enforced at registration)
            return mode, float(cr_s), ""
        except ValueError:
            pass
    base, _, codec = key.partition("+")
    mode, _, cr_s = base.partition("@")
    if cr_s:
        try:
            cr = float(cr_s)
        except ValueError:
            raise ValueError(f"malformed plan key {key!r}: compression "
                             f"rate {cr_s!r} is not a number") from None
    else:
        cr = 0.0
    return mode, cr, codec


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Mode + compression + sequence-partition layout for one executable.

    ``cr`` is the *profiled* compression rate (the perf-map label); ``L`` is
    the *physical* number of segment means per partition at the deployed
    sequence length.  They are related by ``CR = N/(L·P)`` but may be set
    independently when the smoke-test sequence length differs from the
    profiled workload's.

    ``codec`` names a registered :mod:`repro.transport` codec ("" = the
    strategy's default — ``segment_means`` for prism, so pre-codec plans
    keep their identity); ``codec_param`` is its knob (quantization tile /
    top-k).  ``link`` names the transport link the cost accounting charges
    ("" = staged, the paper's GLOO path); ``overlap_chunks`` > 0 runs the
    exchange through the chunked ring executor (compute/comm overlap).
    Neither ``link`` nor ``overlap_chunks`` changes the math, so neither
    is part of the plan's identity (``key``).
    """
    mode: str = "local"              # registered strategy name
    cr: float = 0.0                  # profiled compression rate (0 = n/a)
    L: int = 0                       # segment means per partition (PRISM)
    seq_axis: Optional[str] = None   # mesh axis carrying sequence partitions
    seq_shards: int = 1              # P — number of sequence partitions
    batch_axes: Tuple[str, ...] = ()  # mesh axes sharding the batch dim
    codec: str = ""                  # exchange codec ("" = strategy default)
    codec_param: int = 0             # codec knob (quant tile / topk k)
    link: str = ""                   # transport link ("" = staged)
    overlap_chunks: int = 0          # ring-executor chunks (0 = gather)

    def __post_init__(self):
        from repro.api.strategies import get_strategy
        strategy = get_strategy(self.mode)     # raises on unknown mode
        if self.codec == strategy.default_codec:
            object.__setattr__(self, "codec", "")   # canonical identity
        strategy.validate_plan(self)

    # -- identity -----------------------------------------------------------

    @property
    def perf_mode(self) -> str:
        """Mode name under which this plan is profiled ("prism" for
        prism_sim — same math, same cost model)."""
        from repro.api.strategies import get_strategy
        return get_strategy(self.mode).perf_mode

    @property
    def effective_codec(self) -> str:
        """The codec that actually runs: the plan's, or the strategy's
        default ("" for strategies with no exchange payload)."""
        from repro.api.strategies import get_strategy
        return self.codec or get_strategy(self.mode).default_codec

    @property
    def key(self) -> str:
        """Canonical executable id — replaces hand-rolled "mode@cr" keys."""
        base = (f"{self.perf_mode}@{self.cr:g}" if self.cr > 0
                else self.perf_mode)
        return f"{base}+{self.codec}" if self.codec else base

    @property
    def distributed(self) -> bool:
        from repro.api.strategies import get_strategy
        return get_strategy(self.mode).distributed

    # -- constructors --------------------------------------------------------

    @staticmethod
    def local() -> "ExecutionPlan":
        return ExecutionPlan("local")

    @staticmethod
    def voltage(seq_axis: str = "seq", seq_shards: int = 2,
                batch_axes: Tuple[str, ...] = ()) -> "ExecutionPlan":
        return ExecutionPlan("voltage", 0.0, 0, seq_axis, seq_shards,
                             tuple(batch_axes))

    @staticmethod
    def prism(L: int, cr: float = 0.0, seq_axis: str = "seq",
              seq_shards: int = 2,
              batch_axes: Tuple[str, ...] = ()) -> "ExecutionPlan":
        return ExecutionPlan("prism", cr, L, seq_axis, seq_shards,
                             tuple(batch_axes))

    @staticmethod
    def prism_sim(L: int, cr: float = 0.0, seq_axis: str = "seq",
                  seq_shards: int = 2,
                  batch_axes: Tuple[str, ...] = ()) -> "ExecutionPlan":
        """PRISM math on unpartitioned tensors (single-host validation)."""
        return ExecutionPlan("prism_sim", cr, L, seq_axis, seq_shards,
                             tuple(batch_axes))

    @staticmethod
    def parse(key: str, *, seq_axis: str = "seq", seq_shards: int = 2,
              L: int = 0, codec_param: int = 0) -> "ExecutionPlan":
        """Parse an executable id: ``"local"`` / ``"prism@9.9"`` /
        ``"prism@4+int8"``."""
        mode, cr, codec = split_key(key)
        if mode == "local" and not codec:
            return ExecutionPlan.local()
        return ExecutionPlan(mode, cr, L, seq_axis, seq_shards,
                             codec=codec, codec_param=codec_param)

    # -- conversions ---------------------------------------------------------

    def to_exchange_config(self) -> ExchangeConfig:
        from repro.api.strategies import get_strategy
        return ExchangeConfig(get_strategy(self.mode).exchange_mode,
                              self.seq_axis if self.mode != "local" else None,
                              self.seq_shards if self.mode != "local" else 1,
                              L=self.L, batch_axes=tuple(self.batch_axes),
                              strategy=self.mode, codec=self.codec,
                              codec_param=self.codec_param,
                              overlap_chunks=self.overlap_chunks)

    @staticmethod
    def from_exchange_config(xcfg: ExchangeConfig,
                             n_tokens: Optional[int] = None,
                             cr: Optional[float] = None) -> "ExecutionPlan":
        """Lift a raw ``ExchangeConfig``; ``cr`` recovered from ``n_tokens``
        via CR = N/(L·P) when not given explicitly."""
        mode = xcfg.strategy or xcfg.mode.value
        if cr is None:
            cr = (L_to_cr(n_tokens, xcfg.seq_shards, xcfg.L)
                  if (n_tokens and xcfg.L > 0 and xcfg.seq_shards > 0)
                  else 0.0)
        return ExecutionPlan(mode, cr, xcfg.L, xcfg.seq_axis,
                             xcfg.seq_shards, tuple(xcfg.batch_axes),
                             codec=xcfg.codec, codec_param=xcfg.codec_param,
                             overlap_chunks=xcfg.overlap_chunks)

    def to_perf_key(self, batch: int, bandwidth_mbps: float = 0.0) -> PerfKey:
        if not self.distributed:
            return PerfKey(self.perf_mode, batch, 0.0, 0.0)
        return PerfKey(self.perf_mode, batch, self.cr, bandwidth_mbps,
                       self.codec)

    @staticmethod
    def from_perf_key(key: PerfKey, *, seq_axis: str = "seq",
                      seq_shards: int = 2, n_tokens: Optional[int] = None,
                      simulated: bool = False,
                      codec_param: int = 0) -> "ExecutionPlan":
        """``n_tokens`` resolves the physical L from the profiled CR;
        ``simulated`` maps "prism" onto the single-host prism_sim strategy.
        Codec-bearing keys carry the codec through; parameterized codecs
        (``topk``) additionally need ``codec_param``."""
        mode = key.mode
        if mode == "local":
            return ExecutionPlan.local()
        if mode == "prism" and simulated:
            mode = "prism_sim"
        L = (cr_to_L(n_tokens, seq_shards, key.cr)
             if (n_tokens and key.cr > 0 and not key.codec) else 0)
        return ExecutionPlan(mode, key.cr, L, seq_axis, seq_shards,
                             codec=key.codec, codec_param=codec_param)

    def resolve_L(self, n_tokens: int) -> "ExecutionPlan":
        """Fill in the physical L for a deployment sequence length from the
        profiled CR (no-op for non-PRISM plans, non-default codecs, or when
        L is already set)."""
        if (self.L > 0 or self.cr <= 0 or not self.distributed
                or self.codec):
            return self
        return dataclasses.replace(
            self, L=cr_to_L(n_tokens, self.seq_shards, self.cr))

    def sharding_plan(self, mesh, cfg, *, train: bool = False,
                      decode: bool = False):
        """Mesh-level ``ShardingPlan`` for multi-device launches (the mesh's
        axis sizes override this plan's ``seq_shards``)."""
        from repro.sharding.specs import make_plan
        from repro.api.strategies import get_strategy
        return make_plan(mesh, cfg, get_strategy(self.mode).exchange_mode,
                         L=self.L, train=train, decode=decode)
