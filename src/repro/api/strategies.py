"""Pluggable exchange-strategy registry.

Replaces the enum-switch logic that was spread across ``core/exchange.py``
and ``serving/``: each way attention can communicate across the
sequence-partition axis is one registered ``ExchangeStrategy``. The numeric
kernels stay in ``repro.core.exchange``; a strategy binds them together with
the runtime metadata the session/policy layer needs (is it distributed, how
does the profiler name it, may the policy select it).

Adding a new strategy — e.g. a top-k sparse exchange — is::

    @register_strategy
    class TopKStrategy(ExchangeStrategy):
        name = "topk"
        exchange_mode = ExchangeMode.PRISM      # or a new mode
        distributed = True
        def _prefill(self, q, k, v, cfg, **kw): ...

after which ``ExecutionPlan(mode="topk", ...)`` and the whole
``InferenceSession`` surface work unchanged.
"""
from __future__ import annotations

from typing import Dict, List, Type

from repro.core import exchange as xchg
from repro.core.exchange import ExchangeConfig, ExchangeMode

_REGISTRY: Dict[str, "ExchangeStrategy"] = {}


def register_strategy(cls: Type["ExchangeStrategy"]) -> Type["ExchangeStrategy"]:
    """Class decorator: instantiate and register under ``cls.name``."""
    if not getattr(cls, "name", None):
        raise ValueError(f"{cls.__name__} must define a non-empty `name`")
    if cls.name in _REGISTRY:
        raise ValueError(f"strategy {cls.name!r} already registered "
                         f"(by {type(_REGISTRY[cls.name]).__name__})")
    _REGISTRY[cls.name] = cls()
    return cls


def get_strategy(name: str) -> "ExchangeStrategy":
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown exchange strategy {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def list_strategies() -> List[str]:
    return sorted(_REGISTRY)


class ExchangeStrategy:
    """One way attention communicates across sequence partitions."""

    name: str = ""                             # registry key / plan.mode
    exchange_mode: ExchangeMode = ExchangeMode.LOCAL
    distributed: bool = False                  # needs >1 sequence partition
    selectable: bool = True                    # may the adaptive policy pick it
    requires_L: bool = False                   # needs segment means per shard
    default_codec: str = ""                    # repro.transport codec a plan
                                               # with codec="" resolves to

    @property
    def perf_mode(self) -> str:
        """Mode name in the performance map (default: the strategy name)."""
        return self.name

    # -- plan validation ----------------------------------------------------

    def validate_plan(self, plan) -> None:
        if self.distributed and plan.seq_shards > 1 and plan.seq_axis is None:
            raise ValueError(f"{self.name} plan with seq_shards="
                             f"{plan.seq_shards} needs a seq_axis")
        if plan.codec and plan.codec != self.default_codec:
            from repro.transport import CodecSpec, get_codec
            codec = get_codec(plan.codec)          # raises on unknown codec
            codec.validate_spec(CodecSpec(L=plan.L, param=plan.codec_param))
            return                    # non-default codec owns its parameters
        if self.requires_L and plan.L <= 0 and plan.cr <= 0:
            raise ValueError(f"{self.name} plan needs L > 0 or cr > 0 "
                             f"(got L={plan.L}, cr={plan.cr})")

    # -- prefill / full-sequence attention ----------------------------------

    def prefill_attention(self, q, k, v, cfg: ExchangeConfig, **kw):
        """Full-sequence attention under this exchange. Degenerate layouts
        (no sequence axis, one shard) fall back to plain local attention."""
        if (cfg.mode == ExchangeMode.LOCAL or cfg.seq_axis is None
                or cfg.seq_shards == 1):
            return xchg.local_prefill_attention(q, k, v, cfg, **kw)
        return self._prefill(q, k, v, cfg, **kw)

    def _prefill(self, q, k, v, cfg: ExchangeConfig, **kw):
        raise NotImplementedError(f"{self.name} defines no prefill exchange")

    # -- decode-time attention ----------------------------------------------

    def decode_attention(self, q, k_cache, v_cache, cache_len,
                         cfg: ExchangeConfig, **kw):
        """One-token attention against a (possibly position-sharded) cache."""
        return xchg.decode_attention_sharded(q, k_cache, v_cache, cache_len,
                                             cfg, **kw)


@register_strategy
class LocalStrategy(ExchangeStrategy):
    """Single-device inference — the paper's lower-bound baseline."""
    name = "local"
    exchange_mode = ExchangeMode.LOCAL
    distributed = False


@register_strategy
class VoltageStrategy(ExchangeStrategy):
    """Full-tensor K/V exchange (Hu & Li, ICDCS'24). Profiled for reporting;
    never selected by the paper's deployment policy — it loses everywhere."""
    name = "voltage"
    exchange_mode = ExchangeMode.VOLTAGE
    distributed = True
    selectable = False
    default_codec = "identity"

    def _prefill(self, q, k, v, cfg, **kw):
        return xchg.voltage_prefill_attention(q, k, v, cfg, **kw)


@register_strategy
class PrismStrategy(ExchangeStrategy):
    """Compressed exchange + local-exact attention.  The codec is an axis:
    the default ``segment_means`` is the paper's PRISM (scaling-aware
    softmax over remote means — byte-identical to the pre-codec path); any
    other registered codec (``int8``/``int4``/``topk``) exchanges encoded
    K/V partitions and reconstructs remote context before attention."""
    name = "prism"
    exchange_mode = ExchangeMode.PRISM
    distributed = True
    requires_L = True
    default_codec = "segment_means"

    def _prefill(self, q, k, v, cfg, **kw):
        if cfg.codec and cfg.codec != self.default_codec:
            from repro.transport.executor import codec_prefill_attention
            return codec_prefill_attention(q, k, v, cfg, **kw)
        return xchg.prism_prefill_attention(q, k, v, cfg, **kw)


@register_strategy
class PrismSimStrategy(ExchangeStrategy):
    """PRISM math on unpartitioned tensors — single-host validation and
    training. Shares PRISM's profiling identity (same math, same cost)."""
    name = "prism_sim"
    exchange_mode = ExchangeMode.PRISM_SIM
    distributed = True
    requires_L = True
    default_codec = "segment_means"

    @property
    def perf_mode(self) -> str:
        return "prism"

    def _prefill(self, q, k, v, cfg, **kw):
        if cfg.codec and cfg.codec != self.default_codec:
            from repro.transport.executor import codec_sim_prefill_attention
            return codec_sim_prefill_attention(q, k, v, cfg, **kw)
        return xchg.prism_sim_prefill_attention(q, k, v, cfg, **kw)
