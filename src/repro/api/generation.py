"""Compiled generation fast path: prefill + scanned decode in ONE jitted
executable.

The legacy loop (`ServeEngine.generate` / the seed `InferenceSession.
generate`) dispatched one jitted decode step per prompt token AND per new
token, plus a host-side `jax.random.split` and an implicit device sync per
sampled token — per-step Python/dispatch overhead dominated exactly as the
Jetson profiling literature predicts (arXiv:2508.08430).  Here the whole
generation — cache init, prompt prefill, `lax.scan` decode with on-device
sampling — is a single XLA computation, jitted once per
(plan, batch, prompt-length, n_new) and cached by the caller:

* **Prefill** — ``repro.models.transformer.prefill`` runs the prompt
  through ``exchange_attention`` once and bulk-writes the KV cache
  (attention families).  Recurrent families (hybrid/ssm), and PRISM plans
  under ``prefill_mode="auto"`` (whose compressed prefill is intentionally
  not equivalent to exact per-token decode), use ``prefill_by_decode`` — a
  teacher-forced ``lax.scan`` of ``decode_step``: still one executable,
  just sequential math.
* **Decode** — ``lax.scan`` of ``decode_step`` + on-device sampling with a
  threaded PRNG key (no host round-trips); the cache lives in the scan
  carry so XLA updates it in place.

``dispatch_count()`` counts invocations of compiled generation callables —
the regression tests assert it stays O(1) in prompt length and n_new.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.exchange import ExchangeConfig, ExchangeMode
from repro.models import transformer as tfm

_STATS = {"dispatches": 0, "builds": 0}


def dispatch_count() -> int:
    """Compiled generation callables invoked so far (one per generate)."""
    return _STATS["dispatches"]


def build_count() -> int:
    return _STATS["builds"]


def sample_token(logits: jnp.ndarray, key, temperature: float = 0.0):
    """[B, 1, V] → [B, 1] token ids (greedy at T=0)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / temperature
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def resolve_prefill_mode(cfg: ModelConfig, xcfg: ExchangeConfig,
                         mode: str = "auto") -> str:
    """Pick the prefill implementation: "single_pass" or "scan".

    "auto" chooses single-pass when the family supports it AND the
    full-sequence math is exact w.r.t. the decode path:

    * PRISM plans prefill through compressed segment means — the paper's
      distributed-prefill semantics, but not token-for-token equal to the
      legacy decode loop — so "auto" keeps them scanned; pass
      ``prefill_mode="single_pass"`` explicitly for the compressed prefill.
    * MoE full-sequence routing uses a capacity ∝ seq-len and can DROP
      token-expert assignments that per-token decode (capacity 1/step)
      never drops, so "auto" keeps MoE scanned too; forcing single-pass
      gives the forward/training routing semantics.
    """
    if mode == "scan":
        return "scan"
    supported = tfm.supports_prefill(cfg)
    if mode == "single_pass":
        if not supported:
            raise ValueError(f"family {cfg.family!r} has no single-pass "
                             f"prefill (supported: {tfm.PREFILL_FAMILIES})")
        return "single_pass"
    if mode != "auto":
        raise ValueError(f"prefill_mode {mode!r}: one of "
                         f"'auto' | 'single_pass' | 'scan'")
    exact = ((xcfg.mode in (ExchangeMode.LOCAL, ExchangeMode.VOLTAGE)
              or xcfg.seq_axis is None or xcfg.seq_shards == 1)
             and cfg.moe is None)
    return "single_pass" if (supported and exact) else "scan"


def prefill_by_decode(params, prompt_tokens: jnp.ndarray, cache,
                      cfg: ModelConfig, xcfg: ExchangeConfig):
    """Teacher-forced prompt consumption as ONE ``lax.scan`` of
    ``decode_step`` → (last logits [B, 1, V], primed cache).

    Compiled fallback where single-pass prefill doesn't apply; identical
    math to the legacy per-token loop, minus T0 dispatches.
    """
    B, T0 = prompt_tokens.shape

    def step(carry, xs):
        c, _ = carry
        tok, idx = xs
        logits, c = tfm.decode_step(params, {"tokens": tok[:, None]}, c,
                                    idx, cfg, xcfg)
        return (c, logits), None

    logits0 = jnp.zeros((B, 1, cfg.vocab_size), jnp.float32)
    (cache, logits), _ = jax.lax.scan(
        step, (cache, logits0),
        (prompt_tokens.T, jnp.arange(T0, dtype=jnp.int32)))
    return logits, cache


def decode_scan(params, cache, tok0: jnp.ndarray, start_index, key,
                cfg: ModelConfig, xcfg: ExchangeConfig,
                temperature: float, n_steps: int):
    """``n_steps`` autoregressive steps from ``tok0`` at ``start_index``,
    sampling on device with a threaded key → (tokens [B, n_steps], cache).
    """
    B = tok0.shape[0]
    if n_steps <= 0:
        return jnp.zeros((B, 0), jnp.int32), cache

    def step(carry, _):
        tok, c, idx, k = carry
        logits, c = tfm.decode_step(params, {"tokens": tok}, c, idx, cfg,
                                    xcfg)
        k, sub = jax.random.split(k)
        nxt = sample_token(logits, sub, temperature)[:, 0:1]
        return (nxt, c, idx + 1, k), nxt[:, 0]

    (_, cache, _, _), toks = jax.lax.scan(
        step, (tok0, cache, jnp.asarray(start_index, jnp.int32), key),
        None, length=n_steps)
    return toks.T, cache                               # [B, n_steps]


def build_generate_fn(cfg: ModelConfig, xcfg: ExchangeConfig, *,
                      n_new: int, temperature: float = 0.0,
                      prefill_mode: str = "auto") -> Callable:
    """One jitted end-to-end generation callable.

    Returns ``fn(params, prompt_tokens [B, T0], extras, key) → [B, n_new]``
    (``extras``: the audio/vlm memory inputs, ``{}`` otherwise).  The whole
    pipeline — cache init, prefill, sampled decode scan — is a single XLA
    computation: a constant number of dispatches regardless of T0 / n_new,
    and the cache never round-trips through Python between tokens.
    """
    mode = resolve_prefill_mode(cfg, xcfg, prefill_mode)

    def gen(params, prompt_tokens, extras, key):
        B, T0 = prompt_tokens.shape
        cache = tfm.init_decode_cache(cfg, B, T0 + n_new)
        if cfg.family in ("audio", "vlm"):
            cache = tfm.prefill_memory(
                params, {"tokens": prompt_tokens, **extras}, cfg, xcfg,
                cache)
        if mode == "single_pass":
            logits, cache = tfm.prefill(
                params, {"tokens": prompt_tokens, **extras}, cache, cfg,
                xcfg)
        else:
            logits, cache = prefill_by_decode(params, prompt_tokens, cache,
                                              cfg, xcfg)
        key, sub = jax.random.split(key)
        tok = sample_token(logits, sub, temperature)[:, 0:1]
        rest, _ = decode_scan(params, cache, tok, T0, key, cfg, xcfg,
                              temperature, n_new - 1)
        return jnp.concatenate([tok, rest], axis=1)

    jitted = jax.jit(gen)
    _STATS["builds"] += 1

    def counted(params, prompt_tokens, extras, key):
        _STATS["dispatches"] += 1
        return jitted(params, prompt_tokens, extras, key)

    counted.jitted = jitted
    counted.prefill_mode = mode
    return counted


# ---------------------------------------------------------------------------
# slot-pool serving primitives (continuous batching)
# ---------------------------------------------------------------------------
#
# The serving runtime (repro.serving) keeps ONE pooled decode cache with a
# slot per in-flight request; requests are admitted into free slots between
# decode chunks.  Three primitives make that work while staying token-exact
# with a per-request `generate`:
#
# * `cache_batch_axes`   — which axis of each cache leaf is the batch/slot
#                          axis (the stacked scan layout moves it around).
# * `build_prefill_fn`   — prime ONE request's cache at the pool length and
#                          sample its first token (same math as `generate`).
# * `build_decode_chunk_fn` — `n_steps` decode steps over ALL slots in one
#                          jitted executable, each slot at its OWN position
#                          (a per-slot vmap of `decode_step` with a
#                          threaded per-slot PRNG key).

def cache_batch_axes(cfg: ModelConfig):
    """Pytree (matching ``init_decode_cache``) of ints: the batch axis of
    every cache leaf.  Derived structurally — the axis whose size follows
    the requested batch — so new families need no per-family table."""
    a = jax.eval_shape(lambda: tfm.init_decode_cache(cfg, 1, 8))
    b = jax.eval_shape(lambda: tfm.init_decode_cache(cfg, 2, 8))

    def axis(x, y):
        for i, (p, q) in enumerate(zip(x.shape, y.shape)):
            if p != q:
                return i
        raise ValueError(f"no batch axis in cache leaf {x.shape}")
    return jax.tree_util.tree_map(axis, a, b)


SLOT_POOL_FAMILIES = ("dense", "moe", "hybrid", "ssm")


def supports_slot_pool(cfg: ModelConfig) -> bool:
    """Tokens-only generative families can be slot-pooled.  audio/vlm
    caches carry per-request memory tensors whose shapes depend on the
    request extras, and non-generative families (vit) have no decode cache
    at all — neither can share one pooled pytree."""
    return cfg.family in SLOT_POOL_FAMILIES


def build_prefill_fn(cfg: ModelConfig, xcfg: ExchangeConfig, *,
                     total_len: int, prefill_mode: str = "auto",
                     with_logits: bool = False) -> Callable:
    """One jitted request-admission executable.

    ``fn(params, prompt_tokens [B, T0], extras, key, temp) → (tok0 [B, 1],
    cache, key')`` — cache init at ``total_len`` (the pool's max length),
    prompt prefill, and the first sampled token, exactly the front half of
    ``build_generate_fn`` (same key threading, so a slot primed here and
    decoded by chunks reproduces ``generate`` token-for-token).  ``temp``
    is a traced scalar (≤0 = greedy), not a compile-time constant — serving
    traffic carries per-request temperatures and must not recompile the
    prefill per distinct value.  ``with_logits=True`` appends the raw
    last-position logits [B, 1, V] to the return (the paged prefix cache
    stores them so a later full-prefix hit can re-sample its own first
    token without re-running the prefill).
    """
    mode = resolve_prefill_mode(cfg, xcfg, prefill_mode)

    def pf(params, prompt_tokens, extras, key, temp):
        B, T0 = prompt_tokens.shape
        cache = tfm.init_decode_cache(cfg, B, total_len)
        if cfg.family in ("audio", "vlm"):
            cache = tfm.prefill_memory(
                params, {"tokens": prompt_tokens, **extras}, cfg, xcfg,
                cache)
        if mode == "single_pass":
            logits, cache = tfm.prefill(
                params, {"tokens": prompt_tokens, **extras}, cache, cfg,
                xcfg)
        else:
            logits, cache = prefill_by_decode(params, prompt_tokens, cache,
                                              cfg, xcfg)
        key, sub = jax.random.split(key)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        sampled = jax.random.categorical(
            sub, logits / jnp.maximum(temp, 1e-6), axis=-1).astype(jnp.int32)
        tok = jnp.where(temp > 0.0, sampled, greedy)[:, 0:1]
        if with_logits:
            return tok, cache, key, logits
        return tok, cache, key

    jitted = jax.jit(pf)
    _STATS["builds"] += 1

    def counted(params, prompt_tokens, extras, key, temp):
        _STATS["dispatches"] += 1
        return jitted(params, prompt_tokens, extras, key, temp)

    counted.jitted = jitted
    counted.prefill_mode = mode
    return counted


def build_admit_fn(cfg: ModelConfig) -> Callable:
    """Fused slot admission: ONE jitted executable scatters a primed B=1
    request cache into row ``slot`` of the pool AND updates the four
    per-slot state vectors (current token, write position, PRNG key,
    temperature).  Issuing these as separate eager ops cost ~5 device
    dispatches per admission — measurably more than the prefill itself.

    ``fn(pool, tok, lengths, keys, temps, req_cache, slot, tok0 [1,1],
    length0, key0, temp0) → (pool, tok, lengths, keys, temps)``.
    """
    axes = cache_batch_axes(cfg)

    def admit(pool, tok, lengths, keys, temps, req_cache, slot, tok0,
              length0, key0, temp0):
        pool = jax.tree_util.tree_map(
            lambda p, r, a: jax.lax.dynamic_update_slice_in_dim(
                p, r.astype(p.dtype), slot, axis=a),
            pool, req_cache, axes)
        tok = tok.at[slot].set(tok0[0, 0])
        lengths = lengths.at[slot].set(length0)
        keys = keys.at[slot].set(key0)
        temps = temps.at[slot].set(temp0)
        return pool, tok, lengths, keys, temps

    return jax.jit(admit)


def build_decode_chunk_fn(cfg: ModelConfig, xcfg: ExchangeConfig, *,
                          n_steps: int,
                          max_len: Optional[int] = None) -> Callable:
    """One jitted continuous-batching decode chunk over a slot pool.

    ``fn(params, pool_cache, tok [S], lengths [S], keys [S], temps [S]) →
    (tokens [S, n_steps], pool_cache, lengths, keys)``: a ``lax.scan`` of a
    per-slot ``vmap`` of ``decode_step``, each slot reading/writing its own
    cache row at its own position with its own PRNG key and sampling
    temperature — per-slot math is identical to a B=1 ``generate`` decode
    (greedy at ``temps[i] <= 0``, categorical otherwise, key split every
    step either way), so pooled decoding stays token-exact per request
    regardless of what shares the pool.  Slots that are free (or already
    finished) keep decoding harmlessly: their writes stay inside their own
    row and admission re-primes the whole row.
    """
    axes = cache_batch_axes(cfg)

    def one(params, tok, cache_slot, idx, key, temp):
        cache_b = jax.tree_util.tree_map(
            lambda t, a: jnp.expand_dims(t, a), cache_slot, axes)
        logits, c = tfm.decode_step(params, {"tokens": tok[None, None]},
                                    cache_b, idx, cfg, xcfg)
        key, sub = jax.random.split(key)
        row = logits[0, 0]
        greedy = jnp.argmax(row, axis=-1).astype(jnp.int32)
        sampled = jax.random.categorical(
            sub, row / jnp.maximum(temp, 1e-6)).astype(jnp.int32)
        nxt = jnp.where(temp > 0.0, sampled, greedy)
        c = jax.tree_util.tree_map(
            lambda t, a: jnp.squeeze(t, axis=a), c, axes)
        return nxt, c, key

    vone = jax.vmap(one, in_axes=(None, 0, axes, 0, 0, 0),
                    out_axes=(0, axes, 0))

    def chunk(params, cache, tok, lengths, keys, temps):
        def step(carry, _):
            tok, cache, lengths, keys = carry
            nxt, cache, keys = vone(params, tok, cache, lengths, keys,
                                    temps)
            lengths = lengths + 1
            if max_len is not None:
                lengths = jnp.minimum(lengths, max_len)
            return (nxt, cache, lengths, keys), nxt

        (tok, cache, lengths, keys), toks = jax.lax.scan(
            step, (tok, cache, lengths, keys), None, length=n_steps)
        return toks.T, cache, lengths, keys

    jitted = jax.jit(chunk)
    _STATS["builds"] += 1

    def counted(params, cache, tok, lengths, keys, temps):
        _STATS["dispatches"] += 1
        return jitted(params, cache, tok, lengths, keys, temps)

    counted.jitted = jitted
    return counted


# ---------------------------------------------------------------------------
# paged-pool serving primitives (block KV cache + prefix caching)
# ---------------------------------------------------------------------------
#
# The paged runtime (repro.serving.pages) replaces per-slot dense caches
# with ONE shared pool of fixed-size KV pages; each request owns a row of a
# [rows, max_pages] page table.  Admission still primes a B=1 dense cache
# with the ordinary prefill executable (page-aligned length), then ONE
# fused scatter moves it into the request's pages.  Decode is ONE jitted
# executable per (plan, rows, max_pages): all rows step together against
# the shared pool (per-row vmap would fork the pool), with per-row
# positions/keys/temps — the per-row sampling math is identical to
# `build_decode_chunk_fn`'s, so paged serving stays token-exact vs
# `session.generate`.

def build_paged_admit_fn(cfg: ModelConfig) -> Callable:
    """Fused paged admission: scatter a primed B=1 dense request cache
    (page-aligned length P0·ps) into pool pages ``page_ids`` [P0] AND set
    the row's state vector entries, in ONE executable (compiled per P0,
    like the prefill is per prompt length).

    ``fn(pool, tok, lengths, keys, temps, req_cache, page_ids, row, tok0,
    length0, key0, temp0) → (pool, tok, lengths, keys, temps)``.
    """

    def admit(pool, tok, lengths, keys, temps, req_cache, page_ids, row,
              tok0, length0, key0, temp0):
        def scatter(p, r):
            # p: [L, P, ps, Hk, dh] pool leaf; r: [L, 1, P0*ps, Hk, dh]
            ps = p.shape[2]
            P0 = r.shape[2] // ps
            r = r.astype(p.dtype).reshape(r.shape[0], P0, ps, *r.shape[3:])
            return p.at[:, page_ids].set(r)

        pool = jax.tree_util.tree_map(scatter, pool, req_cache)
        tok = tok.at[row].set(tok0[0, 0])
        lengths = lengths.at[row].set(length0)
        keys = keys.at[row].set(key0)
        temps = temps.at[row].set(temp0)
        return pool, tok, lengths, keys, temps

    # the pool is donated: it is orders of magnitude larger than anything
    # else here and every caller rebinds the returned pool, so XLA can
    # scatter in place instead of copying the whole pool per admission
    jitted = jax.jit(admit, donate_argnums=(0,))
    _STATS["builds"] += 1

    def counted(*args):
        _STATS["dispatches"] += 1
        return jitted(*args)

    counted.jitted = jitted
    return counted


def build_paged_hit_fn(cfg: ModelConfig) -> Callable:
    """Fused full-prefix-hit admission: no prefill runs — the request's
    first token is sampled from the prefix entry's *cached* last-position
    logits with the request's own key (the same split/argmax/categorical
    sequence ``build_prefill_fn`` applies, so a hit stays token-exact vs a
    miss), and the row state vectors are set in the same executable.

    ``fn(tok, lengths, keys, temps, row, logits [1,1,V], length0, key0,
    temp0) → (tok, lengths, keys, temps)``.
    """

    def hit(tok, lengths, keys, temps, row, logits, length0, key0, temp0):
        key0, sub = jax.random.split(key0)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        sampled = jax.random.categorical(
            sub, logits / jnp.maximum(temp0, 1e-6),
            axis=-1).astype(jnp.int32)
        t0 = jnp.where(temp0 > 0.0, sampled, greedy)[0, 0]
        tok = tok.at[row].set(t0)
        lengths = lengths.at[row].set(length0)
        keys = keys.at[row].set(key0)
        temps = temps.at[row].set(temp0)
        return tok, lengths, keys, temps

    jitted = jax.jit(hit)
    _STATS["builds"] += 1

    def counted(*args):
        _STATS["dispatches"] += 1
        return jitted(*args)

    counted.jitted = jitted
    return counted


def build_paged_suffix_fn(cfg: ModelConfig, xcfg: ExchangeConfig, *,
                          n_suffix: int) -> Callable:
    """Partial-prefix-hit admission: the shared prefix pages are already
    hot, so only the ``n_suffix`` remaining prompt tokens run — a
    teacher-forced ``lax.scan`` of ``decode_step_paged`` writing straight
    into the request's pages, then the first-token sampling tail of
    ``build_prefill_fn``.  Scanned prefill is token-exact vs single-pass
    for the families the page pool serves (the `test_generate_parity_local`
    equivalence), so hit admissions reproduce miss admissions exactly.

    ``fn(params, pool, row_table [1, MP], suffix [1, n], start_len [1],
    key, temp) → (tok0 [1, 1], pool, key', logits [1, 1, V])``.
    """

    def pf(params, pool, row_table, suffix, start_len, key, temp):
        def step(carry, xs):
            pool, _ = carry
            t, i = xs
            logits, pool = tfm.decode_step_paged(
                params, {"tokens": t[:, None]}, pool, row_table,
                start_len + i, cfg, xcfg)
            return (pool, logits), None

        logits0 = jnp.zeros((1, 1, cfg.vocab_size), jnp.float32)
        (pool, logits), _ = jax.lax.scan(
            step, (pool, logits0),
            (suffix.T, jnp.arange(n_suffix, dtype=jnp.int32)))
        key, sub = jax.random.split(key)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        sampled = jax.random.categorical(
            sub, logits / jnp.maximum(temp, 1e-6), axis=-1).astype(jnp.int32)
        tok = jnp.where(temp > 0.0, sampled, greedy)[:, 0:1]
        return tok, pool, key, logits

    # donated pool: in-place page writes instead of a pool-sized copy per
    # scan carry (the caller always rebinds the returned pool)
    jitted = jax.jit(pf, donate_argnums=(1,))
    _STATS["builds"] += 1

    def counted(*args):
        _STATS["dispatches"] += 1
        return jitted(*args)

    counted.jitted = jitted
    return counted


def build_paged_decode_chunk_fn(cfg: ModelConfig, xcfg: ExchangeConfig, *,
                                n_steps: int) -> Callable:
    """One jitted continuous-batching decode chunk over the paged pool.

    ``fn(params, pool, page_table [S, MP], caps [S], tok [S], lengths [S],
    keys [S], temps [S]) → (tokens [S, n_steps], pool, lengths, keys)``.
    All rows advance together through ``decode_step_paged`` (the pool is
    shared state); per-row sampling applies exactly the per-slot math of
    ``build_decode_chunk_fn``.  ``caps`` [S] is each row's last writable
    position (pages assigned · page_size − 1): rows whose requests are done
    or freed keep decoding harmlessly, their writes clamped inside their
    own last page (or the trash page) — active rows are never clamped
    because the runtime allocates pages covering the whole chunk first.
    """

    def samp(row, key, temp):
        key, sub = jax.random.split(key)
        greedy = jnp.argmax(row, axis=-1).astype(jnp.int32)
        sampled = jax.random.categorical(
            sub, row / jnp.maximum(temp, 1e-6)).astype(jnp.int32)
        return jnp.where(temp > 0.0, sampled, greedy), key

    def chunk(params, pool, page_table, caps, tok, lengths, keys, temps):
        def step(carry, _):
            tok, pool, lengths, keys = carry
            pos = jnp.minimum(lengths, caps)
            logits, pool = tfm.decode_step_paged(
                params, {"tokens": tok[:, None]}, pool, page_table, pos,
                cfg, xcfg)
            nxt, keys = jax.vmap(samp)(logits[:, 0], keys, temps)
            return (nxt, pool, pos + 1, keys), nxt

        (tok, pool, lengths, keys), toks = jax.lax.scan(
            step, (tok, pool, lengths, keys), None, length=n_steps)
        return toks.T, pool, lengths, keys

    # donated pool: the chunk runs every scheduler step, and an undonated
    # pool costs a full pool copy at the jit boundary each time
    jitted = jax.jit(chunk, donate_argnums=(1,))
    _STATS["builds"] += 1

    def counted(*args):
        _STATS["dispatches"] += 1
        return jitted(*args)

    counted.jitted = jitted
    return counted


def generate(params, prompt_tokens: jnp.ndarray, n_new: int,
             cfg: ModelConfig, xcfg: ExchangeConfig, *,
             batch_extras: Optional[Dict[str, Any]] = None, seed: int = 0,
             temperature: float = 0.0, prefill_mode: str = "auto",
             _cache: Optional[Dict] = None) -> jnp.ndarray:
    """Convenience one-shot wrapper (sessions/engines keep their own
    compiled-fn caches; pass ``_cache`` dict to reuse executables)."""
    B, T0 = prompt_tokens.shape
    if n_new <= 0:
        return jnp.zeros((B, 0), jnp.int32)
    key = (B, T0, int(n_new), float(temperature), prefill_mode)
    fns = _cache if _cache is not None else {}
    if key not in fns:
        fns[key] = build_generate_fn(cfg, xcfg, n_new=n_new,
                                     temperature=temperature,
                                     prefill_mode=prefill_mode)
    return fns[key](params, prompt_tokens, dict(batch_extras or {}),
                    jax.random.key(seed))
