"""Small pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def param_count(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) for l in leaves if hasattr(l, "shape")))


def param_bytes(tree) -> int:
    """Total bytes of a pytree of arrays / ShapeDtypeStructs."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for l in leaves:
        if hasattr(l, "shape") and hasattr(l, "dtype"):
            total += int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
    return total


def tree_norm(tree) -> jax.Array:
    """Global L2 norm over every leaf of a pytree."""
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)
