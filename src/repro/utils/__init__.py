from repro.utils.bandwidth import BandwidthEstimator
from repro.utils.tree import param_count, param_bytes, tree_norm
from repro.utils.timing import Timer

__all__ = ["param_count", "param_bytes", "tree_norm", "Timer",
           "BandwidthEstimator"]
