"""Shared EWMA bandwidth estimator + deterministic drift model.

The paper's runtime probes the link and alpha-blends observations into a
running estimate the policy queries.  The blend used to be duplicated in
``AdaptiveDispatcher.observe_bandwidth`` and ``InferenceSession`` (same
formula, two drifting copies); :class:`BandwidthEstimator` is now the one
implementation both consume — and the serving scheduler reads it too.

:class:`BandwidthWalk` is the drift side of the same story: a seeded,
replayable bandwidth-over-time curve (linear ramp + bounded jitter) that
the chaos layer scripts into fault schedules — WiFi links drift, and the
scenario suite must drift them *identically* on every run.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class BandwidthEstimator:
    """EWMA link-bandwidth estimate: ``bw ← α·obs + (1-α)·bw``.

    With a ``metrics`` registry attached, every observation also lands in
    the ``link.bandwidth_mbps`` gauge with an explicit provenance label:
    probe observations are ``estimated`` (someone's external estimate of
    the link), transfer-derived ones are ``measured`` (bytes actually
    moved over a measured wall), and ``reset`` pins are ``modeled``.
    This replaces the old per-call-site unit/provenance ambiguity — the
    label, not the file a number landed in, says where it came from.
    """

    initial_mbps: float = 400.0
    alpha: float = 0.3
    metrics: object = None             # Optional[MetricsRegistry]

    def __post_init__(self):
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {self.alpha}")
        self._mbps = float(self.initial_mbps)
        self._n = 0

    def _gauge(self, obs_mbps: float, provenance: str) -> None:
        if self.metrics is not None:
            self.metrics.observe_bandwidth("link.bandwidth_mbps", obs_mbps,
                                           provenance)
            self.metrics.gauge("link.bandwidth_ewma_mbps").set(self._mbps)

    def observe(self, mbps: float, provenance: str = "estimated") -> float:
        """Fold one observation in; returns the updated estimate."""
        self._mbps = self.alpha * float(mbps) + (1 - self.alpha) * self._mbps
        self._n += 1
        self._gauge(float(mbps), provenance)
        return self._mbps

    def observe_transfer(self, n_bytes: float, wall_ms: float) -> float:
        """Fold one *observed transfer* in: ``n_bytes`` moved in
        ``wall_ms`` implies a link bandwidth, EWMA-blended like a probe.
        This is how ``session.calibrate()`` refines the link estimate from
        per-dispatch bytes-on-wire telemetry; returns the implied Mbps."""
        if n_bytes <= 0 or wall_ms <= 0:
            raise ValueError(f"transfer needs positive bytes and wall "
                             f"(got {n_bytes} B / {wall_ms} ms)")
        mbps = n_bytes * 8e-3 / wall_ms        # bytes/ms → Mbit/s
        self.observe(mbps, provenance="measured")
        return mbps

    def reset(self, mbps: float) -> None:
        """Pin the estimate (e.g. a fresh probe after a re-mesh)."""
        self._mbps = float(mbps)
        self._gauge(float(mbps), "modeled")

    @property
    def mbps(self) -> float:
        return self._mbps

    @property
    def observations(self) -> int:
        return self._n


@dataclasses.dataclass
class BandwidthWalk:
    """Seeded bandwidth-over-time curve for drift injection.

    ``at(u)`` (``u`` ∈ [0, 1], fraction of the drift window) returns the
    linear ramp from ``from_mbps`` to ``to_mbps`` perturbed by a bounded,
    seed-deterministic jitter — the same seed always produces the same
    curve, which is what makes a chaos schedule replayable.
    """

    from_mbps: float
    to_mbps: float
    seed: int = 0
    jitter: float = 0.1            # max relative perturbation
    resolution: int = 64           # jitter sample points over [0, 1]

    def __post_init__(self):
        if self.from_mbps <= 0 or self.to_mbps <= 0:
            raise ValueError("bandwidth endpoints must be > 0")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(f"jitter must be in [0, 1), got {self.jitter}")
        rng = np.random.RandomState(self.seed)
        self._noise = rng.uniform(-1.0, 1.0, max(self.resolution, 2))

    def at(self, u: float) -> float:
        """Bandwidth (Mbps) at fraction ``u`` of the drift window."""
        u = min(max(float(u), 0.0), 1.0)
        base = self.from_mbps + (self.to_mbps - self.from_mbps) * u
        x = u * (len(self._noise) - 1)
        i = int(x)
        j = min(i + 1, len(self._noise) - 1)
        noise = self._noise[i] + (self._noise[j] - self._noise[i]) * (x - i)
        return max(base * (1.0 + self.jitter * noise), 1e-3)

    def sample(self, n: int):
        """``n`` evenly-spaced values over the window (drift events)."""
        return [self.at((i + 1) / n) for i in range(n)]
