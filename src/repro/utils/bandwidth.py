"""Shared EWMA bandwidth estimator.

The paper's runtime probes the link and alpha-blends observations into a
running estimate the policy queries.  The blend used to be duplicated in
``AdaptiveDispatcher.observe_bandwidth`` and ``InferenceSession`` (same
formula, two drifting copies); :class:`BandwidthEstimator` is now the one
implementation both consume — and the serving scheduler reads it too.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class BandwidthEstimator:
    """EWMA link-bandwidth estimate: ``bw ← α·obs + (1-α)·bw``."""

    initial_mbps: float = 400.0
    alpha: float = 0.3

    def __post_init__(self):
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {self.alpha}")
        self._mbps = float(self.initial_mbps)
        self._n = 0

    def observe(self, mbps: float) -> float:
        """Fold one observation in; returns the updated estimate."""
        self._mbps = self.alpha * float(mbps) + (1 - self.alpha) * self._mbps
        self._n += 1
        return self._mbps

    def observe_transfer(self, n_bytes: float, wall_ms: float) -> float:
        """Fold one *observed transfer* in: ``n_bytes`` moved in
        ``wall_ms`` implies a link bandwidth, EWMA-blended like a probe.
        This is how ``session.calibrate()`` refines the link estimate from
        per-dispatch bytes-on-wire telemetry; returns the implied Mbps."""
        if n_bytes <= 0 or wall_ms <= 0:
            raise ValueError(f"transfer needs positive bytes and wall "
                             f"(got {n_bytes} B / {wall_ms} ms)")
        mbps = n_bytes * 8e-3 / wall_ms        # bytes/ms → Mbit/s
        self.observe(mbps)
        return mbps

    def reset(self, mbps: float) -> None:
        """Pin the estimate (e.g. a fresh probe after a re-mesh)."""
        self._mbps = float(mbps)

    @property
    def mbps(self) -> float:
        return self._mbps

    @property
    def observations(self) -> int:
        return self._n
