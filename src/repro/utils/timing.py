"""Wall-clock timing helpers (block_until_ready-aware)."""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax


@dataclass
class Timer:
    """Accumulating timer; ``with timer.scope("x"): ...`` records wall time."""

    records: dict = field(default_factory=dict)

    def scope(self, name: str):
        return _Scope(self, name)

    def add(self, name: str, dt: float) -> None:
        self.records.setdefault(name, []).append(dt)

    def mean_ms(self, name: str) -> float:
        xs = self.records.get(name, [])
        return 1e3 * sum(xs) / max(len(xs), 1)


class _Scope:
    def __init__(self, timer: Timer, name: str):
        self.timer, self.name = timer, name

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.timer.add(self.name, time.perf_counter() - self.t0)
        return False


def timeit_jax(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-time (seconds) of ``fn(*args)`` with device sync."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
