"""Version compatibility helpers for the JAX APIs this repo leans on.

The container pins one JAX build; these helpers keep the launchers and tests
working across adjacent releases instead of AttributeError-ing on renamed
surface (e.g. ``jax.sharding.AxisType`` does not exist on 0.4.x — mesh axes
there are implicitly Auto under GSPMD, which is exactly what we ask for).
"""
from __future__ import annotations

import contextlib
from typing import Sequence, Tuple

import jax


def make_auto_mesh(shape: Sequence[int], names: Tuple[str, ...]):
    """``jax.make_mesh`` with explicitly-Auto axis types where the installed
    JAX supports them, plain (implicitly Auto) mesh otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(names),
                             axis_types=(axis_type.Auto,) * len(names))
    return jax.make_mesh(tuple(shape), tuple(names))


@contextlib.contextmanager
def set_mesh(mesh):
    """``jax.sharding.set_mesh`` where available; on 0.4.x fall back to the
    legacy ``with mesh:`` thread-resources context (which is what lets bare
    ``PartitionSpec`` sharding constraints and shard_map resolve a mesh)."""
    setter = getattr(jax.sharding, "set_mesh", None)
    if setter is not None:
        with setter(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def shard_map(fn, *, in_specs, out_specs, axis_names=frozenset(),
              check_vma=False):
    """``jax.shard_map`` (new API, mesh from context, ``axis_names`` manual
    subset) or 0.4.x ``jax.experimental.shard_map.shard_map`` (explicit
    mesh from the thread-resources context, ``auto`` = the complement of
    ``axis_names``, ``check_rep`` in place of ``check_vma``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, in_specs=in_specs, out_specs=out_specs,
                             axis_names=axis_names, check_vma=check_vma)
    from jax._src.mesh import thread_resources
    from jax.experimental.shard_map import shard_map as _shard_map
    mesh = thread_resources.env.physical_mesh
    if mesh.empty:
        raise RuntimeError("shard_map outside a mesh context: wrap the call "
                           "in repro.utils.compat.set_mesh(mesh)")
    # NOTE: partial-auto (`auto=`) shard_map on 0.4.x trips an XLA SPMD
    # partitioner check ("IsManualSubgroup" mismatch) when combined with
    # sharding constraints, so run fully manual: axes absent from the specs
    # are replicated into every shard, which is numerically identical (each
    # rank of a non-exchange axis computes the same value).
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


# On 0.4.x, a with_sharding_constraint layout hint on an activation that
# later feeds plain (non-shard_map) ops can CHANGE VALUES (observed: ~0.45
# max-abs drift on a 1-layer reduced llama under `with mesh:`). The hints
# are purely a GSPMD layout nudge, so they are skipped entirely on
# installs without the modern mesh API.
SHARDING_HINTS_SAFE = hasattr(jax.sharding, "set_mesh")


def get_abstract_mesh():
    """``jax.sharding.get_abstract_mesh`` or the 0.4.x thread-resources
    physical mesh (both expose ``.empty`` / ``.shape`` / ``.axis_names``).
    Returns None when no mesh context is active and neither API exists."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    from jax._src.mesh import thread_resources
    mesh = thread_resources.env.physical_mesh
    return None if mesh.empty else mesh
