from repro.train.optimizer import AdamWState, adamw_init, adamw_update
from repro.train.train_step import build_train_step, loss_fn

__all__ = ["AdamWState", "adamw_init", "adamw_update", "build_train_step",
           "loss_fn"]
