"""Loss and train-step builders (pjit-ready, donated, remat inside models).

The forward already scans layers under ``jax.checkpoint``; the step adds
cross-entropy over the (possibly vocab-sharded) logits, MoE aux losses, and
the AdamW update. Gradient compression over the slow (DCN/pod) axis —
the paper's Segment-Means idea applied to training comms — is an optional
hook (``grad_compress``): gradients are reduced normally over the fast axes
by GSPMD, while the hook row-compresses what crosses pods.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.exchange import ExchangeConfig
from repro.models import registry
from repro.train.optimizer import OptConfig, adamw_init, adamw_update


def _pin_vocab(t: jnp.ndarray, xcfg: ExchangeConfig) -> jnp.ndarray:
    """Pin the trailing vocab dim of [B, N, V] to the axis the embedding
    tables use in distributed modes (`data` — see sharding/specs.py): the
    one-hot iota otherwise materializes unsharded-V and drags the logits,
    their cotangent, and the [D, V] table-grad partials to full V."""
    if xcfg.seq_axis is None or not xcfg.batch_axes:
        return t
    try:
        from jax.sharding import PartitionSpec as P
        from repro.utils import compat
        if not compat.SHARDING_HINTS_SAFE:   # 0.4.x: hint can corrupt values
            return t
        mesh = compat.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return t
        vax = next((a for a in xcfg.batch_axes[::-1]
                    if a in mesh.axis_names
                    and t.shape[-1] % mesh.shape[a] == 0), None)
        if vax is None:
            return t
        # keep the batch dim sharded on the remaining batch axes — pinning
        # only V lets propagation fall back to batch-replicated logits
        rem = tuple(a for a in xcfg.batch_axes
                    if a in mesh.axis_names and a != vax)
        bsz = 1
        for a in rem:
            bsz *= mesh.shape[a]
        b_spec = rem if (rem and t.shape[0] % bsz == 0) else P.UNCONSTRAINED
        spec = P(b_spec, *([P.UNCONSTRAINED] * (t.ndim - 2)), vax)
        return jax.lax.with_sharding_constraint(t, spec)
    except (ValueError, RuntimeError, AttributeError, TypeError):
        return t


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            xcfg: ExchangeConfig):
    """Next-token cross-entropy (causal LMs) in f32 with z-loss."""
    logits, aux = registry.forward_fn(cfg)(params, batch, xcfg)
    labels = batch["labels"]
    logits = _pin_vocab(logits, xcfg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: reduces over the vocab
    # dim with a partial-sum (+psum when V is sharded) under GSPMD instead of
    # forcing a replicating gather.
    onehot = _pin_vocab(jax.nn.one_hot(labels, logits.shape[-1],
                                       dtype=logits.dtype), xcfg)
    gold = jnp.einsum("bnv,bnv->bn", logits, onehot)
    nll = (logz - gold).mean()
    zloss = 1e-4 * jnp.square(logz).mean()
    return nll + zloss + aux, {"nll": nll, "aux": aux}


def build_train_step(cfg: ModelConfig, xcfg: ExchangeConfig,
                     opt_cfg: Optional[OptConfig] = None,
                     grad_accum: int = 1,
                     acc_shardings=None,
                     acc_dtype=jnp.float32) -> Callable:
    """(params, opt_state, batch) → (params, opt_state, metrics).

    ``grad_accum`` > 1 splits the global batch into microbatches scanned
    sequentially with an f32 gradient accumulator — the standard
    memory/throughput trade at large batch: live activations shrink by the
    accumulation factor while keeping the global batch size.
    ``acc_shardings`` (a params-shaped tree of shardings, normally the ZeRO-1
    optimizer-state specs) keeps the f32 accumulator maximally sharded.
    """
    opt_cfg = opt_cfg or OptConfig()

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, xcfg), has_aux=True)(params)

    def pin_acc(tree):
        if acc_shardings is None:
            return tree
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, tree, acc_shardings)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, parts), grads = grads_of(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda t: t.reshape(grad_accum, t.shape[0] // grad_accum,
                                    *t.shape[1:]), batch)

            def mb(acc, mbatch):
                (l, parts), g = grads_of(params, mbatch)
                acc = pin_acc(jax.tree_util.tree_map(
                    lambda a, gi: a + gi.astype(acc_dtype), acc, g))
                return acc, (l, parts)

            zeros = pin_acc(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params))
            gacc, (ls, partss) = jax.lax.scan(mb, zeros, micro)
            # keep acc_dtype here: adamw casts per-leaf (transient), a
            # whole-tree astype would materialize a full f32 copy
            grads = jax.tree_util.tree_map(lambda a: a / grad_accum, gacc)
            loss = ls.mean()
            parts = jax.tree_util.tree_map(lambda t: t.mean(), partss)
        new_params, new_state, om = adamw_update(grads, opt_state, params,
                                                 opt_cfg)
        metrics = {"loss": loss, **parts, **om}
        return new_params, new_state, metrics

    return train_step


def build_eval_step(cfg: ModelConfig, xcfg: ExchangeConfig) -> Callable:
    def eval_step(params, batch):
        loss, parts = loss_fn(params, batch, cfg, xcfg)
        return {"loss": loss, **parts}
    return eval_step
