"""Segment-Means gradient compression for the slow (DCN / pod) axis.

The paper's insight — compress what crosses the slow, volume-proportional
link — applied to training communication: gradients are reduced normally
over the fast ICI axes by GSPMD, while the cross-pod reduction exchanges
only L row-segment means per matrix (the same Eq. (1) operator used for
activations), shrinking DCN bytes by rows/L.

Lossy compression needs **error feedback** to keep SGD unbiased over time
(Seide et al. '14; Karimireddy et al. '19): each pod keeps the local
residual ``g - decompress(compress(g))`` and adds it to the next step's
gradient before compressing, so all gradient mass is eventually
transmitted. ``tests/test_grad_compress.py`` verifies the telescoping-sum
property exactly.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def compress(g: jnp.ndarray, L: int) -> jnp.ndarray:
    """Row-segment means of the leading dim: [r, ...] → [L, ...] (f32)."""
    r = g.shape[0]
    if L >= r or r % L:
        return g.astype(jnp.float32)
    seg = r // L
    return g.reshape(L, seg, *g.shape[1:]).astype(jnp.float32).mean(axis=1)


def decompress(z: jnp.ndarray, r: int) -> jnp.ndarray:
    """Broadcast L row means back to r rows (transpose of ``compress`` up to
    the 1/seg scale — each row receives its segment's mean)."""
    L = z.shape[0]
    if L >= r:
        return z
    seg = r // L
    return jnp.repeat(z, seg, axis=0)


def compress_with_feedback(g: jnp.ndarray, residual: Optional[jnp.ndarray],
                           L: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(gradient, carried residual) → (compressed payload, new residual).

    payload = compress(g + residual); new residual = (g + residual) −
    decompress(payload): exactly the mass the wire did NOT carry.
    """
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual
    z = compress(gf, L)
    new_res = gf - decompress(z, g.shape[0]).astype(jnp.float32)
    return z, new_res


def compressed_cross_pod_mean(grads: Any, residuals: Any, L: int,
                              pod_axis: str = "pod"):
    """Mean-reduce a gradient pytree across pods with Segment-Means payloads.

    Call INSIDE a manual region over ``pod_axis`` (shard_map), after the
    fast-axis reductions: every leaf with a compressible leading dim sends
    ``L/r`` of its bytes over DCN; error feedback keeps the update unbiased
    over steps. Returns (reduced grads, new residuals).
    """
    def one(g, res):
        if g.ndim < 2 or g.shape[0] % max(L, 1) or g.shape[0] <= L:
            return jax.lax.pmean(g.astype(jnp.float32), pod_axis), res
        z, new_res = compress_with_feedback(g, res, L)
        z = jax.lax.pmean(z, pod_axis)
        return decompress(z, g.shape[0]), new_res

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = (treedef.flatten_up_to(residuals) if residuals is not None
              else [None] * len(flat_g))
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] if o[1] is not None else
                               jnp.zeros_like(o[0]) for o in out]))


def init_residuals(grads: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compression_ratio(r: int, L: int) -> float:
    """DCN byte reduction for a leading dim of r rows."""
    return r / L if (L < r and r % L == 0) else 1.0
