"""Training loop wiring: data cursor + fault-tolerant driver + checkpoints.

The inner step is the pjit'd train_step from train_step.py; this module adds
the deterministic data cursor (seed ⊕ step → batch), checkpoint cadence and
the heartbeat hook so the FaultTolerantLoop can restart it bit-exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.core.exchange import ExchangeConfig
from repro.data.pipeline import SyntheticLMDataset
from repro.models import registry
from repro.runtime.fault import FaultTolerantLoop, HeartbeatMonitor
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import build_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 200
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    log_every: int = 10
    batch_size: int = 8
    seq_len: int = 128


class Trainer:
    """Single-host trainer used by examples/ and tests (same step code the
    launcher shards over the production mesh)."""

    def __init__(self, cfg: ModelConfig, xcfg: ExchangeConfig,
                 tcfg: TrainerConfig = TrainerConfig(),
                 opt_cfg: Optional[OptConfig] = None):
        self.cfg, self.xcfg, self.tcfg = cfg, xcfg, tcfg
        self.params = registry.init_params(cfg, seed=tcfg.seed)
        self.opt_state = adamw_init(self.params)
        self.step_fn = jax.jit(build_train_step(cfg, xcfg, opt_cfg),
                               donate_argnums=(0, 1))
        self.ds = SyntheticLMDataset(cfg.vocab_size, tcfg.seq_len,
                                     tcfg.batch_size, seed=tcfg.seed)
        self.metrics_log: list = []

    def batch_for_step(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.RandomState(self.tcfg.seed * 100003 + step)
        b = self.ds.sample(rng)
        return {k: jnp.asarray(v) for k, v in b.items()}

    def run(self, n_steps: Optional[int] = None, fail_at=None):
        n = n_steps or self.tcfg.steps
        ckpt = CheckpointManager(self.tcfg.ckpt_dir, keep=2)
        monitor = HeartbeatMonitor(["host0"])

        def step_fn(state, batch):
            params, opt = state
            params, opt, m = self.step_fn(params, opt, batch)
            self.metrics_log.append({k: float(v) for k, v in m.items()})
            return (params, opt), m

        loop = FaultTolerantLoop(step_fn, self.batch_for_step, ckpt, monitor,
                                 ckpt_every=self.tcfg.ckpt_every)
        (self.params, self.opt_state), step = loop.run(
            (self.params, self.opt_state), 0, n, fail_at=fail_at)
        return step
