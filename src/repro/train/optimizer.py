"""Hand-rolled sharded AdamW (+ cosine schedule, global-norm clipping).

State is a pytree mirroring the params (m, v in f32) and shards under the
ZeRO-1 specs from ``repro.sharding.opt_state_shardings``. No optax
dependency — the update is four tree_maps and jits/shards cleanly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # scalar int32
    m: Any                     # first moment  (f32 pytree)
    v: Any                     # second moment (f32 pytree)


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    """``moment_dtype=bfloat16`` halves optimizer HBM (DeepSpeed-style
    low-precision moments; the update math still runs in f32)."""
    zeros = lambda t: jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, moment_dtype), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(params),
                      v=zeros(params))


def schedule(step, cfg: OptConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def adamw_update(grads, state: AdamWState, params, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(step, cfg)

    def upd_core(g, m, v, p, ndim):
        mdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if ndim >= 2:          # decoupled decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    def upd(g, m, v, p):
        return upd_core(g, m, v, p, p.ndim)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
