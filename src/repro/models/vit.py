"""ViT encoder — the paper's evaluation workload (ViT-B/16, CIFAR-10 at
224², N = 197 tokens). Bidirectional attention with the PRISM / Voltage /
local exchange threaded through every block, exactly as the prototype
distributes it; the classifier head reads the CLS token.

Sequence padding: 197 is not divisible by P partitions, so tokens are padded
to ``pad_len(197, P, L)`` and the pads are excluded via the mask-aware
segment means (exact — zero probability mass on pads).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.exchange import ExchangeConfig, exchange_attention
from repro.models.layers import (apply_mlp, apply_norm, dense_init, init_mlp,
                                 init_norm, project_qkv)
from repro.models.transformer import _attn_spec, _stack, pad_len

Params = Dict[str, Any]

PATCH = 16
IMAGE = 224
N_PATCHES = (IMAGE // PATCH) ** 2          # 196
N_TOKENS = N_PATCHES + 1                   # + CLS = 197


def init_vit(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    d, dtype = cfg.d_model, cfg.jdtype
    patch_dim = PATCH * PATCH * 3

    def layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": init_norm(cfg.norm_type, d),
                "attn": {
                    "wq": dense_init(jax.random.fold_in(k1, 0), d, d, dtype),
                    "wk": dense_init(jax.random.fold_in(k1, 1), d, d, dtype),
                    "wv": dense_init(jax.random.fold_in(k1, 2), d, d, dtype),
                    "wo": dense_init(jax.random.fold_in(k1, 3), d, d, dtype)},
                "ln2": init_norm(cfg.norm_type, d),
                "mlp": init_mlp(k2, d, cfg.d_ff, dtype, gated=False)}

    return {
        "patch_embed": dense_init(ks[0], patch_dim, d, dtype),
        "patch_bias": jnp.zeros((d,), dtype),
        "cls": (jax.random.normal(ks[1], (1, 1, d), jnp.float32) * 0.02
                ).astype(dtype),
        "pos": (jax.random.normal(ks[2], (1, N_TOKENS, d), jnp.float32) * 0.02
                ).astype(dtype),
        "layers": _stack(layer, ks[3], cfg.n_layers),
        "final_norm": init_norm(cfg.norm_type, d),
        "head": dense_init(ks[4], d, cfg.vocab_size, dtype, scale=0.02),
        "head_bias": jnp.zeros((cfg.vocab_size,), dtype),
    }


def patchify(images: jnp.ndarray) -> jnp.ndarray:
    """[B, 224, 224, 3] → [B, 196, 768] raw patch vectors."""
    B = images.shape[0]
    g = IMAGE // PATCH
    x = images.reshape(B, g, PATCH, g, PATCH, 3)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, N_PATCHES, PATCH * PATCH * 3)


def forward_vit(params: Params, images: jnp.ndarray, cfg: ModelConfig,
                xcfg: ExchangeConfig) -> jnp.ndarray:
    """[B, 224, 224, 3] → class logits [B, n_classes]."""
    B = images.shape[0]
    x = patchify(images.astype(cfg.jdtype)) @ params["patch_embed"]
    x = x + params["patch_bias"]
    x = jnp.concatenate([jnp.broadcast_to(params["cls"], (B, 1, x.shape[-1])),
                         x], axis=1)
    x = x + params["pos"]

    # pad so every partition divides into L integer segments
    N = pad_len(N_TOKENS, max(xcfg.seq_shards, 1), max(xcfg.L, 1))
    x = jnp.pad(x, ((0, 0), (0, N - N_TOKENS), (0, 0)))
    kv_mask = jnp.broadcast_to(jnp.arange(N)[None] < N_TOKENS, (B, N))

    spec = _attn_spec(cfg, causal=False, use_rope=False)

    def body(xc, lp):
        xin = apply_norm(cfg.norm_type, lp["ln1"], xc)
        q, k, v = project_qkv(lp["attn"], xin, spec, None)
        h = exchange_attention(q, k, v, xcfg, causal=False, kv_mask=kv_mask)
        h = h.reshape(B, N, -1) @ lp["attn"]["wo"]
        xc = xc + h
        h2 = apply_mlp(lp["mlp"], apply_norm(cfg.norm_type, lp["ln2"], xc),
                       cfg.act)
        return xc + h2, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["layers"])
    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    cls = x[:, 0]
    return (cls @ params["head"] + params["head_bias"]).astype(jnp.float32)
