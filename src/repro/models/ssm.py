"""State-space / recurrent sequence mixers: Mamba (hymba) and xLSTM blocks.

PRISM inapplicability (DESIGN.md §4): these paths have no softmax attention
to feed segment means into. Sequence distribution instead uses *state
hand-off*: the inter-device object is the recurrent state (independent of
sequence length — already maximally "compressed"), exchanged once per block
via an exclusive prefix scan over the sequence axis
(``jax.lax.associative_scan``-style, here a P-step ``ppermute`` chain since P
is small and states are tiny).

Forms implemented per mixer:
  * ``*_scan``  — full-sequence (train / prefill), ``lax.scan`` over time:
    compiles to a compact while-loop; the chunked Pallas formulation is the
    hillclimb target (EXPERIMENTS.md §Perf).
  * ``*_step``  — single-token decode with O(1) carried state.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMCfg
from repro.models.layers import dense_init, init_norm, apply_norm

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Mamba (selective SSM) — hymba's parallel-SSM head path
# ---------------------------------------------------------------------------

def init_mamba(key, d: int, cfg: SSMCfg, dtype, d_inner: Optional[int] = None
               ) -> Params:
    di = d_inner or cfg.expand * d
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], d, 2 * di, dtype),
        "conv": (jax.random.normal(ks[1], (cfg.conv_width, di), jnp.float32)
                 * (cfg.conv_width ** -0.5)).astype(dtype),
        "w_bc": dense_init(ks[2], di, 2 * cfg.state_size, dtype),
        "w_dt": dense_init(ks[3], di, di, dtype, scale=d ** -0.5),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, cfg.state_size + 1,
                                             dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[4], di, d, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 carry: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over time. x: [B, N, di]; w: [W, di]."""
    W = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return out, xp[:, -(W - 1):, :]


def _mamba_inner(params, x, cfg: SSMCfg):
    """Shared projections; returns (xc, z, dt, B_in, C_in)."""
    di = params["d_skip"].shape[0]
    xz = x @ params["w_in"]
    xs, z = xz[..., :di], xz[..., di:]
    xc, conv_carry = _causal_conv(xs, params["conv"])
    xc = jax.nn.silu(xc)
    bc = xc @ params["w_bc"]
    B_in, C_in = bc[..., :cfg.state_size], bc[..., cfg.state_size:]
    dt = jax.nn.softplus((xc @ params["w_dt"]).astype(jnp.float32)
                         + params["dt_bias"])
    return xc, z, dt, B_in, C_in, conv_carry


def chunked_time_scan(step, state0, xs_time, chunk: int):
    """Two-level time scan: outer scan over chunks with ``jax.checkpoint``
    (backward stores the recurrent state only at chunk boundaries and
    recomputes inside) — without this, reverse-mode through a T-step scan
    saves T copies of the state (e.g. xLSTM's [B,H,dh,dh] matrix memory →
    hundreds of GB at T=4096).

    xs_time: pytree with leading time axis T (T % chunk == 0 expected;
    falls back to a single plain scan otherwise)."""
    T = jax.tree_util.tree_leaves(xs_time)[0].shape[0]
    if chunk <= 1 or T % chunk or T <= chunk:
        return jax.lax.scan(step, state0, xs_time)
    n = T // chunk
    xs_c = jax.tree_util.tree_map(
        lambda t: t.reshape(n, chunk, *t.shape[1:]), xs_time)

    @jax.checkpoint
    def outer(state, xs_chunk):
        state, ys = jax.lax.scan(step, state, xs_chunk)
        return state, ys

    state, ys_c = jax.lax.scan(outer, state0, xs_c)
    ys = jax.tree_util.tree_map(
        lambda t: t.reshape(T, *t.shape[2:]), ys_c)
    return state, ys


def mamba_scan(params: Params, x: jnp.ndarray, cfg: SSMCfg,
               h0: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence selective scan. x: [B, N, D] → (y: [B, N, D], h_N)."""
    B, N, D = x.shape
    di = params["d_skip"].shape[0]
    xc, z, dt, B_in, C_in, _ = _mamba_inner(params, x, cfg)
    A = -jnp.exp(params["a_log"])                       # [di, S] (negative)

    def step(h, inp):
        xc_t, dt_t, b_t, c_t = inp
        dA = jnp.exp(dt_t[:, :, None] * A[None])        # [B, di, S]
        dBx = dt_t[:, :, None] * b_t[:, None, :] * xc_t.astype(jnp.float32)[:, :, None]
        h = dA * h + dBx
        y = jnp.einsum("bds,bs->bd", h, c_t)            # [B, di]
        return h, y

    h0 = jnp.zeros((B, di, cfg.state_size), jnp.float32) if h0 is None else h0
    xs = (xc.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          B_in.transpose(1, 0, 2).astype(jnp.float32),
          C_in.transpose(1, 0, 2).astype(jnp.float32))
    h_final, ys = chunked_time_scan(step, h0, xs, cfg.chunk)
    y = ys.transpose(1, 0, 2).astype(x.dtype)           # [B, N, di]
    y = y + xc * params["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ params["w_out"], h_final


def mamba_step(params: Params, x: jnp.ndarray, cfg: SSMCfg,
               state: Dict[str, jnp.ndarray]
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token decode. x: [B, 1, D]; state: {"h": [B,di,S], "conv": ...}."""
    di = params["d_skip"].shape[0]
    xz = x @ params["w_in"]
    xs, z = xz[..., :di], xz[..., di:]
    xc, conv_carry = _causal_conv(xs, params["conv"], state["conv"])
    xc = jax.nn.silu(xc)
    bc = xc @ params["w_bc"]
    B_in, C_in = bc[..., :cfg.state_size], bc[..., cfg.state_size:]
    dt = jax.nn.softplus((xc @ params["w_dt"]).astype(jnp.float32)
                         + params["dt_bias"])
    A = -jnp.exp(params["a_log"])
    dA = jnp.exp(dt[:, 0, :, None] * A[None])
    dBx = (dt[:, 0, :, None] * B_in.astype(jnp.float32)[:, 0, None, :]
           * xc.astype(jnp.float32)[:, 0, :, None])
    h = dA * state["h"] + dBx
    y = jnp.einsum("bds,bs->bd", h, C_in.astype(jnp.float32)[:, 0])[:, None, :]
    y = y.astype(x.dtype) + xc * params["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ params["w_out"], {"h": h, "conv": conv_carry}


def init_mamba_state(batch: int, d: int, cfg: SSMCfg, dtype,
                     d_inner: Optional[int] = None):
    di = d_inner or cfg.expand * d
    return {"h": jnp.zeros((batch, di, cfg.state_size), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, di), dtype)}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM's matrix-memory cell)
# ---------------------------------------------------------------------------

def init_mlstm(key, d: int, cfg: SSMCfg, dtype) -> Params:
    H = cfg.mlstm_heads
    dh = int(d * cfg.proj_factor) // H
    di = H * dh
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], d, 2 * di, dtype),
        "w_q": dense_init(ks[1], di, di, dtype),
        "w_k": dense_init(ks[2], di, di, dtype),
        "w_v": dense_init(ks[3], di, di, dtype),
        "w_if": dense_init(ks[4], di, 2 * H, dtype, scale=0.02),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]
                                ).astype(jnp.float32),
        "gn_scale": jnp.ones((di,), jnp.float32),
        "w_down": dense_init(ks[5], di, d, dtype),
    }


def mlstm_scan(params: Params, x: jnp.ndarray, cfg: SSMCfg,
               state0: Optional[Dict[str, jnp.ndarray]] = None):
    """Full-sequence mLSTM. x: [B, N, D] → (y, final_state).

    Stabilized exponential gating (Beck et al. 2024): m tracks the running
    max of (f̃ + m_prev, ĩ); C, n are rescaled accordingly.
    """
    B, N, D = x.shape
    H = cfg.mlstm_heads
    di = params["w_q"].shape[0]
    dh = di // H
    up = x @ params["w_up"]
    xin, z = up[..., :di], up[..., di:]
    q = (xin @ params["w_q"]).reshape(B, N, H, dh) * (dh ** -0.5)
    k = (xin @ params["w_k"]).reshape(B, N, H, dh) * (dh ** -0.5)
    v = (xin @ params["w_v"]).reshape(B, N, H, dh)
    gates = (xin @ params["w_if"]).astype(jnp.float32) + params["b_if"]
    i_pre, f_pre = gates[..., :H], gates[..., H:]       # [B, N, H]

    def step(carry, inp):
        C, n, m = carry                                  # [B,H,dh,dh],[B,H,dh],[B,H]
        q_t, k_t, v_t, i_t, f_t = inp
        logf = -jax.nn.softplus(-f_t)                    # log σ(f)
        m_new = jnp.maximum(logf + m, i_t)
        fg = jnp.exp(logf + m - m_new)                   # [B,H]
        ig = jnp.exp(i_t - m_new)
        kf = k_t.astype(jnp.float32)
        vf = v_t.astype(jnp.float32)
        C = fg[..., None, None] * C + ig[..., None, None] * (
            kf[..., :, None] * vf[..., None, :])         # [B,H,dh,dh]
        n = fg[..., None] * n + ig[..., None] * kf
        qf = q_t.astype(jnp.float32)
        num = jnp.einsum("bhd,bhde->bhe", qf, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                          jnp.exp(-m_new))[..., None]
        return (C, n, m_new), (num / den)

    if state0 is None:
        state0 = init_mlstm_state(B, D, cfg)
    carry0 = (state0["C"], state0["n"], state0["m"])
    xs = tuple(t.transpose(1, 0, 2, 3) for t in (q, k, v)) + (
        i_pre.transpose(1, 0, 2), f_pre.transpose(1, 0, 2))
    (C, n, m), ys = chunked_time_scan(step, carry0, xs, cfg.chunk)
    h = ys.transpose(1, 0, 2, 3).reshape(B, N, di)       # [B, N, di]
    h = _groupnorm_heads(h, H, params["gn_scale"]).astype(x.dtype)
    y = (h * jax.nn.silu(z)) @ params["w_down"]
    return y, {"C": C, "n": n, "m": m}


def _groupnorm_heads(h: jnp.ndarray, H: int, scale: jnp.ndarray):
    """Per-head RMS-style groupnorm used by xLSTM after the cell."""
    B, N, di = h.shape
    hh = h.reshape(B, N, H, di // H).astype(jnp.float32)
    var = jnp.mean(jnp.square(hh), axis=-1, keepdims=True)
    hh = hh * jax.lax.rsqrt(var + 1e-6)
    return (hh.reshape(B, N, di) * scale)


def mlstm_step(params: Params, x: jnp.ndarray, cfg: SSMCfg,
               state: Dict[str, jnp.ndarray]):
    """Single-token decode — same math as one scan step."""
    y, new_state = mlstm_scan(params, x, cfg, state0=state)
    return y, new_state


def init_mlstm_state(batch: int, d: int, cfg: SSMCfg):
    H = cfg.mlstm_heads
    dh = int(d * cfg.proj_factor) // H
    return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM's scalar-memory cell with recurrent mixing)
# ---------------------------------------------------------------------------

def init_slstm(key, d: int, cfg: SSMCfg, dtype) -> Params:
    H = cfg.mlstm_heads
    dh = d // H
    ks = jax.random.split(key, 4)
    # input weights for (i, f, z, o); block-diagonal recurrent weights per head
    return {
        "w_x": dense_init(ks[0], d, 4 * d, dtype),
        "r": (jax.random.normal(ks[1], (4, H, dh, dh), jnp.float32)
              * (dh ** -0.5)).astype(dtype),
        "b": jnp.concatenate([jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
                              jnp.zeros((2 * d,))]).astype(jnp.float32),
        "gn_scale": jnp.ones((d,), jnp.float32),
        "w_up": dense_init(ks[2], d, int(d * 4 / 3) * 2, dtype),
        "w_down": dense_init(ks[3], int(d * 4 / 3), d, dtype),
    }


def slstm_scan(params: Params, x: jnp.ndarray, cfg: SSMCfg,
               state0: Optional[Dict[str, jnp.ndarray]] = None):
    """Strictly-sequential sLSTM over time. x: [B, N, D] → (y, state)."""
    B, N, D = x.shape
    H = cfg.mlstm_heads
    dh = D // H
    wx = (x @ params["w_x"]).astype(jnp.float32)         # [B, N, 4D]

    def step(carry, wx_t):
        c, n, h, m = carry                               # all [B, D] (+m)
        hh = h.reshape(B, H, dh)
        rec = jnp.stack([
            jnp.einsum("bhd,hde->bhe", hh, params["r"][g].astype(jnp.float32))
            for g in range(4)], axis=1).reshape(B, 4 * D)
        pre = wx_t + rec + params["b"]
        i_p, f_p, z_p, o_p = jnp.split(pre, 4, axis=-1)
        logf = -jax.nn.softplus(-f_p)
        m_new = jnp.maximum(logf + m, i_p)
        ig = jnp.exp(i_p - m_new)
        fg = jnp.exp(logf + m - m_new)
        c = fg * c + ig * jnp.tanh(z_p)
        n = fg * n + ig
        h_new = jax.nn.sigmoid(o_p) * c / jnp.maximum(n, 1.0)
        return (c, n, h_new, m_new), h_new

    if state0 is None:
        state0 = init_slstm_state(B, D)
    carry0 = (state0["c"], state0["n"], state0["h"], state0["m"])
    (c, n, h, m), ys = chunked_time_scan(step, carry0, wx.transpose(1, 0, 2),
                                         cfg.chunk)
    hseq = ys.transpose(1, 0, 2)                         # [B, N, D] f32
    hseq = _groupnorm_heads(hseq, H, params["gn_scale"]).astype(x.dtype)
    # post-cell gated FFN (proj factor 4/3)
    du = params["w_down"].shape[0]
    up = hseq @ params["w_up"]
    y = (jax.nn.gelu(up[..., :du]) * up[..., du:]) @ params["w_down"]
    return y, {"c": c, "n": n, "h": h, "m": m}


def slstm_step(params: Params, x: jnp.ndarray, cfg: SSMCfg,
               state: Dict[str, jnp.ndarray]):
    return slstm_scan(params, x, cfg, state0=state)


def init_slstm_state(batch: int, d: int):
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -1e30, jnp.float32)}
