"""Mixture-of-Experts FFN (DeepSeek-style: fine-grained routed + shared).

Dispatch is the capacity-based einsum formulation (MaxText-style) because it
shards cleanly under GSPMD: the dispatch tensor ``[G, S, E, C]`` carries the
``G`` (batch-group) dim on the data axis and the ``E`` (expert) dim on the
model axis, so the big intermediates ``[G, E, C, ...]`` are 2-D sharded and
the expert matmuls are fully local; the only collective is the combine-side
reduction over E (one all-reduce / reduce-scatter per MoE layer).

Router: softmax over routed experts, top-k, probabilities renormalized over
the selected k (DeepSeek convention); shared experts always execute. The
load-balance auxiliary loss (Switch-style f·p) is returned for training.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoECfg
from repro.models.layers import _act, dense_init, init_mlp, apply_mlp

Params = Dict[str, Any]


def expert_capacity(tokens_per_group: int, cfg: MoECfg) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor // cfg.n_experts)
    return max(c, 1)


def init_moe(key, d: int, cfg: MoECfg, dtype) -> Params:
    ks = jax.random.split(key, 5)
    E, f = cfg.n_experts, cfg.d_ff_expert
    scale = d ** -0.5
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32, scale=0.02),
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32)
                   * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32)
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32)
                   * (f ** -0.5)).astype(dtype),
    }
    if cfg.n_shared:
        p["shared"] = init_mlp(ks[4], d, cfg.n_shared * f, dtype)
    return p


GROUP_TOKENS = 4096      # re-group long sequences so capacity (∝S) stays sane


def _pin_expert(t: jnp.ndarray) -> jnp.ndarray:
    """Pin dim 1 (the expert dim of [G, E, C, ...]) to the `model` axis.

    In sequence-distributed modes GSPMD sometimes resolves the expert
    einsums by REPLICATING the expert weight stack (f32!) instead of
    keeping E sharded — 10 GB/device for DeepSeek-V2. Pinning the
    activation side forces the expert-parallel schedule."""
    try:
        from repro.utils import compat
        if not compat.SHARDING_HINTS_SAFE:   # 0.4.x: hint can corrupt values
            return t
        mesh = compat.get_abstract_mesh()
        if (mesh is None or mesh.empty or "model" not in mesh.axis_names
                or t.shape[1] % mesh.shape["model"]):
            return t
        from jax.sharding import PartitionSpec as P
        U = P.UNCONSTRAINED
        return jax.lax.with_sharding_constraint(
            t, P(U, "model", *([U] * (t.ndim - 2))))
    except (ValueError, RuntimeError, AttributeError, TypeError):
        return t


def apply_moe(params: Params, x: jnp.ndarray, cfg: MoECfg,
              act: str = "silu") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [G, S, D] → (y: [G, S, D], aux_loss scalar).

    Long sequences are re-grouped to ~GROUP_TOKENS tokens per group: the
    dispatch tensors scale as [G, S, E, C] with C ∝ S, so a 32k sequence in
    one group costs 64× the HBM of eight 4k groups."""
    G0, S0, D0 = x.shape
    if S0 > GROUP_TOKENS and S0 % GROUP_TOKENS == 0:
        f = S0 // GROUP_TOKENS
        y, aux = apply_moe(params,
                           x.reshape(G0 * f, GROUP_TOKENS, D0), cfg, act)
        return y.reshape(G0, S0, D0), aux
    G, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = expert_capacity(S, cfg)

    logits = (x.astype(jnp.float32) @ params["router"])      # [G, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                   # [G, S, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity buffer
    sel = jax.nn.one_hot(top_i, E, dtype=jnp.float32)        # [G, S, k, E]
    mask = sel.reshape(G, S * k, E)
    pos = (jnp.cumsum(mask, axis=1) - 1.0) * mask            # [G, S*k, E]
    pos = pos.reshape(G, S, k, E)
    fits = (pos < C) & (sel > 0)

    # dispatch / combine tensors — [G, S, E, C]; E goes on the model axis
    oh_pos = jax.nn.one_hot(pos.max(-1), C, dtype=jnp.float32)   # [G, S, k, C]
    disp = jnp.einsum("gske,gskc->gsec", sel * fits, oh_pos)
    comb = jnp.einsum("gske,gskc->gsec", sel * fits * top_p[..., None], oh_pos)

    xe = _pin_expert(jnp.einsum("gsec,gsd->gecd", disp.astype(x.dtype), x))
    h = _pin_expert(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"]))
    u = _pin_expert(jnp.einsum("gecd,edf->gecf", xe, params["w_up"]))
    h = _act(h, act) * u
    ye = _pin_expert(jnp.einsum("gecf,efd->gecd", h, params["w_down"]))
    y = jnp.einsum("gsec,gecd->gsd", comb.astype(x.dtype), ye)   # [G,S,D]

    if cfg.n_shared and "shared" in params:
        y = y + apply_mlp(params["shared"], x, act)

    # Switch-style load balance: E * Σ_e f_e · p_e
    frac = sel.sum(axis=2).mean(axis=(0, 1))                     # f_e [E]
    mean_p = probs.mean(axis=(0, 1))                             # p_e [E]
    aux = cfg.router_aux_weight * E * jnp.sum(frac * mean_p)
    return y, aux
