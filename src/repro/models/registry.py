"""Config → model builder + abstract input specs for every (arch × shape).

``input_specs(cfg, shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for
every model input (weak-type-correct, shardable, no device allocation) —
the dry-run lowers against these. Modality frontends are STUBS per the
brief: whisper gets precomputed frame embeddings, the VLM gets projected
patch embeddings.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import transformer as tfm
from repro.models import vit as vit_mod

ShapeStruct = jax.ShapeDtypeStruct


def init_fn(cfg: ModelConfig) -> Callable:
    if cfg.family == "vit":
        return lambda key: vit_mod.init_vit(key, cfg)
    return lambda key: tfm.init_lm(key, cfg)


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating anything."""
    return jax.eval_shape(init_fn(cfg), jax.random.key(0))


def init_params(cfg: ModelConfig, seed: int = 0):
    return init_fn(cfg)(jax.random.key(seed))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract inputs for (arch × shape): train/prefill take full sequences;
    decode takes one new token + the cache is built separately."""
    B, N = shape.global_batch, shape.seq_len
    tok = jnp.int32

    if cfg.family == "vit":
        return {"images": ShapeStruct((B, vit_mod.IMAGE, vit_mod.IMAGE, 3),
                                      jnp.float32)}

    if shape.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {"tokens": ShapeStruct((B, N), tok)}
        if shape.kind == "train":
            specs["labels"] = ShapeStruct((B, N), tok)
        if cfg.family == "audio":
            specs["frames"] = ShapeStruct((B, cfg.encoder_seq, cfg.d_model),
                                          cfg.jdtype)
        if cfg.family == "vlm":
            specs["image_embeds"] = ShapeStruct((B, cfg.image_tokens,
                                                 cfg.d_model), cfg.jdtype)
        return specs

    # decode: one new token against a cache of length N
    return {"tokens": ShapeStruct((B, 1), tok)}


def abstract_cache(cfg: ModelConfig, shape: ShapeSpec, xcfg=None):
    """Abstract decode cache for (arch × shape) — scan-stacked layout."""
    B, S = shape.global_batch, shape.seq_len

    def build():
        cache = tfm.init_decode_cache(cfg, B, S)
        if cfg.family in ("audio", "vlm"):
            # memory K/V slots materialize with prefill; give them abstract
            # shapes here so the decode step can lower standalone.
            from repro.models.transformer import pad_len
            shards = xcfg.seq_shards if xcfg is not None else 1
            L = xcfg.L if xcfg is not None else 1
            if cfg.family == "audio":
                M = pad_len(cfg.encoder_seq, shards, max(L, 1))
                n_stack = cfg.n_layers
            else:
                M = pad_len(cfg.image_tokens, shards, max(L, 1))
                n_stack = cfg.n_layers // cfg.cross_attn_every
            mem_kv = {"k": jnp.zeros((n_stack, B, M, cfg.n_kv_heads, cfg.hd),
                                     cfg.jdtype),
                      "v": jnp.zeros((n_stack, B, M, cfg.n_kv_heads, cfg.hd),
                                     cfg.jdtype)}
            mem_mask = jnp.zeros((B, M), bool)
            cache = {**cache, "mem_kv": mem_kv, "mem_mask": mem_mask}
        return cache

    return jax.eval_shape(build)


def forward_fn(cfg: ModelConfig):
    if cfg.family == "vit":
        return lambda params, batch, xcfg: (
            vit_mod.forward_vit(params, batch["images"], cfg, xcfg),
            jnp.zeros((), jnp.float32))
    return lambda params, batch, xcfg: tfm.forward_lm(params, batch, cfg, xcfg)


def prefill_fn(cfg: ModelConfig):
    """Forward that unembeds only the last position (serving prefill)."""
    if cfg.family == "vit":
        return forward_fn(cfg)
    return lambda params, batch, xcfg: tfm.forward_lm(params, batch, cfg,
                                                      xcfg, last_only=True)


def decode_fn(cfg: ModelConfig):
    if cfg.family == "vit":
        raise ValueError("ViT is encoder-only: no decode step (skip decode "
                         "shapes per the brief)")
    return lambda params, batch, cache, idx, xcfg: tfm.decode_step(
        params, batch, cache, idx, cfg, xcfg)
