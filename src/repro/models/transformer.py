"""Unified Transformer stacks for every assigned architecture family.

One scan-based implementation covers: dense GQA decoders (llama / qwen /
internlm), gemma2 (local–global alternation, softcaps, post-norms), MoE
decoders (deepseek-moe / deepseek-v2 with MLA), encoder–decoder (whisper),
VLM with interleaved cross-attention (llama-3.2-vision), hybrid
attention+SSM (hymba) and pure-recurrent (xLSTM).

Layer parameters are **stacked** along a leading group axis and consumed by
``jax.lax.scan`` (with per-layer ``jax.checkpoint``), so HLO size — and
dry-run compile time — is independent of depth. Heterogeneous stacks (gemma
local/global pairs, VLM 1-in-k cross layers, xLSTM 1-in-k sLSTM) scan over
*groups* holding one stack per member role.

Entry points:
  init_lm(key, cfg)                     → params pytree
  forward_lm(params, batch, cfg, xcfg)  → (logits, aux)   train / full fwd
  init_decode_cache(cfg, B, S)          → cache pytree
  prefill(params, batch, cache, cfg, xcfg) → (last logits, primed cache)
  decode_step(params, batch, cache, i, cfg, xcfg) → (logits, cache)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.exchange import (ExchangeConfig, ExchangeMode,
                                 exchange_cross_attention, pin_activations)
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (AttnSpec, apply_mlp, apply_norm,
                                 attention_block, attention_decode,
                                 attention_decode_paged, embed,
                                 init_attention, init_embedding, init_kv_cache,
                                 init_mlp, init_norm, prefill_kv_cache,
                                 project_qkv, unembed)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# attention specs per layer kind
# ---------------------------------------------------------------------------

def _attn_spec(cfg: ModelConfig, *, window: Optional[int] = None,
               causal: Optional[bool] = None, use_rope: bool = True) -> AttnSpec:
    return AttnSpec(
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
        causal=cfg.causal if causal is None else causal,
        window=window, logit_softcap=cfg.attn_softcap,
        rope_theta=cfg.rope_theta, use_rope=use_rope and cfg.rope_theta > 0,
        scale=cfg.query_scale)


def _stack(init_fn, key, n: int):
    """Stack ``n`` independent inits along a leading axis (scan layout)."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def pad_len(n: int, shards: int, L: int) -> int:
    """Pad a memory length so each of ``shards`` partitions splits into L
    integer segments (mask-aware means handle the remainder exactly)."""
    q = shards * max(L, 1)
    return ((n + q - 1) // q) * q


# ---------------------------------------------------------------------------
# per-family layer init / apply
# ---------------------------------------------------------------------------

def _init_dense_layer(cfg: ModelConfig):
    d, dtype = cfg.d_model, cfg.jdtype

    def init(key):
        ks = jax.random.split(key, 2)
        p = {"ln1": init_norm(cfg.norm_type, d),
             "attn": init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.hd, dtype, qkv_bias=cfg.qkv_bias),
             "ln2": init_norm(cfg.norm_type, d),
             "mlp": init_mlp(ks[1], d, cfg.d_ff, dtype,
                             gated=cfg.act != "gelu")}
        if cfg.post_norms:
            p["post_attn"] = init_norm(cfg.norm_type, d)
            p["post_mlp"] = init_norm(cfg.norm_type, d)
        return p
    return init


def _apply_attn_mlp(p: Params, x, cfg: ModelConfig, xcfg, spec: AttnSpec,
                    positions, mlp_fn=None):
    """Standard pre-norm block: x + attn(ln(x)); x + mlp(ln(x))."""
    x = pin_activations(x, xcfg)
    h = attention_block(p["attn"], apply_norm(cfg.norm_type, p["ln1"], x),
                        spec, xcfg, positions=positions)
    if cfg.post_norms:
        h = apply_norm(cfg.norm_type, p["post_attn"], h)
    x = x + h
    hin = apply_norm(cfg.norm_type, p["ln2"], x)
    h2 = mlp_fn(hin) if mlp_fn else apply_mlp(p["mlp"], hin, cfg.act)
    aux = 0.0
    if isinstance(h2, tuple):
        h2, aux = h2
    if cfg.post_norms:
        h2 = apply_norm(cfg.norm_type, p["post_mlp"], h2)
    return x + h2, aux


def _apply_attn_mlp_prefill(p: Params, x, cfg: ModelConfig, xcfg,
                            spec: AttnSpec, positions, cache,
                            mlp_fn=None):
    """Full-sequence block that also bulk-writes the prompt K/V into the
    decode cache — the single-pass prefill analogue of ``_apply_attn_mlp``
    (same math) + ``_apply_attn_mlp_decode``'s cache updates."""
    x = pin_activations(x, xcfg)
    xin = apply_norm(cfg.norm_type, p["ln1"], x)
    q, k, v = project_qkv(p["attn"], xin, spec, positions)
    new_cache = prefill_kv_cache(cache, k, v)
    from repro.core.exchange import exchange_attention
    attn = exchange_attention(q, k, v, xcfg, causal=spec.causal,
                              window=spec.window,
                              logit_softcap=spec.logit_softcap,
                              scale=spec.scale)
    B, N = x.shape[:2]
    h = attn.reshape(B, N, spec.n_heads * spec.head_dim) @ p["attn"]["wo"]
    if cfg.post_norms:
        h = apply_norm(cfg.norm_type, p["post_attn"], h)
    x = x + h
    hin = apply_norm(cfg.norm_type, p["ln2"], x)
    h2 = mlp_fn(hin) if mlp_fn else apply_mlp(p["mlp"], hin, cfg.act)
    if isinstance(h2, tuple):
        h2 = h2[0]
    if cfg.post_norms:
        h2 = apply_norm(cfg.norm_type, p["post_mlp"], h2)
    return x + h2, new_cache


def _apply_attn_mlp_decode(p: Params, x, cfg: ModelConfig, xcfg,
                           spec: AttnSpec, cache, index, mlp_fn=None):
    h, new_cache = attention_decode(
        p["attn"], apply_norm(cfg.norm_type, p["ln1"], x), spec, xcfg,
        cache, index)
    if cfg.post_norms:
        h = apply_norm(cfg.norm_type, p["post_attn"], h)
    x = x + h
    hin = apply_norm(cfg.norm_type, p["ln2"], x)
    h2 = mlp_fn(hin) if mlp_fn else apply_mlp(p["mlp"], hin, cfg.act)
    if isinstance(h2, tuple):
        h2 = h2[0]
    if cfg.post_norms:
        h2 = apply_norm(cfg.norm_type, p["post_mlp"], h2)
    return x + h2, new_cache


# --- MoE -------------------------------------------------------------------

def _init_moe_layer(cfg: ModelConfig, dense_mlp: bool):
    d, dtype = cfg.d_model, cfg.jdtype
    m = cfg.moe

    def init(key):
        ks = jax.random.split(key, 3)
        p = {"ln1": init_norm(cfg.norm_type, d), "ln2": init_norm(cfg.norm_type, d)}
        if cfg.mla is not None:
            p["attn"] = mla_mod.init_mla(ks[0], d, cfg.n_heads, cfg.mla, dtype)
        else:
            p["attn"] = init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                       cfg.hd, dtype, qkv_bias=cfg.qkv_bias)
        if dense_mlp:
            p["mlp"] = init_mlp(ks[1], d, m.d_ff_dense, dtype)
        else:
            p["moe"] = moe_mod.init_moe(ks[1], d, m, dtype)
        return p
    return init


def _apply_moe_layer(p: Params, x, cfg: ModelConfig, xcfg, positions,
                     dense_mlp: bool):
    x = pin_activations(x, xcfg)
    if cfg.mla is not None:
        h = mla_mod.mla_block(p["attn"],
                              apply_norm(cfg.norm_type, p["ln1"], x),
                              cfg.n_heads, cfg.mla, xcfg,
                              positions=positions, rope_theta=cfg.rope_theta)
    else:
        h = attention_block(p["attn"],
                            apply_norm(cfg.norm_type, p["ln1"], x),
                            _attn_spec(cfg), xcfg, positions=positions)
    x = x + h
    hin = apply_norm(cfg.norm_type, p["ln2"], x)
    if dense_mlp:
        return x + apply_mlp(p["mlp"], hin, cfg.act), 0.0
    y, aux = moe_mod.apply_moe(p["moe"], hin, cfg.moe, cfg.act)
    return x + y, aux


# --- hymba (parallel attention ‖ mamba heads) ------------------------------

def _init_hymba_layer(cfg: ModelConfig):
    d, dtype = cfg.d_model, cfg.jdtype

    def init(key):
        ks = jax.random.split(key, 3)
        return {"ln1": init_norm(cfg.norm_type, d),
                "attn": init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                       cfg.hd, dtype),
                "mamba": ssm_mod.init_mamba(ks[1], d, cfg.ssm, dtype),
                "attn_norm": init_norm(cfg.norm_type, cfg.n_heads * cfg.hd),
                "ssm_norm": init_norm(cfg.norm_type, d),
                "fuse": (jnp.zeros((cfg.n_heads * cfg.hd, d), dtype)
                         if cfg.n_heads * cfg.hd != d else None),
                "ln2": init_norm(cfg.norm_type, d),
                "mlp": init_mlp(ks[2], d, cfg.d_ff, dtype)}
    return init


def _hymba_mix(p, attn_out, ssm_out, cfg):
    """Hymba's fusion: per-path normalization then mean (arXiv:2411.13676)."""
    a = apply_norm(cfg.norm_type, p["attn_norm"], attn_out)
    if p.get("fuse") is not None:
        a = a @ p["fuse"]
    s = apply_norm(cfg.norm_type, p["ssm_norm"], ssm_out)
    return 0.5 * (a + s)


def _apply_hymba_layer(p, x, cfg: ModelConfig, xcfg, positions):
    x = pin_activations(x, xcfg)
    xin = apply_norm(cfg.norm_type, p["ln1"], x)
    spec = _attn_spec(cfg)
    from repro.models.layers import project_qkv  # local import for clarity
    from repro.core.exchange import exchange_attention
    q, k, v = project_qkv(p["attn"], xin, spec, positions)
    attn_out = exchange_attention(q, k, v, xcfg, causal=True)
    B, N = x.shape[:2]
    attn_out = attn_out.reshape(B, N, cfg.n_heads * cfg.hd) @ p["attn"]["wo"]
    ssm_out, _ = ssm_mod.mamba_scan(p["mamba"], xin, cfg.ssm)
    x = x + _hymba_mix(p, attn_out, ssm_out, cfg)
    h2 = apply_mlp(p["mlp"], apply_norm(cfg.norm_type, p["ln2"], x), cfg.act)
    return x + h2, 0.0


def _apply_hymba_decode(p, x, cfg, xcfg, cache, index):
    xin = apply_norm(cfg.norm_type, p["ln1"], x)
    spec = _attn_spec(cfg)
    attn_out, kv_cache = attention_decode(p["attn"], xin, spec, xcfg,
                                          cache["kv"], index)
    ssm_out, sstate = ssm_mod.mamba_step(p["mamba"], xin, cfg.ssm,
                                         cache["ssm"])
    x = x + _hymba_mix(p, attn_out, ssm_out, cfg)
    h2 = apply_mlp(p["mlp"], apply_norm(cfg.norm_type, p["ln2"], x), cfg.act)
    return x + h2, {"kv": kv_cache, "ssm": sstate}


# --- xLSTM ------------------------------------------------------------------

def _init_xlstm_group(cfg: ModelConfig):
    """One group = (slstm_every - 1) mLSTM blocks + 1 sLSTM block."""
    d, dtype = cfg.d_model, cfg.jdtype
    n_m = cfg.ssm.slstm_every - 1

    def init(key):
        ks = jax.random.split(key, n_m + 1)
        m_ln = jax.tree_util.tree_map(lambda l: jnp.stack([l] * n_m),
                                      init_norm(cfg.norm_type, d))
        return {"m_ln": m_ln if n_m else None,
                "mlstm": _stack(lambda k: ssm_mod.init_mlstm(k, d, cfg.ssm,
                                                             dtype),
                                ks[0], n_m) if n_m else None,
                "s_ln": init_norm(cfg.norm_type, d),
                "slstm": ssm_mod.init_slstm(ks[-1], d, cfg.ssm, dtype)}
    return init


def _apply_xlstm_group(p, x, cfg: ModelConfig, states=None, decode=False):
    """states: {"m": stacked mLSTM states [n_m, ...], "s": sLSTM state}."""
    n_m = cfg.ssm.slstm_every - 1
    new_m, new_s = None, None
    if n_m:
        def body(carry, inp):
            xc = carry
            lp, ln_p, st = inp
            xin = apply_norm(cfg.norm_type, ln_p, xc)
            if decode:
                y, ns = ssm_mod.mlstm_step(lp, xin, cfg.ssm, st)
            else:
                y, ns = ssm_mod.mlstm_scan(lp, xin, cfg.ssm, state0=st)
            return xc + y, ns
        m_states = (states["m"] if states is not None else
                    jax.tree_util.tree_map(
                        lambda l: jnp.stack([l] * n_m),
                        ssm_mod.init_mlstm_state(x.shape[0], cfg.d_model,
                                                 cfg.ssm)))
        x, new_m = jax.lax.scan(body, x, (p["mlstm"], p["m_ln"], m_states))
    xin = apply_norm(cfg.norm_type, p["s_ln"], x)
    s_state = states["s"] if states is not None else None
    if decode:
        y, new_s = ssm_mod.slstm_step(p["slstm"], xin, cfg.ssm, s_state)
    else:
        y, new_s = ssm_mod.slstm_scan(p["slstm"], xin, cfg.ssm, state0=s_state)
    return x + y, {"m": new_m, "s": new_s}


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 8)
    d, dtype = cfg.d_model, cfg.jdtype
    params: Params = {
        "embed": init_embedding(ks[0], cfg.vocab_size, d, dtype),
        "final_norm": init_norm(cfg.norm_type, d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(ks[1], cfg.vocab_size, d, dtype)

    fam = cfg.family
    if fam in ("dense",):
        if cfg.local_global:
            n_pairs = cfg.n_layers // 2
            params["local_layers"] = _stack(_init_dense_layer(cfg), ks[2], n_pairs)
            params["global_layers"] = _stack(_init_dense_layer(cfg), ks[3], n_pairs)
        else:
            params["layers"] = _stack(_init_dense_layer(cfg), ks[2], cfg.n_layers)
    elif fam == "moe":
        fd = cfg.moe.first_dense_layers
        params["first_layers"] = _stack(_init_moe_layer(cfg, dense_mlp=True),
                                        ks[2], fd)
        params["layers"] = _stack(_init_moe_layer(cfg, dense_mlp=False),
                                  ks[3], cfg.n_layers - fd)
    elif fam == "audio":
        params["enc_layers"] = _stack(
            _init_dense_layer(dataclasses.replace(cfg, causal=False)),
            ks[2], cfg.encoder_layers)
        params["enc_norm"] = init_norm(cfg.norm_type, d)
        params["dec_layers"] = _stack(_init_encdec_layer(cfg), ks[3],
                                      cfg.n_layers)
    elif fam == "vlm":
        k_every = cfg.cross_attn_every
        n_groups = cfg.n_layers // k_every
        params["self_layers"] = _stack(
            lambda k: _stack(_init_dense_layer(cfg), k, k_every - 1),
            ks[2], n_groups)
        params["cross_layers"] = _stack(_init_cross_layer(cfg), ks[3], n_groups)
    elif fam == "hybrid":
        params["layers"] = _stack(_init_hymba_layer(cfg), ks[2], cfg.n_layers)
    elif fam == "ssm":
        n_groups = cfg.n_layers // cfg.ssm.slstm_every
        params["groups"] = _stack(_init_xlstm_group(cfg), ks[2], n_groups)
    else:
        raise ValueError(f"unknown family {fam}")
    return params


def _init_encdec_layer(cfg: ModelConfig):
    """Whisper decoder layer: causal self-attn + cross-attn + MLP."""
    d, dtype = cfg.d_model, cfg.jdtype

    def init(key):
        ks = jax.random.split(key, 3)
        return {"ln1": init_norm(cfg.norm_type, d),
                "attn": init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                       cfg.hd, dtype),
                "ln_x": init_norm(cfg.norm_type, d),
                "xattn": init_attention(ks[1], d, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.hd, dtype),
                "ln2": init_norm(cfg.norm_type, d),
                "mlp": init_mlp(ks[2], d, cfg.d_ff, dtype,
                                gated=cfg.act != "gelu")}
    return init


def _init_cross_layer(cfg: ModelConfig):
    """VLM cross-attention layer (attends to image tokens) + MLP."""
    d, dtype = cfg.d_model, cfg.jdtype

    def init(key):
        ks = jax.random.split(key, 2)
        return {"ln1": init_norm(cfg.norm_type, d),
                "xattn": init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.hd, dtype),
                "gate": jnp.zeros((), jnp.float32),
                "ln2": init_norm(cfg.norm_type, d),
                "mlp": init_mlp(ks[1], d, cfg.d_ff, dtype)}
    return init


def _cross_attend(p, x, mem_kv, mem_mask, cfg: ModelConfig, xcfg):
    """Cross-attention of x onto a precomputed (k, v) memory.

    Full-sequence queries use the partitioned-memory exchange (PRISM/Voltage
    over the memory); single-token decode queries use the exact sharded-merge
    (the per-step collective is already output-sized, so compressing it
    further buys nothing — DESIGN.md §4).
    """
    B, N, _ = x.shape
    xin = apply_norm(cfg.norm_type, p["ln1"], x)
    q = (xin @ p["xattn"]["wq"]).reshape(B, N, cfg.n_heads, cfg.hd)
    if N == 1:
        from repro.core.exchange import decode_attention_sharded
        dcfg = (xcfg if xcfg.mode == ExchangeMode.LOCAL
                else xcfg.with_mode(ExchangeMode.VOLTAGE))
        valid = mem_mask.sum(axis=-1).astype(jnp.int32)      # pads are a suffix
        out = decode_attention_sharded(q, mem_kv["k"], mem_kv["v"], valid,
                                       dcfg, logit_softcap=cfg.attn_softcap,
                                       scale=cfg.query_scale)
    else:
        out = exchange_cross_attention(q, mem_kv["k"], mem_kv["v"], mem_mask,
                                       xcfg, logit_softcap=cfg.attn_softcap,
                                       scale=cfg.query_scale)
    out = out.reshape(B, N, cfg.n_heads * cfg.hd) @ p["xattn"]["wo"]
    if "gate" in p:
        out = jnp.tanh(p["gate"]).astype(out.dtype) * out
    return x + out


def _memory_kv(p_attn, mem, cfg: ModelConfig):
    """Project a memory [B, M, D] to (k, v) once (shared by all queries)."""
    B, M, _ = mem.shape
    k = (mem @ p_attn["wk"]).reshape(B, M, cfg.n_kv_heads, cfg.hd)
    v = (mem @ p_attn["wv"]).reshape(B, M, cfg.n_kv_heads, cfg.hd)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def forward_lm(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
               xcfg: ExchangeConfig, last_only: bool = False
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. batch: {"tokens": [B, N], +family extras}.

    Returns (logits [B, N, V] f32, aux scalar). ``last_only`` unembeds just
    the final position (prefill: a [B, N, V] logits tensor is N× wasted
    HBM — only the next-token distribution is needed).
    """
    tokens = batch["tokens"]
    B, N = tokens.shape
    x = embed(params["embed"], tokens, scale_by_sqrt_d=cfg.embed_scale)
    x = pin_activations(x, xcfg)
    positions = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[None], (B, N))
    aux_total = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam == "dense":
        if cfg.local_global:
            def pair(xc, lp):
                x1, _ = _apply_attn_mlp(lp[0], xc, cfg, xcfg,
                                        _attn_spec(cfg, window=cfg.window),
                                        positions)
                x2, _ = _apply_attn_mlp(lp[1], x1, cfg, xcfg, _attn_spec(cfg),
                                        positions)
                return x2, None
            x, _ = jax.lax.scan(jax.checkpoint(pair), x,
                                (params["local_layers"], params["global_layers"]))
        else:
            def body(xc, lp):
                y, _ = _apply_attn_mlp(lp, xc, cfg, xcfg, _attn_spec(cfg),
                                       positions)
                return y, None
            x, _ = jax.lax.scan(jax.checkpoint(body), x, params["layers"])

    elif fam == "moe":
        def first(xc, lp):
            y, a = _apply_moe_layer(lp, xc, cfg, xcfg, positions, True)
            return y, a
        x, _ = jax.lax.scan(jax.checkpoint(first), x, params["first_layers"])

        def body(xc, lp):
            y, a = _apply_moe_layer(lp, xc, cfg, xcfg, positions, False)
            return y, a
        x, auxs = jax.lax.scan(jax.checkpoint(body), x, params["layers"])
        aux_total = aux_total + jnp.sum(auxs)

    elif fam == "audio":
        mem, mem_mask = _encode_audio(params, batch, cfg, xcfg)

        def body(xc, lp):
            h = attention_block(lp["attn"],
                                apply_norm(cfg.norm_type, lp["ln1"], xc),
                                _attn_spec(cfg), xcfg, positions=positions)
            xc = xc + h
            mem_kv = _memory_kv(lp["xattn"], mem, cfg)
            xc = _cross_attend({"ln1": lp["ln_x"], "xattn": lp["xattn"]},
                               xc, mem_kv, mem_mask, cfg, xcfg)
            h2 = apply_mlp(lp["mlp"], apply_norm(cfg.norm_type, lp["ln2"], xc),
                           cfg.act)
            return xc + h2, None
        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"])

    elif fam == "vlm":
        mem, mem_mask = _image_memory(batch, cfg, xcfg)

        def group(xc, lp):
            selfs, crossp = lp

            def inner(xi, sp):
                y, _ = _apply_attn_mlp(sp, xi, cfg, xcfg, _attn_spec(cfg),
                                       positions)
                return y, None
            xc, _ = jax.lax.scan(inner, xc, selfs)
            mem_kv = _memory_kv(crossp["xattn"], mem, cfg)
            xc = _cross_attend(crossp, xc, mem_kv, mem_mask, cfg, xcfg)
            h2 = apply_mlp(crossp["mlp"],
                           apply_norm(cfg.norm_type, crossp["ln2"], xc),
                           cfg.act)
            return xc + h2, None
        x, _ = jax.lax.scan(jax.checkpoint(group), x,
                            (params["self_layers"], params["cross_layers"]))

    elif fam == "hybrid":
        def body(xc, lp):
            y, a = _apply_hymba_layer(lp, xc, cfg, xcfg, positions)
            return y, a
        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["layers"])

    elif fam == "ssm":
        def body(xc, gp):
            y, _ = _apply_xlstm_group(gp, xc, cfg)
            return y, None
        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["groups"])

    else:
        raise ValueError(fam)

    if last_only:
        x = x[:, -1:]
    x = pin_activations(apply_norm(cfg.norm_type, params["final_norm"], x),
                        xcfg)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(head, x, final_softcap=cfg.final_softcap)
    return logits, aux_total


def _encode_audio(params, batch, cfg: ModelConfig, xcfg):
    """Whisper encoder over stub frame embeddings [B, M0, D] (padded)."""
    frames = batch["frames"]
    B, M0, _ = frames.shape
    M = pad_len(M0, xcfg.seq_shards, xcfg.L)
    mem = jnp.pad(frames, ((0, 0), (0, M - M0), (0, 0)))
    mem_mask = jnp.broadcast_to(jnp.arange(M)[None] < M0, (B, M))
    pos = jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32)[None], (B, M))
    ecfg = dataclasses.replace(cfg, causal=False)

    def body(xc, lp):
        y, _ = _apply_attn_mlp(lp, xc, ecfg, xcfg,
                               _attn_spec(cfg, causal=False), pos)
        return y, None
    mem, _ = jax.lax.scan(jax.checkpoint(body), mem, params["enc_layers"])
    mem = apply_norm(cfg.norm_type, params["enc_norm"], mem)
    return mem, mem_mask


def _image_memory(batch, cfg: ModelConfig, xcfg):
    """Pad stub image-patch embeddings [B, T0, D] for partitioning."""
    img = batch["image_embeds"]
    B, T0, _ = img.shape
    T = pad_len(T0, xcfg.seq_shards, xcfg.L)
    mem = jnp.pad(img, ((0, 0), (0, T - T0), (0, 0)))
    mask = jnp.broadcast_to(jnp.arange(T)[None] < T0, (B, T))
    return mem, mask


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _scan_decode_layers(body_fn, x, params_stack, cache_stack):
    """Layer scan for decode with the stacked cache in the CARRY.

    Scanning the cache as xs with updated ys duplicates every cache buffer
    (input stack + output stack + staging ≈ 3× cache HBM). Carrying it lets
    XLA update the single stacked buffer in place inside the while loop;
    per layer we dynamic-slice one layer's cache out and write it back.

    body_fn(x, layer_params, layer_cache) → (x, new_layer_cache).
    """
    import jax.tree_util as jtu

    def body(carry, lp):
        xc, cache, i = carry
        c = jtu.tree_map(
            lambda t: jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False),
            cache)
        y, nc = body_fn(xc, lp, c)
        cache = jtu.tree_map(
            lambda t, u: jax.lax.dynamic_update_index_in_dim(
                t, u.astype(t.dtype), i, 0), cache, nc)
        return (y, cache, i + 1), None

    (x, cache_stack, _), _ = jax.lax.scan(
        body, (x, cache_stack, jnp.asarray(0, jnp.int32)), params_stack)
    return x, cache_stack


def init_decode_cache(cfg: ModelConfig, batch: int, seq: int) -> Params:
    """Cache pytree with stacked leading layer/group dims (scan layout)."""
    dtype = cfg.jdtype
    fam = cfg.family

    def kv(n, s):
        c = init_kv_cache(batch, s, cfg.n_kv_heads, cfg.hd, dtype,
                          quant=cfg.kv_quant)
        return jax.tree_util.tree_map(lambda l: jnp.stack([l] * n), c)

    if fam == "dense":
        if cfg.local_global:
            n_pairs = cfg.n_layers // 2
            return {"local": kv(n_pairs, seq), "global": kv(n_pairs, seq)}
        return {"kv": kv(cfg.n_layers, seq)}
    if fam == "moe":
        fd = cfg.moe.first_dense_layers
        if cfg.mla is not None:
            def mlac(n):
                c = mla_mod.init_mla_cache(batch, seq, cfg.mla, dtype)
                return jax.tree_util.tree_map(lambda l: jnp.stack([l] * n), c)
            return {"first": mlac(fd), "kv": mlac(cfg.n_layers - fd)}
        return {"first": kv(fd, seq), "kv": kv(cfg.n_layers - fd, seq)}
    if fam == "audio":
        return {"kv": kv(cfg.n_layers, seq), "mem_kv": None, "mem_mask": None}
    if fam == "vlm":
        k_every = cfg.cross_attn_every
        n_groups = cfg.n_layers // k_every
        selfs = kv(n_groups, seq)
        selfs = jax.tree_util.tree_map(
            lambda l: l.reshape(n_groups, 1, *l.shape[1:]).repeat(
                k_every - 1, axis=1), selfs)
        return {"self": selfs, "mem_kv": None, "mem_mask": None}
    if fam == "hybrid":
        kvs = kv(cfg.n_layers, seq)
        sst = ssm_mod.init_mamba_state(batch, cfg.d_model, cfg.ssm, dtype)
        sst = jax.tree_util.tree_map(lambda l: jnp.stack([l] * cfg.n_layers), sst)
        return {"kv": kvs, "ssm": sst}
    if fam == "ssm":
        n_groups = cfg.n_layers // cfg.ssm.slstm_every
        n_m = cfg.ssm.slstm_every - 1
        m = ssm_mod.init_mlstm_state(batch, cfg.d_model, cfg.ssm)
        m = jax.tree_util.tree_map(
            lambda l: jnp.stack([jnp.stack([l] * n_m)] * n_groups), m)
        s = ssm_mod.init_slstm_state(batch, cfg.d_model)
        s = jax.tree_util.tree_map(lambda l: jnp.stack([l] * n_groups), s)
        return {"m": m, "s": s}
    raise ValueError(fam)


def decode_step(params: Params, batch: Dict[str, jnp.ndarray], cache: Params,
                cache_index, cfg: ModelConfig, xcfg: ExchangeConfig
                ) -> Tuple[jnp.ndarray, Params]:
    """One-token step. batch: {"tokens": [B, 1], +extras on first call}.

    Returns (logits [B, 1, V], updated cache). ``cache_index`` is the global
    write position (current sequence length).
    """
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = embed(params["embed"], tokens, scale_by_sqrt_d=cfg.embed_scale)
    fam = cfg.family

    if fam == "dense":
        if cfg.local_global:
            def pair(xc, lps, c):
                lp_l, lp_g = lps
                c_l, c_g = c
                x1, nc_l = _apply_attn_mlp_decode(
                    lp_l, xc, cfg, xcfg, _attn_spec(cfg, window=cfg.window),
                    c_l, cache_index)
                x2, nc_g = _apply_attn_mlp_decode(
                    lp_g, x1, cfg, xcfg, _attn_spec(cfg), c_g, cache_index)
                return x2, (nc_l, nc_g)
            x, (ncl, ncg) = _scan_decode_layers(
                pair, x, (params["local_layers"], params["global_layers"]),
                (cache["local"], cache["global"]))
            new_cache = {"local": ncl, "global": ncg}
        else:
            def body(xc, lp, c):
                return _apply_attn_mlp_decode(lp, xc, cfg, xcfg,
                                              _attn_spec(cfg), c, cache_index)
            x, nkv = _scan_decode_layers(body, x, params["layers"],
                                         cache["kv"])
            new_cache = {"kv": nkv}

    elif fam == "moe":
        def make_body(dense_mlp):
            def body(xc, lp, c):
                if cfg.mla is not None:
                    h, nc = mla_mod.mla_decode(
                        lp["attn"], apply_norm(cfg.norm_type, lp["ln1"], xc),
                        cfg.n_heads, cfg.mla, xcfg, c, cache_index,
                        rope_theta=cfg.rope_theta)
                    xc = xc + h
                    hin = apply_norm(cfg.norm_type, lp["ln2"], xc)
                    if dense_mlp:
                        y = apply_mlp(lp["mlp"], hin, cfg.act)
                    else:
                        y, _ = moe_mod.apply_moe(lp["moe"], hin, cfg.moe, cfg.act)
                    return xc + y, nc
                mlp_fn = ((lambda h: apply_mlp(lp["mlp"], h, cfg.act))
                          if dense_mlp else
                          (lambda h: moe_mod.apply_moe(lp["moe"], h, cfg.moe,
                                                       cfg.act)))
                return _apply_attn_mlp_decode(lp, xc, cfg, xcfg,
                                              _attn_spec(cfg), c, cache_index,
                                              mlp_fn=mlp_fn)
            return body
        x, nfirst = _scan_decode_layers(make_body(True), x,
                                        params["first_layers"],
                                        cache["first"])
        x, nkv = _scan_decode_layers(make_body(False), x, params["layers"],
                                     cache["kv"])
        new_cache = {"first": nfirst, "kv": nkv}

    elif fam == "audio":
        mem_kv, mem_mask = cache["mem_kv"], cache["mem_mask"]

        # mem K/V differ per layer: stacked along the layer axis (read-only
        # xs); the self-attention cache rides the carry (in-place update)
        def body2(xc, lps, c):
            lp, mkv = lps
            h, nc = attention_decode(
                lp["attn"], apply_norm(cfg.norm_type, lp["ln1"], xc),
                _attn_spec(cfg), xcfg, c, cache_index)
            xc = xc + h
            xc = _cross_attend({"ln1": lp["ln_x"], "xattn": lp["xattn"]},
                               xc, mkv, mem_mask, cfg, xcfg)
            h2 = apply_mlp(lp["mlp"],
                           apply_norm(cfg.norm_type, lp["ln2"], xc), cfg.act)
            return xc + h2, nc
        x, nkv = _scan_decode_layers(body2, x,
                                     (params["dec_layers"], mem_kv),
                                     cache["kv"])
        new_cache = {"kv": nkv, "mem_kv": mem_kv, "mem_mask": mem_mask}

    elif fam == "vlm":
        mem_kv, mem_mask = cache["mem_kv"], cache["mem_mask"]

        def group(xc, lps, c):
            selfs, crossp, mkv = lps

            def inner(xi, sp, cc):
                return _apply_attn_mlp_decode(sp, xi, cfg, xcfg,
                                              _attn_spec(cfg), cc, cache_index)
            xc, ncs = _scan_decode_layers(inner, xc, selfs, c)
            xc = _cross_attend(crossp, xc, mkv, mem_mask, cfg, xcfg)
            h2 = apply_mlp(crossp["mlp"],
                           apply_norm(cfg.norm_type, crossp["ln2"], xc),
                           cfg.act)
            return xc + h2, ncs
        x, nself = _scan_decode_layers(
            group, x, (params["self_layers"], params["cross_layers"], mem_kv),
            cache["self"])
        new_cache = {"self": nself, "mem_kv": mem_kv, "mem_mask": mem_mask}

    elif fam == "hybrid":
        def body(xc, lp, c):
            return _apply_hymba_decode(lp, xc, cfg, xcfg, c, cache_index)
        x, new_cache = _scan_decode_layers(body, x, params["layers"], cache)

    elif fam == "ssm":
        def body(xc, gp, st):
            return _apply_xlstm_group(gp, xc, cfg, states=st, decode=True)
        x, new_cache = _scan_decode_layers(body, x, params["groups"], cache)

    else:
        raise ValueError(fam)

    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(head, x, final_softcap=cfg.final_softcap)
    return logits, new_cache


def _apply_attn_mlp_decode_paged(p: Params, x, cfg: ModelConfig, xcfg,
                                 spec: AttnSpec, cache, page_table, lengths):
    """Pre-norm block around ``attention_decode_paged`` — the paged twin of
    ``_apply_attn_mlp_decode`` (identical residual/norm/MLP math)."""
    h, new_cache = attention_decode_paged(
        p["attn"], apply_norm(cfg.norm_type, p["ln1"], x), spec, xcfg,
        cache, page_table, lengths)
    if cfg.post_norms:
        h = apply_norm(cfg.norm_type, p["post_attn"], h)
    x = x + h
    hin = apply_norm(cfg.norm_type, p["ln2"], x)
    h2 = apply_mlp(p["mlp"], hin, cfg.act)
    if cfg.post_norms:
        h2 = apply_norm(cfg.norm_type, p["post_mlp"], h2)
    return x + h2, new_cache


def supports_page_pool(cfg: ModelConfig) -> bool:
    """Paged decode covers the plain dense stack: one homogeneous KV cache
    per layer, no sliding-window alternation (gemma local/global needs
    per-page window masks) and no per-slot int8 cache (cold pages quantize
    through the transport codecs instead, in ``repro.serving.pages``)."""
    return (cfg.family == "dense" and not cfg.local_global
            and not cfg.kv_quant)


def init_page_pool(cfg: ModelConfig, n_pages: int, page_size: int) -> Params:
    """Shared paged KV pool: same pytree as ``init_decode_cache`` but the
    (batch, seq) axes become (page, in-page position) — leaves are
    ``[n_layers, n_pages, page_size, Hk, dh]``.  Requests address it through
    per-row page tables; physical rows are interchangeable."""
    if not supports_page_pool(cfg):
        raise ValueError(f"family {cfg.family!r} (local_global="
                         f"{cfg.local_global}, kv_quant={cfg.kv_quant}) "
                         f"has no paged decode path")
    return init_decode_cache(cfg, n_pages, page_size)


def decode_step_paged(params: Params, batch: Dict[str, jnp.ndarray],
                      pool: Params, page_table: jnp.ndarray,
                      lengths: jnp.ndarray, cfg: ModelConfig,
                      xcfg: ExchangeConfig) -> Tuple[jnp.ndarray, Params]:
    """One-token step for every row against the shared paged pool.

    batch: {"tokens": [S, 1]}; ``page_table`` [S, max_pages] int32 maps each
    row's logical blocks to pool pages; ``lengths`` [S] int32 is each row's
    current sequence length (= this step's write position).  Returns
    (logits [S, 1, V], updated pool).
    """
    if not supports_page_pool(cfg):
        raise ValueError(f"family {cfg.family!r} has no paged decode path")
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, scale_by_sqrt_d=cfg.embed_scale)

    def body(xc, lp, c):
        return _apply_attn_mlp_decode_paged(lp, xc, cfg, xcfg,
                                            _attn_spec(cfg), c,
                                            page_table, lengths)
    x, nkv = _scan_decode_layers(body, x, params["layers"], pool["kv"])
    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(head, x, final_softcap=cfg.final_softcap)
    return logits, {"kv": nkv}


# single-pass prefill is defined for the attention-cached families; the
# recurrent families (hybrid mamba conv state, xLSTM) prefill via the
# compiled teacher-forced scan in repro.api.generation instead.
PREFILL_FAMILIES = ("dense", "moe", "audio", "vlm")


def supports_prefill(cfg: ModelConfig) -> bool:
    return cfg.family in PREFILL_FAMILIES


def prefill(params: Params, batch: Dict[str, jnp.ndarray], cache: Params,
            cfg: ModelConfig, xcfg: ExchangeConfig
            ) -> Tuple[jnp.ndarray, Params]:
    """True single-pass prefill: run the whole prompt [B, T0] through
    ``exchange_attention`` ONCE and bulk-write the KV cache for positions
    [0, T0) — replacing T0 sequential one-token decode steps.

    Returns (last-position logits [B, 1, V] f32, primed cache).  For
    audio/vlm the memory slots must be populated first
    (``prefill_memory``).  Distributed exchanges apply their *prefill*
    semantics here: under PRISM the prompt attends through compressed
    segment means (the paper's scheme), which is intentionally not
    identical to T0 exact decode steps.
    """
    if not supports_prefill(cfg):
        raise ValueError(f"family {cfg.family!r} has no single-pass "
                         f"prefill; use the scanned decode fallback "
                         f"(repro.api.generation.prefill_by_decode)")
    tokens = batch["tokens"]
    B, T0 = tokens.shape
    x = embed(params["embed"], tokens, scale_by_sqrt_d=cfg.embed_scale)
    x = pin_activations(x, xcfg)
    positions = jnp.broadcast_to(jnp.arange(T0, dtype=jnp.int32)[None],
                                 (B, T0))
    fam = cfg.family

    if fam == "dense":
        if cfg.local_global:
            def pair(xc, lps, c):
                lp_l, lp_g = lps
                c_l, c_g = c
                x1, nc_l = _apply_attn_mlp_prefill(
                    lp_l, xc, cfg, xcfg, _attn_spec(cfg, window=cfg.window),
                    positions, c_l)
                x2, nc_g = _apply_attn_mlp_prefill(
                    lp_g, x1, cfg, xcfg, _attn_spec(cfg), positions, c_g)
                return x2, (nc_l, nc_g)
            x, (ncl, ncg) = _scan_decode_layers(
                pair, x, (params["local_layers"], params["global_layers"]),
                (cache["local"], cache["global"]))
            new_cache = {"local": ncl, "global": ncg}
        else:
            def body(xc, lp, c):
                return _apply_attn_mlp_prefill(lp, xc, cfg, xcfg,
                                               _attn_spec(cfg), positions, c)
            x, nkv = _scan_decode_layers(body, x, params["layers"],
                                         cache["kv"])
            new_cache = {"kv": nkv}

    elif fam == "moe":
        def make_body(dense_mlp):
            def body(xc, lp, c):
                if cfg.mla is not None:
                    xc = pin_activations(xc, xcfg)
                    h, nc = mla_mod.mla_prefill(
                        lp["attn"], apply_norm(cfg.norm_type, lp["ln1"], xc),
                        cfg.n_heads, cfg.mla, xcfg, c, positions=positions,
                        rope_theta=cfg.rope_theta)
                    xc = xc + h
                    hin = apply_norm(cfg.norm_type, lp["ln2"], xc)
                    if dense_mlp:
                        y = apply_mlp(lp["mlp"], hin, cfg.act)
                    else:
                        y, _ = moe_mod.apply_moe(lp["moe"], hin, cfg.moe,
                                                 cfg.act)
                    return xc + y, nc
                mlp_fn = ((lambda h: apply_mlp(lp["mlp"], h, cfg.act))
                          if dense_mlp else
                          (lambda h: moe_mod.apply_moe(lp["moe"], h, cfg.moe,
                                                       cfg.act)))
                return _apply_attn_mlp_prefill(lp, xc, cfg, xcfg,
                                               _attn_spec(cfg), positions, c,
                                               mlp_fn=mlp_fn)
            return body
        x, nfirst = _scan_decode_layers(make_body(True), x,
                                        params["first_layers"],
                                        cache["first"])
        x, nkv = _scan_decode_layers(make_body(False), x, params["layers"],
                                     cache["kv"])
        new_cache = {"first": nfirst, "kv": nkv}

    elif fam == "audio":
        mem_kv, mem_mask = cache["mem_kv"], cache["mem_mask"]

        def body2(xc, lps, c):
            lp, mkv = lps
            xin = apply_norm(cfg.norm_type, lp["ln1"], xc)
            spec = _attn_spec(cfg)
            q, k, v = project_qkv(lp["attn"], xin, spec, positions)
            nc = prefill_kv_cache(c, k, v)
            from repro.core.exchange import exchange_attention
            h = exchange_attention(q, k, v, xcfg, causal=spec.causal,
                                   logit_softcap=spec.logit_softcap,
                                   scale=spec.scale)
            h = h.reshape(B, T0, spec.n_heads * spec.head_dim) \
                @ lp["attn"]["wo"]
            xc = xc + h
            xc = _cross_attend({"ln1": lp["ln_x"], "xattn": lp["xattn"]},
                               xc, mkv, mem_mask, cfg, xcfg)
            h2 = apply_mlp(lp["mlp"],
                           apply_norm(cfg.norm_type, lp["ln2"], xc), cfg.act)
            return xc + h2, nc
        x, nkv = _scan_decode_layers(body2, x,
                                     (params["dec_layers"], mem_kv),
                                     cache["kv"])
        new_cache = {"kv": nkv, "mem_kv": mem_kv, "mem_mask": mem_mask}

    elif fam == "vlm":
        mem_kv, mem_mask = cache["mem_kv"], cache["mem_mask"]

        def group(xc, lps, c):
            selfs, crossp, mkv = lps

            def inner(xi, sp, cc):
                return _apply_attn_mlp_prefill(sp, xi, cfg, xcfg,
                                               _attn_spec(cfg), positions,
                                               cc)
            xc, ncs = _scan_decode_layers(inner, xc, selfs, c)
            xc = _cross_attend(crossp, xc, mkv, mem_mask, cfg, xcfg)
            h2 = apply_mlp(crossp["mlp"],
                           apply_norm(cfg.norm_type, crossp["ln2"], xc),
                           cfg.act)
            return xc + h2, ncs
        x, nself = _scan_decode_layers(
            group, x, (params["self_layers"], params["cross_layers"], mem_kv),
            cache["self"])
        new_cache = {"self": nself, "mem_kv": mem_kv, "mem_mask": mem_mask}

    else:                                  # pragma: no cover — guarded above
        raise ValueError(fam)

    x = pin_activations(apply_norm(cfg.norm_type, params["final_norm"],
                                   x[:, -1:]), xcfg)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(head, x, final_softcap=cfg.final_softcap)
    return logits, new_cache


def prefill_memory(params: Params, batch: Dict[str, jnp.ndarray],
                   cfg: ModelConfig, xcfg: ExchangeConfig, cache: Params
                   ) -> Params:
    """Populate decode-cache memory slots for enc-dec / VLM families."""
    if cfg.family == "audio":
        mem, mem_mask = _encode_audio(params, batch, cfg, xcfg)
        mem_kv = jax.vmap(lambda lp: _memory_kv(lp["xattn"], mem, cfg),
                          in_axes=0)(params["dec_layers"])
        return {**cache, "mem_kv": mem_kv, "mem_mask": mem_mask}
    if cfg.family == "vlm":
        mem, mem_mask = _image_memory(batch, cfg, xcfg)
        mem_kv = jax.vmap(lambda lp: _memory_kv(lp["xattn"], mem, cfg),
                          in_axes=0)(params["cross_layers"])
        return {**cache, "mem_kv": mem_kv, "mem_mask": mem_mask}
    return cache
