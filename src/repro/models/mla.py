"""Multi-head Latent Attention (DeepSeek-V2) with PRISM latent exchange.

The KV path is compressed to a rank-``r`` latent ``c_kv`` plus a shared
rotary key ``k_pe``; only ``r + d_rope`` floats/token are cached or
communicated. PRISM's segment means are taken **in latent space** (the two
compressions compound — see ``repro.core.exchange.exchange_attention_mla``),
and decode uses the absorbed formulation (W_uk folded into the query,
W_uv applied after attention) so the cache is never expanded.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLACfg
from repro.core.exchange import ExchangeConfig, ExchangeMode, exchange_attention_mla
from repro.models.layers import (apply_rope, dense_init, init_rmsnorm,
                                 rmsnorm, rope_tables)

Params = Dict[str, Any]


def init_mla(key, d: int, n_heads: int, cfg: MLACfg, dtype) -> Params:
    ks = jax.random.split(key, 8)
    H = n_heads
    return {
        "w_dq": dense_init(ks[0], d, cfg.q_lora_rank, dtype),
        "q_norm": init_rmsnorm(cfg.q_lora_rank),
        "w_uq": dense_init(ks[1], cfg.q_lora_rank,
                           H * (cfg.qk_nope_dim + cfg.qk_rope_dim), dtype),
        "w_dkv": dense_init(ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim, dtype),
        "kv_norm": init_rmsnorm(cfg.kv_lora_rank),
        # stored [r, H, dim] so mean-then-expand is a single einsum
        "w_uk": (jax.random.normal(ks[3], (cfg.kv_lora_rank, H, cfg.qk_nope_dim),
                                   jnp.float32) * cfg.kv_lora_rank ** -0.5
                 ).astype(dtype),
        "w_uv": (jax.random.normal(ks[4], (cfg.kv_lora_rank, H, cfg.v_head_dim),
                                   jnp.float32) * cfg.kv_lora_rank ** -0.5
                 ).astype(dtype),
        "wo": dense_init(ks[5], H * cfg.v_head_dim, d, dtype),
    }


def _project_q(params: Params, x: jnp.ndarray, H: int, cfg: MLACfg,
               positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    B, N, _ = x.shape
    q = rmsnorm(params["q_norm"], x @ params["w_dq"]) @ params["w_uq"]
    q = q.reshape(B, N, H, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_pe = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    cos, sin = rope_tables(positions, cfg.qk_rope_dim, theta)
    q_pe = apply_rope(q_pe, cos, sin)
    return jnp.concatenate([q_nope, q_pe], axis=-1)


def _project_kv_latent(params: Params, x: jnp.ndarray, cfg: MLACfg,
                       positions: jnp.ndarray, theta: float):
    ckv = x @ params["w_dkv"]
    c_kv, k_pe = ckv[..., :cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c_kv = rmsnorm(params["kv_norm"], c_kv)
    cos, sin = rope_tables(positions, cfg.qk_rope_dim, theta)
    k_pe = apply_rope(k_pe[..., None, :], cos, sin)[..., 0, :]
    return c_kv, k_pe


def mla_block(params: Params, x: jnp.ndarray, n_heads: int, cfg: MLACfg,
              xcfg: ExchangeConfig, *, positions: Optional[jnp.ndarray] = None,
              rope_theta: float = 10000.0) -> jnp.ndarray:
    """Full-sequence MLA attention (train / prefill)."""
    B, N, _ = x.shape
    if positions is None:
        positions = jnp.arange(N, dtype=jnp.int32)[None, :]
    q = _project_q(params, x, n_heads, cfg, positions, rope_theta)
    c_kv, k_pe = _project_kv_latent(params, x, cfg, positions, rope_theta)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    out = exchange_attention_mla(q, c_kv, k_pe, params["w_uk"], params["w_uv"],
                                 xcfg, causal=True, scale=scale)
    return out.reshape(B, N, n_heads * cfg.v_head_dim) @ params["wo"]


def mla_prefill(params: Params, x: jnp.ndarray, n_heads: int, cfg: MLACfg,
                xcfg: ExchangeConfig, cache: Dict[str, jnp.ndarray],
                *, positions: Optional[jnp.ndarray] = None,
                rope_theta: float = 10000.0
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence MLA attention that also bulk-writes the latent cache
    for positions [0, N) — the single-pass prefill analogue of
    ``mla_block`` (same math) + ``mla_decode``'s cache updates."""
    B, N, _ = x.shape
    if positions is None:
        positions = jnp.arange(N, dtype=jnp.int32)[None, :]
    q = _project_q(params, x, n_heads, cfg, positions, rope_theta)
    c_kv, k_pe = _project_kv_latent(params, x, cfg, positions, rope_theta)
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), 0, axis=1)
    pe_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), 0, axis=1)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    out = exchange_attention_mla(q, c_kv, k_pe, params["w_uk"], params["w_uv"],
                                 xcfg, causal=True, scale=scale)
    y = out.reshape(B, N, n_heads * cfg.v_head_dim) @ params["wo"]
    return y, {"c_kv": c_cache, "k_pe": pe_cache}


def mla_decode(params: Params, x: jnp.ndarray, n_heads: int, cfg: MLACfg,
               xcfg: ExchangeConfig, cache: Dict[str, jnp.ndarray],
               cache_index, *, rope_theta: float = 10000.0
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Absorbed-form decode over the latent cache.

    logits_h = q_nope_h·W_uk_h·c_kv^T + q_pe·k_pe^T ;  out_h = (p·c_kv)·W_uv_h
    — the per-token work in the cache dimension is O(r + d_rope), and the
    latent cache shards over the sequence axis exactly like a K/V cache
    (flash-decoding LSE merge, see below).
    """
    B = x.shape[0]
    H = n_heads
    pos = jnp.full((B, 1), cache_index, jnp.int32)
    q = _project_q(params, x, H, cfg, pos, rope_theta)           # [B,1,H,dq]
    q_nope, q_pe = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    c_new, pe_new = _project_kv_latent(params, x, cfg, pos, rope_theta)
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), cache_index, axis=1)
    pe_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k_pe"], pe_new.astype(cache["k_pe"].dtype), cache_index, axis=1)

    # absorb: q_lat[b,1,h,r] = q_nope · W_uk^T
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, params["w_uk"])
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    cache_len = cache_index + 1

    from repro.core.exchange import mla_decode_attention_sharded
    o_lat = mla_decode_attention_sharded(
        q_lat, q_pe, c_cache, pe_cache, cache_len, xcfg, scale=scale)
    out = jnp.einsum("bqhr,rhd->bqhd", o_lat, params["w_uv"])
    y = out.reshape(B, 1, H * cfg.v_head_dim) @ params["wo"]
    return y, {"c_kv": c_cache, "k_pe": pe_cache}


def init_mla_cache(batch: int, seq: int, cfg: MLACfg, dtype):
    return {"c_kv": jnp.zeros((batch, seq, cfg.kv_lora_rank), dtype),
            "k_pe": jnp.zeros((batch, seq, cfg.qk_rope_dim), dtype)}
