"""Shared model building blocks (pure-JAX, module-free).

Conventions
-----------
* Parameters are nested dicts of ``jnp.ndarray`` (or ``ShapeDtypeStruct`` in
  abstract/dry-run mode); every layer ships an ``init_*`` and an ``apply``
  function.  No framework dependency beyond jax.
* Weights are stored in ``cfg.dtype`` (bf16 by default); math that needs it
  (norms, softmax, RoPE) runs in f32 and casts back.
* Attention is the paper's integration point: ``ExchangeConfig`` decides how
  K/V cross sequence partitions (LOCAL / VOLTAGE full-tensor / PRISM segment
  means) — see ``repro.core.exchange``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.exchange import (ExchangeConfig, ExchangeMode,
                                 decode_attention_sharded, exchange_attention)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = (d_in ** -0.5) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}        # stored zero-centered


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with (1 + scale) weighting (llama/gemma convention)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(kind: str, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return layernorm(params, x) if kind == "layernorm" else rmsnorm(params, x)


def init_norm(kind: str, d: int, dtype=jnp.float32) -> Params:
    return init_layernorm(d, dtype) if kind == "layernorm" else init_rmsnorm(d, dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_tables(positions: jnp.ndarray, head_dim: int, theta: float):
    """cos/sin tables for given (possibly sharded) integer positions.

    positions: [..., N] int32 global positions → ([..., N, hd/2], ...) f32.
    """
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate [..., N, H, hd] by per-position tables [..., N, hd/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]        # broadcast over heads
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, dtype, gated: bool = True) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def apply_mlp(params: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    up = x @ params["w_up"]
    if "w_gate" in params:
        gate = x @ params["w_gate"]
        h = _act(gate, act) * up
    else:
        h = _act(up, act)
    return h @ params["w_down"]


def _act(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "gelu_tanh":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {kind}")


# ---------------------------------------------------------------------------
# GQA attention with PRISM/Voltage/local exchange
# ---------------------------------------------------------------------------

def init_attention(key, d: int, n_heads: int, n_kv: int, head_dim: int, dtype,
                   qkv_bias: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d, n_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d, n_kv * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Static attention behaviour for one layer."""
    n_heads: int
    n_kv: int
    head_dim: int
    causal: bool = True
    window: Optional[int] = None          # sliding window (gemma2 local layers)
    logit_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    use_rope: bool = True
    scale: Optional[float] = None         # override 1/sqrt(hd) (gemma2 uses
                                          # query_pre_attn_scalar)


def project_qkv(params: Params, x: jnp.ndarray, spec: AttnSpec,
                positions: Optional[jnp.ndarray]):
    """Linear projections + RoPE. x: [B, N, D] → q [B,N,H,hd], k/v [B,N,Hk,hd]."""
    B, N, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, N, spec.n_heads, spec.head_dim)
    k = k.reshape(B, N, spec.n_kv, spec.head_dim)
    v = v.reshape(B, N, spec.n_kv, spec.head_dim)
    if spec.use_rope:
        if positions is None:
            positions = jnp.arange(N, dtype=jnp.int32)[None, :]
        cos, sin = rope_tables(positions, spec.head_dim, spec.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def attention_block(
    params: Params,
    x: jnp.ndarray,                       # [B, N, D] (N possibly seq-sharded)
    spec: AttnSpec,
    xcfg: ExchangeConfig,
    *,
    positions: Optional[jnp.ndarray] = None,   # [B, N] global positions
) -> jnp.ndarray:
    """Full-sequence (train/prefill) attention with the configured exchange."""
    q, k, v = project_qkv(params, x, spec, positions)
    out = exchange_attention(
        q, k, v, xcfg, causal=spec.causal, window=spec.window,
        logit_softcap=spec.logit_softcap, scale=spec.scale)
    B, N = x.shape[:2]
    return out.reshape(B, N, spec.n_heads * spec.head_dim) @ params["wo"]


def _quantize_kv(t: jnp.ndarray):
    """Symmetric per-(token, head) int8 quantization: [B,1,Hk,dh] →
    (int8 values, f32 scale [B,1,Hk])."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attention_decode(
    params: Params,
    x: jnp.ndarray,                       # [B, 1, D] new token features
    spec: AttnSpec,
    xcfg: ExchangeConfig,
    cache: Dict[str, jnp.ndarray],        # {"k": [B,S,Hk,hd], "v": ..., }
    cache_index,                          # scalar int32 — write position
    *,
    k_means: Optional[jnp.ndarray] = None,
    v_means: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One autoregressive step against a (possibly sequence-sharded) cache.

    Caches created with ``quant=True`` hold int8 values + per-(token, head)
    f32 scales; dequantization happens per layer on the device-local shard
    (transient bf16, the resident cache stays int8 — 2× HBM saving)."""
    B = x.shape[0]
    pos = jnp.full((B, 1), cache_index, jnp.int32)
    q, k_new, v_new = project_qkv(params, x, spec, pos)
    quant = "k_scale" in cache
    if quant:
        k_q, k_s = _quantize_kv(k_new)
        v_q, v_s = _quantize_kv(v_new)
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k_q, cache_index, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v_q, cache_index, axis=1),
            "k_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], k_s, cache_index, axis=1),
            "v_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], v_s, cache_index, axis=1),
        }
        k_cache = _dequantize_kv(new_cache["k"], new_cache["k_scale"],
                                 x.dtype)
        v_cache = _dequantize_kv(new_cache["v"], new_cache["v_scale"],
                                 x.dtype)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), cache_index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), cache_index, axis=1)
    cache_len = cache_index + 1
    if spec.window is not None:
        # sliding-window cache: only the last `window` positions are valid
        # (device-local — no sharded merge); kernel-dispatched.
        from repro.kernels import dispatch as kdsp
        out = kdsp.decode_attention(q, k_cache, v_cache, cache_len,
                                    window=spec.window,
                                    logit_softcap=spec.logit_softcap,
                                    scale=spec.scale)
    else:
        out = decode_attention_sharded(
            q, k_cache, v_cache, cache_len, xcfg,
            logit_softcap=spec.logit_softcap, scale=spec.scale,
            k_means=k_means, v_means=v_means)
    y = out.reshape(B, 1, spec.n_heads * spec.head_dim) @ params["wo"]
    if quant:
        return y, new_cache
    return y, {"k": k_cache, "v": v_cache}


def attention_decode_paged(
    params: Params,
    x: jnp.ndarray,                       # [S, 1, D] new token features
    spec: AttnSpec,
    xcfg: ExchangeConfig,
    cache: Dict[str, jnp.ndarray],        # {"k": [P,ps,Hk,hd], "v": ...}
    page_table: jnp.ndarray,              # [S, max_pages] int32
    lengths: jnp.ndarray,                 # [S] int32 — per-row write position
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One autoregressive step against a shared *paged* KV pool.

    Unlike ``attention_decode`` (scalar ``cache_index``, dense per-request
    cache, vmapped per row by the serving chunk), all rows step together
    here — the pool is shared state, so per-row vmap would fork it.  Row
    ``b`` writes its new K/V at logical position ``lengths[b]``, which the
    page table resolves to physical ``(page_table[b, len//ps], len % ps)``;
    attention then reads through ``kdsp.decode_attention_paged``.  Rows never
    write into shared (refcount > 1) pages: the allocator COW-copies any
    partially-filled shared page at admit, so a row's write frontier always
    lands in a page it exclusively owns (or the trash page, for idle rows).
    """
    if spec.window is not None:
        raise NotImplementedError("paged decode has no sliding-window path")
    B = x.shape[0]
    pos = lengths[:, None].astype(jnp.int32)                  # [S, 1]
    q, k_new, v_new = project_qkv(params, x, spec, pos)
    ps = cache["k"].shape[1]
    blk = (lengths // ps).astype(jnp.int32)
    wp = jnp.take_along_axis(page_table, blk[:, None], axis=1)[:, 0]  # [S]
    off = lengths % ps
    k_pool = cache["k"].at[wp, off].set(k_new[:, 0].astype(cache["k"].dtype))
    v_pool = cache["v"].at[wp, off].set(v_new[:, 0].astype(cache["v"].dtype))
    from repro.kernels import dispatch as kdsp
    out = kdsp.decode_attention_paged(
        q, k_pool, v_pool, page_table, lengths + 1,
        logit_softcap=spec.logit_softcap, scale=spec.scale)
    y = out.reshape(B, 1, spec.n_heads * spec.head_dim) @ params["wo"]
    return y, {"k": k_pool, "v": v_pool}


def prefill_kv_cache(cache: Dict[str, jnp.ndarray], k_new: jnp.ndarray,
                     v_new: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Bulk-write projected prompt K/V [B, T0, Hk, hd] into positions
    [0, T0) of a decode cache (single-pass prefill).  Quantized caches get
    the same per-(token, head) int8 quantization the per-step path applies,
    so prefill-then-decode and decode-only caches are bit-identical."""
    if "k_scale" in cache:
        k_q, k_s = _quantize_kv(k_new)
        v_q, v_s = _quantize_kv(v_new)
        upd = {"k": k_q, "v": v_q, "k_scale": k_s, "v_scale": v_s}
        return {name: jax.lax.dynamic_update_slice_in_dim(
                    cache[name], val, 0, axis=1)
                for name, val in upd.items()}
    return {"k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k_new.astype(cache["k"].dtype), 0, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v_new.astype(cache["v"].dtype), 0, axis=1)}


def init_kv_cache(batch: int, seq: int, n_kv: int, head_dim: int, dtype,
                  quant: bool = False):
    shape = (batch, seq, n_kv, head_dim)
    if quant:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], jnp.float32),
                "v_scale": jnp.zeros(shape[:3], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# embeddings / unembed
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    return {"table": embed_init(key, vocab, d, dtype)}


def embed(params: Params, tokens: jnp.ndarray, scale_by_sqrt_d: bool = False):
    x = jnp.take(params["table"], tokens, axis=0)
    if scale_by_sqrt_d:
        x = x * jnp.asarray(x.shape[-1] ** 0.5, x.dtype)
    return x


def unembed(params: Params, x: jnp.ndarray,
            final_softcap: Optional[float] = None) -> jnp.ndarray:
    logits = (x @ params["table"].T).astype(jnp.float32)
    if final_softcap is not None:
        logits = final_softcap * jnp.tanh(logits / final_softcap)
    return logits
