from repro.data.pipeline import (SyntheticLMDataset, SyntheticImageDataset,
                                 make_lm_batch, synthetic_vit_task)

__all__ = ["SyntheticLMDataset", "SyntheticImageDataset", "make_lm_batch",
           "synthetic_vit_task"]
