"""Synthetic data pipelines (deterministic, host-side, prefetching).

No datasets ship offline, so training/serving examples consume synthetic
streams with enough structure to show learning: the LM stream is a Zipf-ish
Markov chain (so next-token loss has signal), and the image task embeds the
class label in low-frequency image structure (so the ViT accuracy experiment
in EXPERIMENTS.md §Paper-validation can show PRISM's CR↔accuracy trade-off
and fine-tuning recovery — the paper's Table 3 mechanism).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    order: int = 2          # Markov order — gives the LM something to learn

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        V = self.vocab_size
        # sparse transition table: each context maps to 8 likely tokens
        self._succ = rng.randint(0, V, size=(V, 8))

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.RandomState(self.seed + 1)
        while True:
            yield self.sample(rng)

    def sample(self, rng) -> Dict[str, np.ndarray]:
        B, N, V = self.batch_size, self.seq_len, self.vocab_size
        toks = np.empty((B, N + 1), np.int32)
        toks[:, 0] = rng.randint(0, V, size=B)
        for t in range(1, N + 1):
            ctx = toks[:, t - 1]
            choice = rng.randint(0, 8, size=B)
            noise = rng.rand(B) < 0.1
            nxt = self._succ[ctx, choice]
            nxt = np.where(noise, rng.randint(0, V, size=B), nxt)
            toks[:, t] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_lm_batch(vocab: int, batch: int, seq: int, seed: int = 0
                  ) -> Dict[str, np.ndarray]:
    ds = SyntheticLMDataset(vocab, seq, batch, seed=seed)
    return ds.sample(np.random.RandomState(seed))


@dataclasses.dataclass
class SyntheticImageDataset:
    """224² images whose class is encoded in low-frequency structure."""
    n_classes: int = 10
    batch_size: int = 16
    seed: int = 0
    noise: float = 0.35

    def sample(self, rng: Optional[np.random.RandomState] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        rng = rng or np.random.RandomState(self.seed)
        B, C = self.batch_size, self.n_classes
        labels = rng.randint(0, C, size=B)
        xs = np.linspace(0, 2 * np.pi, 224)
        yy, xx = np.meshgrid(xs, xs, indexing="ij")
        imgs = np.empty((B, 224, 224, 3), np.float32)
        for i, c in enumerate(labels):
            f = 1 + c % 5
            phase = (c // 5) * np.pi / 2
            base = np.sin(f * xx + phase) * np.cos(f * yy)
            img = np.stack([base, np.roll(base, 37, 0), -base], -1)
            imgs[i] = img + self.noise * rng.randn(224, 224, 3)
        return imgs.astype(np.float32), labels.astype(np.int32)

    def batches(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        rng = np.random.RandomState(self.seed)
        while True:
            yield self.sample(rng)


def synthetic_vit_task(batch: int, seed: int = 0):
    return SyntheticImageDataset(batch_size=batch, seed=seed).sample()


class Prefetcher:
    """Background-thread prefetch wrapper around any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
