"""Adaptive micro-batch scheduler + runtime hooks.

The paper's policy decides *how* a batch executes (local vs distributed(CR))
— the scheduler decides *what the batch is*: it queries the compiled
:class:`~repro.profiling.table.PolicyTable` across the profiled batch grid
(:meth:`PolicyTable.plan_batch`) and forms the micro-batch whose size AND
mode/CR minimize the active objective per queued request, padding to the
nearest profiled grid point (flagged) when the queue is short.  On
integrated-GPU edge hardware batch composition is the dominant performance
lever (arXiv 2508.08430), so batch formation goes through the same profiled
table as routing.

Two hook classes wire the orphaned ``repro.runtime`` machinery into the
serving loop:

* :class:`StragglerHook` — feeds observed per-device step times to
  :class:`~repro.runtime.straggler.StragglerMitigator` and, when a device
  persistently lags, derives rebalanced sequence partitions for the active
  PRISM plan.
* :class:`FaultHook` — heartbeat-miss detection
  (:class:`~repro.runtime.fault.HeartbeatMonitor`) → elastic re-mesh
  (:class:`~repro.runtime.elastic.ElasticMeshManager.drop` with the
  *explicit* failed ids) → the runtime re-admits in-flight requests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence

from repro.core.policy import BatchPlan, ObjectiveLike, resolve_objective
from repro.serving.queue import Request, RequestQueue


@dataclasses.dataclass
class MicroBatch:
    """One scheduling decision: these requests, this plan, this shape."""
    requests: List[Request]
    plan: BatchPlan                        # table-derived batch formation
    exec_key: str                          # executable id ("local"/"prism@x")

    @property
    def extrapolated(self) -> bool:
        return self.plan.extrapolated or self.plan.decision.extrapolated


class AdaptiveScheduler:
    """Forms micro-batches from the queue via the compiled policy table.

    ``session`` supplies the profiled policy and the bandwidth estimate;
    ``objective`` defaults to the session's.  ``max_wait_ms`` bounds how
    long the scheduler holds a short queue hoping to fill the cheapest
    profiled batch before admitting what it has (latency/throughput knob).
    """

    def __init__(self, session, objective: Optional[ObjectiveLike] = None,
                 max_wait_ms: float = 0.0):
        self.session = session
        self.objective = (resolve_objective(objective) if objective
                          else session.objective)
        self.max_wait_ms = max_wait_ms
        self.history: List[MicroBatch] = []

    def _table(self):
        return self.session.policy.table(self.objective)

    def plan_batch(self, n_queued: int,
                   max_batch: Optional[int] = None) -> BatchPlan:
        return self._table().plan_batch(n_queued, self.session.bandwidth,
                                        max_batch=max_batch)

    def next_batch(self, queue: RequestQueue, free_slots: int,
                   idle: bool = True,
                   now: Optional[float] = None) -> Optional[MicroBatch]:
        """Form the next micro-batch, or None to wait.

        Holds back only when the pool is still busy (``idle=False``), the
        queue is shorter than the planned batch wants, and nothing has
        waited past ``max_wait_ms`` — a brief hold can fill a cheaper grid
        batch, but never at the cost of an idle pool or a deadline.
        """
        if not queue or free_slots <= 0:
            return None
        plan = self.plan_batch(len(queue), max_batch=free_slots)
        if (not idle and plan.n_admit < plan.batch
                and queue.oldest_wait_ms(now) < self.max_wait_ms):
            return None
        reqs = queue.pop_many(plan.n_admit, now=now)
        if not reqs:                   # everything queued had expired
            return None
        mb = MicroBatch(requests=reqs, plan=plan,
                        exec_key=plan.decision.exec_key)
        self.history.append(mb)
        return mb


# ---------------------------------------------------------------------------
# runtime hooks
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RebalanceEvent:
    """A straggler-driven partition rebalance proposal."""
    stragglers: List[int]                  # device indices flagged
    partitions: List[int]                  # proposed token counts per device
    n_tokens: int
    seg_size: int


class StragglerHook:
    """Feed observed per-device step times into the mitigator; when a
    device persistently lags, emit rebalanced sequence partitions for the
    active plan (PRISM's partitions need not be equal — the master
    re-balances the position-wise split)."""

    def __init__(self, n_devices: int, seg_size: int = 1, **mitigator_kw):
        from repro.runtime.straggler import StragglerMitigator
        self.mitigator = StragglerMitigator(n_devices=n_devices,
                                            **mitigator_kw)
        self.seg_size = max(int(seg_size), 1)
        self.events: List[RebalanceEvent] = []
        self.chunk_walls_ms: List[float] = []

    def observe_chunk(self, wall_ms: float, n_steps: int) -> None:
        """Record one decode chunk's per-step wall time (runtime
        telemetry).  This deliberately does NOT feed the mitigator: a
        single-host chunk wall has no per-device resolution, and uniform
        fabricated times would both never flag a straggler and dilute any
        genuine per-device observations fed through :meth:`observe`."""
        self.chunk_walls_ms.append(wall_ms / max(n_steps, 1))

    def observe(self, step_times: Sequence[float],
                n_tokens: int) -> Optional[RebalanceEvent]:
        """Called once per decode chunk with per-device wall times; returns
        a rebalance proposal iff a straggler is (still) flagged.  A
        workload too small to give every device a segment yields no
        proposal — telemetry must never abort the serving loop."""
        self.mitigator.observe(step_times)
        stragglers = self.mitigator.stragglers()
        if not stragglers:
            return None
        if n_tokens // self.seg_size < self.mitigator.n_devices:
            return None
        parts = self.mitigator.rebalanced_partitions(n_tokens, self.seg_size)
        ev = RebalanceEvent(stragglers=stragglers, partitions=parts,
                            n_tokens=n_tokens, seg_size=self.seg_size)
        self.events.append(ev)
        return ev


@dataclasses.dataclass
class FailoverEvent:
    """One heartbeat-miss → re-mesh → re-admit cycle."""
    dead: List[Any]
    survivors: int
    requeued: int


class FaultHook:
    """Heartbeat-driven failover: detect dead participants, shrink the
    device set through :class:`ElasticMeshManager` (explicit ids — the
    tail-truncation bug is fixed), and tell the runtime to re-admit
    whatever was in flight."""

    def __init__(self, monitor=None, mesh_manager=None,
                 nodes: Sequence[str] = ("n0",), timeout_s: float = 10.0):
        from repro.runtime.fault import HeartbeatMonitor
        self.monitor = monitor or HeartbeatMonitor(list(nodes),
                                                   timeout_s=timeout_s)
        self.mesh_manager = mesh_manager
        self.events: List[FailoverEvent] = []

    def beat(self, node: str) -> None:
        self.monitor.beat(node)

    def check(self) -> Optional[List[str]]:
        """Dead node list iff a failover should run now (once per failure:
        dead nodes are dropped from future checks)."""
        dead = self.monitor.dead_nodes()
        if not dead:
            return None
        for n in dead:                     # consume: controller drops them
            self.monitor.remove(n)
        if self.mesh_manager is not None:
            known = [d for d in dead if self._known(d)]
            if known:
                self.mesh_manager.drop(known, rebuild=False)
        return dead

    def _known(self, node) -> bool:
        devs = self.mesh_manager.devices
        return any(d is node or d == node or getattr(d, "id", None) == node
                   for d in devs)

    def record(self, dead: List[Any], requeued: int) -> FailoverEvent:
        ev = FailoverEvent(dead=list(dead),
                           survivors=(len(self.mesh_manager.devices)
                                      if self.mesh_manager else
                                      len(self.monitor.nodes)),
                           requeued=requeued)
        self.events.append(ev)
        return ev
