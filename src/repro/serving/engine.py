"""Serving engine: prefill / decode step builders + a batched request loop.

NOTE: ``ServeEngine`` is a deprecation shim — ``repro.api.InferenceSession``
(``session.generate(...)``) is the supported generation surface. The step
builders (``build_prefill_step`` / ``build_decode_step``) remain the
canonical jit targets for the dry-run ``decode_*``/``long_*`` shapes.

``serve_step`` is one-token decode against a sequence-sharded KV cache, with
greedy/temperature sampling; adaptive LOCAL-vs-PRISM routing lives in
``repro.api.InferenceSession.dispatch``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.exchange import ExchangeConfig
from repro.models import registry
from repro.models import transformer as tfm


def build_prefill_step(cfg: ModelConfig, xcfg: ExchangeConfig) -> Callable:
    """Full-sequence forward returning last-position logits + primed cache."""

    def prefill_step(params, batch, cache):
        logits, _ = registry.forward_fn(cfg)(params, batch, xcfg)
        cache = tfm.prefill_memory(params, batch, cfg, xcfg, cache)
        return logits[:, -1:], cache

    return prefill_step


def build_decode_step(cfg: ModelConfig, xcfg: ExchangeConfig) -> Callable:
    """serve_step: one new token given a cache of the current length."""

    def serve_step(params, batch, cache, cache_index):
        logits, cache = tfm.decode_step(params, batch, cache, cache_index,
                                        cfg, xcfg)
        return logits, cache

    return serve_step


def sample_token(logits: jnp.ndarray, key, temperature: float = 0.0):
    """[B, 1, V] → [B, 1] token ids (greedy at T=0)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / temperature
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class ServeEngine:
    """Minimal batched generation loop over the jitted steps.

    .. deprecated:: use ``repro.api.InferenceSession.generate`` instead.
    """
    cfg: ModelConfig
    xcfg: ExchangeConfig
    params: Any
    max_len: int = 256
    temperature: float = 0.0

    def __post_init__(self):
        import warnings
        warnings.warn("ServeEngine is deprecated; use "
                      "repro.api.InferenceSession.generate",
                      DeprecationWarning, stacklevel=2)
        self._decode = jax.jit(build_decode_step(self.cfg, self.xcfg),
                               donate_argnums=(2,))

    def generate(self, prompt_tokens: jnp.ndarray, n_new: int,
                 batch_extras: Optional[Dict[str, jnp.ndarray]] = None,
                 seed: int = 0):
        """prompt_tokens: [B, T0] → generated [B, n_new] (greedy/T)."""
        B, T0 = prompt_tokens.shape
        S = T0 + n_new
        cache = tfm.init_decode_cache(self.cfg, B, S)
        if self.cfg.family in ("audio", "vlm"):
            batch = {"tokens": prompt_tokens, **(batch_extras or {})}
            cache = tfm.prefill_memory(self.params, batch, self.cfg,
                                       self.xcfg, cache)
        key = jax.random.key(seed)
        # teacher-forced prompt consumption token by token (prefill-by-decode;
        # the batched prefill path is build_prefill_step)
        tok = prompt_tokens[:, :1]
        out = []
        logits = None
        for t in range(S - 1):
            logits, cache = self._decode(self.params, {"tokens": tok}, cache,
                                         t)
            if t + 1 < T0:
                tok = prompt_tokens[:, t + 1:t + 2]
            else:
                key, sub = jax.random.split(key)
                tok = sample_token(logits, sub, self.temperature)[:, 0:1]
                out.append(tok)
            if len(out) >= n_new:
                break
        return jnp.concatenate(out, axis=1) if out else jnp.zeros((B, 0),
                                                                  jnp.int32)
