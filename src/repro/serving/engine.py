"""Serving runtime: continuous-batching decode on a slot-based KV-cache pool.

``ServingRuntime`` is the request-level serving loop the ROADMAP's
"heavy traffic" north-star needs: a bounded :class:`RequestQueue` feeds an
:class:`AdaptiveScheduler` that forms micro-batches from the compiled policy
table; admitted requests are prefilled one-by-one (``session.prime_slot``,
exactly ``generate``'s front half) and scattered into free rows of a pooled
decode cache; decode then runs in fixed-size chunks over ALL slots in one
jitted executable per (plan, slot-count) — new requests are admitted into
freed slots *between* chunks, finished sequences are evicted, and per-slot
PRNG keys keep every request token-exact with a sequential
``session.generate`` (greedy or sampled).

Fault/straggler wiring: a :class:`FaultHook` (heartbeat miss → elastic
re-mesh → re-admit in-flight requests) and a :class:`StragglerHook`
(observed per-device step times → partition rebalance proposal) plug into
``step()``.

The legacy step builders (``build_prefill_step``/``build_decode_step``)
remain the canonical jit targets for dry-run shape analysis.  The old
``ServeEngine``/``AdaptiveDispatcher`` shims are **removed** — use
``InferenceSession.generate``/``InferenceSession.dispatch`` (single
batches) or :class:`ServingRuntime` (request traffic).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.exchange import ExchangeConfig
from repro.models import registry
from repro.models import transformer as tfm
from repro.obs import MetricsRegistry, StatsDict, maybe_span, request_trace_id
from repro.serving.queue import Request, RequestQueue
from repro.serving.scheduler import (AdaptiveScheduler, FaultHook,
                                     MicroBatch, StragglerHook)


def build_prefill_step(cfg: ModelConfig, xcfg: ExchangeConfig) -> Callable:
    """Full-sequence forward returning last-position logits + primed cache."""

    def prefill_step(params, batch, cache):
        logits, _ = registry.forward_fn(cfg)(params, batch, xcfg)
        cache = tfm.prefill_memory(params, batch, cfg, xcfg, cache)
        return logits[:, -1:], cache

    return prefill_step


def build_decode_step(cfg: ModelConfig, xcfg: ExchangeConfig) -> Callable:
    """serve_step: one new token given a cache of the current length."""

    def serve_step(params, batch, cache, cache_index):
        logits, cache = tfm.decode_step(params, batch, cache, cache_index,
                                        cfg, xcfg)
        return logits, cache

    return serve_step


# canonical home is repro.api.generation; re-exported for legacy imports
from repro.api.generation import sample_token  # noqa: E402,F401

_NULL_CTX = contextlib.nullcontext()


@functools.lru_cache(maxsize=None)
def _placeholder_keys(n: int):
    """One shared ``[n]`` placeholder PRNG-key array per size.

    Every pool used to rebuild ``jnp.stack([jax.random.key(0)] * n)`` in
    its constructor — n host→device transfers plus a stack, re-done for
    every plan's pool.  The values are placeholders (``admit`` overwrites a
    row's key before any decode reads it), so one cached array per size is
    safe to share: jax arrays are immutable and the pools only ever
    functionally replace the whole vector."""
    base = jax.random.key(0)
    try:
        return jnp.broadcast_to(base, (n,))
    except Exception:                  # older jax: key arrays can't broadcast
        return jnp.stack([base] * n)


@dataclasses.dataclass
class Completion:
    """One finished request with its serving telemetry."""
    request_id: int
    tokens: np.ndarray                 # [n_new] generated token ids
    plan_key: str                      # executable family that decoded it
    arrival_ts: float
    admitted_ts: float
    finished_ts: float
    slo_ms: Optional[float] = None
    extrapolated: bool = False         # scheduled off the profiled grid
    codec: str = ""                    # exchange codec of the serving plan
    wire_bytes: int = 0                # modeled bytes-on-wire, this request
    worker: str = ""                   # serving worker, when fleet-routed

    @property
    def latency_ms(self) -> float:
        return 1e3 * (self.finished_ts - self.arrival_ts)

    @property
    def queue_ms(self) -> float:
        return 1e3 * (self.admitted_ts - self.arrival_ts)

    @property
    def slo_met(self) -> Optional[bool]:
        if self.slo_ms is None:
            return None
        return self.latency_ms <= self.slo_ms


@dataclasses.dataclass
class _Active:
    """Host-side bookkeeping for one occupied slot.

    ``first_tok`` stays a device scalar until completion — pulling it at
    admission would insert a host sync between prefill and the next decode
    chunk.  ``tokens`` holds the chunk-produced tokens (the first generated
    token is ``first_tok``, sampled by prefill)."""
    request: Request
    admitted_ts: float
    exec_key: str
    extrapolated: bool
    first_tok: Any = None                  # [1, 1] device array
    tokens: List[int] = dataclasses.field(default_factory=list)
    codec: str = ""                        # exchange codec of the plan
    wire_bytes: int = 0                    # modeled per-request wire bytes
    decode_start: float = 0.0              # tracer stamp: admission done

    @property
    def emitted(self) -> int:
        return 1 + len(self.tokens)

    @property
    def done(self) -> bool:
        return self.emitted >= self.request.n_new

    def token_array(self) -> np.ndarray:
        out = [int(np.asarray(self.first_tok)[0, 0])]
        out.extend(self.tokens[:self.request.n_new - 1])
        return np.asarray(out, np.int32)


class SlotPool:
    """One pooled decode cache + per-slot device state for one plan.

    Slot state lives in four device arrays (pooled cache, current token
    [S], write position [S], PRNG key [S]) so a decode chunk is ONE
    executable; the request-to-slot map stays on the host.
    """

    def __init__(self, session, plan, n_slots: int, max_len: int):
        self.session = session
        self.plan = plan
        self.n_slots = n_slots
        self.max_len = max_len
        self.tracer = None                 # set by ServingRuntime._pool
        self.trace_worker = ""
        self.cache = session.init_slot_pool(n_slots, max_len)
        self.tok = jnp.zeros((n_slots,), jnp.int32)
        self.lengths = jnp.zeros((n_slots,), jnp.int32)
        self.keys = _placeholder_keys(n_slots)
        self.temps = jnp.zeros((n_slots,), jnp.float32)
        self.slots: List[Optional[_Active]] = [None] * n_slots

    # -- occupancy -----------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    # -- admission / eviction ------------------------------------------------

    def admit(self, req: Request, slot: int, exec_key: str,
              extrapolated: bool, now: float) -> _Active:
        """Prefill one request and scatter it into ``slot``: after this the
        slot decodes exactly like ``session.generate(prompt[None], ...)``."""
        if self.slots[slot] is not None:
            raise RuntimeError(f"slot {slot} is occupied")
        if req.total_len > self.max_len:
            raise ValueError(
                f"request needs {req.total_len} positions but the pool is "
                f"sized for {self.max_len}; raise ServingRuntime(max_len=)")
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        with maybe_span(self.tracer, "prefill", kind="serving",
                        worker=self.trace_worker,
                        prompt_len=req.prompt_len):
            tok0, cache, key = self.session.prime_slot(
                prompt, total_len=self.max_len, plan=self.plan,
                seed=req.seed, temperature=req.temperature)
        with maybe_span(self.tracer, "admit", kind="serving",
                        worker=self.trace_worker, slot=slot):
            (self.cache, self.tok, self.lengths, self.keys, self.temps) = \
                self.session.admit_slot(self.cache, self.tok, self.lengths,
                                        self.keys, self.temps, cache, slot,
                                        tok0, req.prompt_len, key,
                                        req.temperature)
        from repro.transport import plan_wire_bytes
        wire = plan_wire_bytes(self.plan, self.session.cfg, 1,
                               req.prompt_len)
        active = _Active(request=req, admitted_ts=now, exec_key=exec_key,
                         extrapolated=extrapolated, first_tok=tok0,
                         codec=(self.plan.effective_codec if wire else ""),
                         wire_bytes=wire)
        self.slots[slot] = active
        return active

    def evict(self, slot: int) -> _Active:
        act, self.slots[slot] = self.slots[slot], None
        return act

    def drain(self) -> List[Request]:
        """Drop every in-flight request (fault re-admission path)."""
        reqs = [s.request for s in self.slots if s is not None]
        self.slots = [None] * self.n_slots
        return reqs

    # -- decode --------------------------------------------------------------

    def decode_chunk(self, n_steps: int) -> float:
        """One chunk over all slots; appends tokens to active requests and
        returns the wall ms the chunk took (straggler signal)."""
        t0 = time.perf_counter()
        toks, self.cache, self.lengths, self.keys = \
            self.session.decode_chunk(self.cache, self.tok, self.lengths,
                                      self.keys, self.temps,
                                      n_steps=n_steps, plan=self.plan,
                                      max_len=self.max_len)
        self.tok = toks[:, -1]
        out = np.asarray(toks)
        wall_ms = 1e3 * (time.perf_counter() - t0)
        for i, act in enumerate(self.slots):
            if act is None or act.done:
                continue
            need = act.request.n_new - act.emitted
            act.tokens.extend(int(t) for t in out[i, :need])
        return wall_ms


class ServingRuntime:
    """Policy-driven request serving over an :class:`InferenceSession`.

    One ``step()`` = failover check → admissions (scheduler-formed
    micro-batch into free slots) → one decode chunk per active pool →
    evictions.  ``run()`` steps until queue and pools are empty.  Per-plan
    pools keep decode executables at one per (plan, slot-count); all pools
    share the session's params.

    **Paged mode** (``page_size=``/``n_pages=``): pools become
    :class:`~repro.serving.pages.PagedPool` — a budget-sized shared page
    pool instead of ``n_slots`` dense ``max_len`` rows.  Admission is then
    bounded by free *pages* (each request commits
    ``ceil(total_len/page_size)`` pages), row count defaults to
    ``n_pages`` (one-page requests can fill the whole budget), and prompts
    sharing a cached prefix skip the shared part of prefill entirely.

    Memory note (dense mode): every plan that receives traffic lazily
    allocates its own ``n_slots``-row cache pool even though global
    concurrency is capped at ``n_slots`` — with K plans in rotation the
    resident decode-cache HBM is up to K× what the admitted load can use.
    Paged mode is the budget-sized answer: pools size by pages, not by
    worst-case rows.
    """

    def __init__(self, session, *, n_slots: int = 4, chunk: int = 8,
                 max_len: int = 256, queue_size: int = 1024,
                 scheduler: Optional[AdaptiveScheduler] = None,
                 fault_hook: Optional[FaultHook] = None,
                 straggler_hook: Optional[StragglerHook] = None,
                 shed_expired: bool = False,
                 clock: Callable[[], float] = time.monotonic,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 n_rows: Optional[int] = None,
                 prefix_cache: bool = True,
                 cold_horizon: Optional[int] = None,
                 cold_codec: str = "int8",
                 metrics: Optional[MetricsRegistry] = None,
                 tracer=None, worker: str = ""):
        if n_slots <= 0 or chunk <= 0:
            raise ValueError("n_slots and chunk must be >= 1")
        self.paged = page_size is not None or n_pages is not None
        if self.paged:
            # --slots stays meaningful as a *budget* alias: the dense pool
            # held n_slots·max_len positions, so that is the page budget
            self.page_size = page_size or 16
            self.n_pages = (n_pages if n_pages is not None
                            else max(1, n_slots * max_len // self.page_size))
            self.max_pages = -(-max_len // self.page_size)
            # rows bound concurrency; pages bound memory — default to one
            # row per page so short requests can fill the whole budget
            n_slots = n_rows if n_rows is not None else self.n_pages
        self.prefix_cache = prefix_cache
        self.cold_horizon = cold_horizon
        self.cold_codec = cold_codec
        self.session = session
        self.n_slots = n_slots
        self.chunk = chunk
        self.max_len = max_len
        self.queue = RequestQueue(queue_size, shed_expired=shed_expired)
        self.scheduler = scheduler or AdaptiveScheduler(session)
        self.fault_hook = fault_hook
        self.straggler_hook = straggler_hook
        self.chaos = None                 # ChaosController.attach target
        self.chaos_name = "runtime"       # fault-schedule key for this node
        # optional streaming hook: called after every decode chunk with
        # (request_id, tokens-so-far) per active request — the RPC worker
        # turns this into TokenChunk frames (repro.rpc.worker)
        self.on_progress: Optional[Callable[[int, List[int]], None]] = None
        self.clock = clock
        self.pools: Dict[str, Union[SlotPool, "PagedPool"]] = {}
        self.completions: List[Completion] = []
        # observability: every scalar counter lives in the registry under
        # serving.<key>; the tracer is opt-in (None = zero-cost guards)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer
        self.trace_worker = worker
        self._req_spans: Dict[int, Any] = {}   # open per-request root spans
        self._requeue_ts: Dict[int, float] = {}
        # hot-path handles: resolved once, not per chunk/completion
        self._chunk_hist = self.metrics.histogram("serving.chunk_ms")
        self._latency_hist = self.metrics.histogram(
            "serving.request_latency_ms")
        self.stats = StatsDict(
            self.metrics, "serving",
            {"steps": 0, "chunks": 0, "admitted": 0,
             "requeued": 0, "max_concurrent": 0, "retries": 0,
             "straggled": 0,
             "wire_bytes": 0},      # modeled bytes-on-wire admitted
            labels={"worker": worker} if worker else None)

    # -- request intake ------------------------------------------------------

    def submit(self, prompt, n_new: int, *, slo_ms: Optional[float] = None,
               seed: int = 0, temperature: float = 0.0) -> Request:
        return self.submit_request(
            Request(prompt=np.asarray(prompt), n_new=n_new, slo_ms=slo_ms,
                    seed=seed, temperature=temperature,
                    arrival_ts=self.clock()))

    def submit_request(self, req: Request) -> Request:
        if req.total_len > self.max_len:
            raise ValueError(
                f"request needs {req.total_len} positions but max_len is "
                f"{self.max_len}")
        return self.queue.put(req)

    # -- plan / pool resolution ----------------------------------------------

    def _pool(self, exec_key: str) -> Union[SlotPool, "PagedPool"]:
        key, plan = self.session.plan_for_key(exec_key)
        pool = self.pools.get(key)
        if pool is None:
            if self.paged:
                from repro.serving.pages import PagedPool
                pool = PagedPool(self.session, plan, self.n_slots,
                                 n_pages=self.n_pages,
                                 page_size=self.page_size,
                                 max_pages=self.max_pages,
                                 prefix_cache=self.prefix_cache,
                                 cold_horizon=self.cold_horizon,
                                 cold_codec=self.cold_codec)
            else:
                pool = SlotPool(self.session, plan, self.n_slots,
                                self.max_len)
            self.pools[key] = pool
        pool.tracer = self.tracer        # may be attached after pools exist
        pool.trace_worker = self.trace_worker
        return pool

    def _run_trace(self) -> str:
        """Trace id for runtime-level spans (decode chunks, failovers) that
        belong to no single request."""
        return f"runtime:{self.trace_worker or 'serving'}"

    def _free_slots(self) -> int:
        used = sum(p.n_active for p in self.pools.values())
        # pools share the slot budget conceptually; a fresh plan's pool
        # allocates lazily, so "free" is the budget minus what is in flight
        return max(self.n_slots - used, 0)

    @property
    def idle(self) -> bool:
        """True when no request is in flight in any pool."""
        return all(p.n_active == 0 for p in self.pools.values())

    # -- telemetry -----------------------------------------------------------

    def stats_snapshot(self) -> Dict[str, Any]:
        """Consistent point-in-time copy of the runtime counters.

        ``stats`` is a plain mutable dict updated mid-``step()``; a reader
        in another logical context (the fleet router, a benchmark thread)
        must not see half-updated state or hold a reference that keeps
        mutating under it.  The snapshot also folds in derived gauges —
        queue depth, in-flight count, completions, and the queue's shed
        accounting (rejected puts by reason).
        """
        snap = dict(self.stats)
        snap["queue_depth"] = len(self.queue)
        snap["in_flight"] = sum(p.n_active for p in self.pools.values())
        snap["completed"] = len(self.completions)
        snap["rejected"] = self.queue.rejected
        snap["rejections"] = dict(self.queue.rejections)
        snap["expired"] = self.queue.rejections.get("expired", 0)
        snap["failovers"] = (len(self.fault_hook.events)
                             if self.fault_hook is not None else 0)
        if self.paged:
            agg: Dict[str, Any] = {
                "pages_total": 0, "pages_free": 0, "pages_committed": 0,
                "prefix_hits": 0, "prefix_misses": 0, "full_hits": 0,
                "partial_hits": 0, "cow_splits": 0, "cold_pages": 0,
                "dequant_pages": 0, "prefix_entries": 0,
                "prefix_evictions": 0, "admit_ms": 0.0}
            for p in self.pools.values():
                for k, v in p.page_stats().items():
                    if k in agg:
                        agg[k] += v
            snap.update(agg)
            snap["page_occupancy"] = (
                1.0 - agg["pages_free"] / agg["pages_total"]
                if agg["pages_total"] else 0.0)
            looked = agg["prefix_hits"] + agg["prefix_misses"]
            snap["prefix_hit_rate"] = (agg["prefix_hits"] / looked
                                       if looked else 0.0)
        return snap

    # -- fleet support -------------------------------------------------------

    def drain_requests(self) -> List[Request]:
        """Pull every queued AND in-flight request out of this runtime
        (dead-worker path: the fleet router re-routes them to surviving
        workers).  Deadline order is recovered by the target queue's EDF
        ``pop``; re-served requests stay token-exact because ``seed``/
        ``temperature`` pin the sampling chain."""
        reqs = self.queue.drain()
        for pool in self.pools.values():
            reqs.extend(pool.drain())
        return reqs

    # -- the serving loop ----------------------------------------------------

    def step(self) -> List[Completion]:
        """One scheduling + decode round; returns completions it produced."""
        self.stats["steps"] += 1
        now = self.clock()
        self._check_faults(now)
        self._admit(now)
        done: List[Completion] = []
        tr = self.tracer
        for key, pool in self.pools.items():
            if pool.n_active == 0:
                continue
            straggle = 1.0
            if self.chaos is not None:
                fault = self.chaos.dispatch_fault(self.chaos_name, now)
                if fault is not None and fault.kind == "error":
                    # the chunk's exchange failed before any token was
                    # committed: nothing to roll back, retry next step
                    self.stats["retries"] += 1
                    if tr is not None:
                        tr.record("retry", start=now, end=now,
                                  kind="serving", trace_id=self._run_trace(),
                                  worker=self.trace_worker, plan=key,
                                  reason="chaos_error")
                    continue
                if fault is not None and fault.kind == "straggle":
                    straggle = max(fault.value, 1.0)
                    self.stats["straggled"] += 1
            t0 = self.clock()
            wall_ms = pool.decode_chunk(self.chunk)
            self.stats["chunks"] += 1
            self._observe_stragglers(pool, wall_ms * straggle)
            if self.on_progress is not None:
                for act in pool.slots:
                    if act is not None:
                        self.on_progress(act.request.id, act.tokens)
            fin = self.clock()
            if tr is not None:
                tr.record("decode_chunk", start=t0, end=fin, kind="serving",
                          trace_id=self._run_trace(),
                          worker=self.trace_worker, plan=key,
                          active=pool.n_active, steps=self.chunk)
                self._chunk_hist.observe(wall_ms)
            for i, act in enumerate(pool.slots):
                if act is not None and act.done:
                    pool.evict(i)
                    done.append(Completion(
                        request_id=act.request.id,
                        tokens=act.token_array(),
                        plan_key=key, arrival_ts=act.request.arrival_ts,
                        admitted_ts=act.admitted_ts, finished_ts=fin,
                        slo_ms=act.request.slo_ms,
                        extrapolated=act.extrapolated,
                        codec=act.codec, wire_bytes=act.wire_bytes))
                    if tr is not None:
                        self._finish_request(act, fin)
        self.completions.extend(done)
        return done

    def _finish_request(self, act: _Active, fin: float) -> None:
        """Close a finished request's trace: one ``decode`` residency leaf
        (admission-complete → finished) plus the root ``request`` span."""
        req = act.request
        root = self._req_spans.pop(req.id, None)
        tid = req.trace_id or request_trace_id(req.id)
        self.tracer.record(
            "decode", start=act.decode_start or act.admitted_ts,
            end=fin, kind="serving", trace_id=tid,
            parent_id=root.span_id if root is not None else None,
            worker=self.trace_worker, tokens=req.n_new)
        if root is not None:
            self.tracer.finish(root, at=fin)
        self._latency_hist.observe(1e3 * (fin - req.arrival_ts))

    def run(self, max_steps: int = 100_000) -> List[Completion]:
        """Serve until the queue and every pool are empty."""
        start = len(self.completions)
        steps = 0
        while (self.queue or not self.idle):
            self.step()
            steps += 1
            if steps >= max_steps:
                raise RuntimeError(f"run() exceeded {max_steps} steps")
        return self.completions[start:]

    def drive(self, prompts: Sequence, arrivals: Sequence[float], n_new,
              *, seeds: Optional[Sequence[int]] = None,
              slo_ms: Optional[float] = None,
              temperatures: Optional[Sequence[float]] = None,
              poll_s: float = 0.005) -> List[Completion]:
        """Replay a real-time arrival schedule: submit request ``i`` once
        ``arrivals[i]`` seconds have elapsed (``clock``-relative), stepping
        the runtime in between and sleeping only when there is nothing to
        do.  ``n_new`` is an int or a per-request sequence.  Returns the
        completions this drive produced — the one arrival loop shared by
        ``launch/serve.py`` and ``benchmarks/serve_throughput.py``."""
        start = len(self.completions)
        t0 = self.clock()
        pending = list(range(len(prompts)))
        while pending or self.queue or not self.idle:
            now = self.clock() - t0
            while pending and arrivals[pending[0]] <= now:
                if len(self.queue) >= self.queue.max_size:
                    break      # backpressure: resubmit after the next step
                i = pending.pop(0)
                self.submit(
                    prompts[i],
                    n_new[i] if not isinstance(n_new, int) else n_new,
                    seed=seeds[i] if seeds is not None else i,
                    slo_ms=slo_ms,
                    temperature=(temperatures[i] if temperatures is not None
                                 else 0.0))
            if self.queue or not self.idle:
                self.step()
            elif pending:
                time.sleep(min(max(arrivals[pending[0]] - now, 0.0),
                               poll_s))
        return self.completions[start:]

    # -- admission -----------------------------------------------------------

    def _request_root(self, req: Request):
        """Open (or reuse, on re-admission after a fault) the per-request
        root span.  ``req.parent_span`` — set by a fleet router or carried
        over the RPC wire — parents the whole tree under the client's
        dispatch span."""
        if not req.trace_id:
            req.trace_id = request_trace_id(req.id)
        root = self._req_spans.get(req.id)
        if root is None:
            root = self.tracer.start(
                "request", kind="serving", trace_id=req.trace_id,
                parent_id=req.parent_span or None,
                worker=self.trace_worker, at=req.arrival_ts,
                n_new=req.n_new, prompt_len=req.prompt_len)
            self._req_spans[req.id] = root
        return root

    def _page_feasible(self) -> int:
        """How many queue-head requests (EDF order) the paged pool could
        commit pages for right now — the admission bound the scheduler
        sees instead of raw free rows."""
        if not self.pools:
            return self.n_slots           # first pool allocates fresh/empty
        avail = max(p.alloc.available()
                    + (p.prefix.reclaimable() if p.prefix is not None else 0)
                    for p in self.pools.values())
        k = 0
        for req in sorted(self.queue,
                          key=lambda r: (r.deadline(), r.arrival_ts)):
            need = -(-req.total_len // self.page_size)
            if need > avail:
                break
            avail -= need
            k += 1
        return k

    def _admit(self, now: float) -> Optional[MicroBatch]:
        free = self._free_slots()
        if self.paged:
            # admit against free *pages*, not free rows: the policy table's
            # plan_batch sees only what the page budget can commit to
            free = min(free, self._page_feasible())
        mb = self.scheduler.next_batch(self.queue, free, idle=self.idle,
                                       now=now)
        if mb is None:
            return None
        pool = self._pool(mb.exec_key)
        free_ids = pool.free_slots()
        tr = self.tracer
        for req, slot in zip(mb.requests, free_ids):
            if self.paged and not pool.can_admit(req):
                # feasibility was estimated across pools / before this
                # micro-batch's own commitments — recheck per request
                self.queue.put(req, force=True)
                self._requeue_ts[req.id] = now
                self.stats["requeued"] += 1
                continue
            root = None
            if tr is not None:
                root = self._request_root(req)
                # end at *this* request's admission start, not the admit
                # pass entry: earlier requests' prefills in the same pass
                # are still queueing time for this one
                tr.record("queue_wait",
                          start=self._requeue_ts.pop(req.id,
                                                     req.arrival_ts),
                          end=tr.clock(), kind="serving",
                          trace_id=req.trace_id,
                          parent_id=root.span_id, worker=self.trace_worker)
            with tr.active(root) if tr is not None else _NULL_CTX:
                act = pool.admit(req, slot, mb.exec_key, mb.extrapolated,
                                 now)
            if tr is not None:
                act.decode_start = tr.clock()
            self.stats["admitted"] += 1
            self.stats["wire_bytes"] += act.wire_bytes
        overflow = mb.requests[len(free_ids):]
        for req in overflow:               # should not happen; be safe
            self.queue.put(req, force=True)
            self._requeue_ts[req.id] = now
        self.stats["max_concurrent"] = max(
            self.stats["max_concurrent"],
            sum(p.n_active for p in self.pools.values()))
        return mb

    # -- hooks ---------------------------------------------------------------

    def heartbeat(self, node: str) -> None:
        if self.fault_hook is not None:
            self.fault_hook.beat(node)

    def _check_faults(self, now: Optional[float] = None) -> None:
        if self.fault_hook is None:
            return
        dead = self.fault_hook.check()
        if not dead:
            return
        now = self.clock() if now is None else now
        requeued = 0
        for pool in self.pools.values():
            for req in pool.drain():       # re-admit from scratch; these
                # were already admitted once — the bound must not drop them
                self.queue.put(req, force=True)
                self._requeue_ts[req.id] = now
                requeued += 1
        self.stats["requeued"] += requeued
        self.fault_hook.record(dead, requeued)
        if self.tracer is not None:
            self.tracer.record("failover", start=now, end=now,
                               kind="serving", trace_id=self._run_trace(),
                               worker=self.trace_worker,
                               dead=",".join(sorted(dead)),
                               requeued=requeued)

    def _observe_stragglers(self, pool: SlotPool, wall_ms: float) -> None:
        if self.straggler_hook is None:
            return
        # chunk walls are telemetry only — genuinely per-device step times
        # must come from the fleet via hook.observe(times, n_tokens=...)
        self.straggler_hook.observe_chunk(wall_ms, self.chunk)
