"""Serving engine: prefill / decode step builders + a batched request loop.

NOTE: ``ServeEngine`` is a deprecation shim — ``repro.api.InferenceSession``
(``session.generate(...)``) is the supported generation surface. The step
builders (``build_prefill_step`` / ``build_decode_step``) remain the
canonical jit targets for the dry-run ``decode_*``/``long_*`` shapes.

``serve_step`` is one-token decode against a sequence-sharded KV cache, with
greedy/temperature sampling; adaptive LOCAL-vs-PRISM routing lives in
``repro.api.InferenceSession.dispatch``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.exchange import ExchangeConfig
from repro.models import registry
from repro.models import transformer as tfm


def build_prefill_step(cfg: ModelConfig, xcfg: ExchangeConfig) -> Callable:
    """Full-sequence forward returning last-position logits + primed cache."""

    def prefill_step(params, batch, cache):
        logits, _ = registry.forward_fn(cfg)(params, batch, xcfg)
        cache = tfm.prefill_memory(params, batch, cfg, xcfg, cache)
        return logits[:, -1:], cache

    return prefill_step


def build_decode_step(cfg: ModelConfig, xcfg: ExchangeConfig) -> Callable:
    """serve_step: one new token given a cache of the current length."""

    def serve_step(params, batch, cache, cache_index):
        logits, cache = tfm.decode_step(params, batch, cache, cache_index,
                                        cfg, xcfg)
        return logits, cache

    return serve_step


# canonical home is repro.api.generation; re-exported for legacy imports
from repro.api.generation import sample_token  # noqa: E402,F401


@dataclasses.dataclass
class ServeEngine:
    """Legacy generation surface, now a thin veneer over the compiled
    fast path (`repro.api.generation`) — the per-token Python loop it used
    to duplicate is gone.

    .. deprecated:: use ``repro.api.InferenceSession.generate`` instead.
    """
    cfg: ModelConfig
    xcfg: ExchangeConfig
    params: Any
    max_len: int = 256
    temperature: float = 0.0

    def __post_init__(self):
        import warnings
        warnings.warn("ServeEngine is deprecated; use "
                      "repro.api.InferenceSession.generate",
                      DeprecationWarning, stacklevel=2)
        self._gen_fns: Dict[Any, Any] = {}

    def generate(self, prompt_tokens: jnp.ndarray, n_new: int,
                 batch_extras: Optional[Dict[str, jnp.ndarray]] = None,
                 seed: int = 0):
        """prompt_tokens: [B, T0] → generated [B, n_new] (greedy/T)."""
        from repro.api import generation as gen
        return gen.generate(self.params, prompt_tokens, n_new, self.cfg,
                            self.xcfg, batch_extras=batch_extras, seed=seed,
                            temperature=self.temperature,
                            _cache=self._gen_fns)
