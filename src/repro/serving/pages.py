"""Paged KV-cache pool: block allocator, prefix cache, paged serving pool.

The dense :class:`~repro.serving.engine.SlotPool` reserves ``max_len``
positions per slot up front, so a pool sized for long requests strands most
of its memory on short ones.  This module replaces that with vLLM-style
paging: one shared device pool of fixed-size KV pages
(``[n_layers, n_pages, page_size, Hk, dh]`` per leaf), per-request page
tables, and a host-side free-list allocator that grows a request's table
page-by-page as decode advances.  Admission is bounded by *free pages*, not
free rows, so concurrency at a fixed memory budget scales with actual
sequence lengths instead of the worst case.

Three mechanisms ride on the page indirection:

* **Prefix caching** — completed prefills are remembered keyed by a running
  hash of the prompt; a new request whose prompt extends a cached prefix
  skips the prefill for the shared pages entirely (full hit: first token is
  sampled from the entry's cached last-position logits; partial hit: only
  the suffix is teacher-forced through the pool).  Sharing is
  copy-on-write at page granularity: an unaligned tail page is copied
  before the new request may write into it, so sharers never collide.
* **Commitment admission** — ``can_admit`` reserves the request's whole
  page need (``ceil(total_len / page_size)``) against the free list at
  admission; on-demand growth then draws on that reservation, so decode
  can never deadlock mid-request on an empty free list.
* **Cold-page quantization** (optional, **lossy**) — prefix entries idle
  for ``cold_horizon`` admissions have their pages encoded through the
  wire codecs (``repro.transport.codecs`` int8/int4), freeing the pages;
  a later hit decodes them back into fresh pages.  Off by default
  (``cold_horizon=None``) because dequantized history is no longer
  bit-exact with a fresh prefill.

The **trash page** convention: the device pool is created with one extra
page (id ``n_pages``) that the allocator never hands out.  Idle rows keep
their page-table row pointed at it, so the fixed-shape decode chunk can
advance every row unconditionally — writes from vacant or finished rows
land in the trash page (or clamp inside the row's own last page via
``caps``) and are never validly read.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import maybe_span
from repro.serving.queue import Request


class PagesExhausted(RuntimeError):
    """Admission was attempted without enough uncommitted free pages."""


# ---------------------------------------------------------------------------
# Page allocator
# ---------------------------------------------------------------------------

class PageAllocator:
    """Free-list page allocator with refcounts and admission commitments.

    Pages are shared (prefix cache + any number of requests), so each holder
    retains a reference; a page returns to the free list only when the last
    holder releases it.  ``commit`` reserves pages for an admitted request
    before they are physically allocated — ``available()`` is what a *new*
    admission may claim, keeping on-demand growth deadlock-free.
    """

    def __init__(self, n_pages: int):
        if n_pages <= 0:
            raise ValueError("n_pages must be >= 1")
        self.n_pages = n_pages
        # LIFO: low page ids hand out first (stable tests, warm reuse)
        self.free: List[int] = list(range(n_pages - 1, -1, -1))
        self.refs: Dict[int, int] = {}
        self.committed = 0

    def available(self) -> int:
        """Free pages not already promised to an admitted request."""
        return len(self.free) - self.committed

    # -- commitments ---------------------------------------------------------

    def commit(self, n: int) -> None:
        if n > self.available():
            raise PagesExhausted(
                f"commit({n}) exceeds available ({self.available()})")
        self.committed += n

    def uncommit(self, n: int) -> None:
        if n > self.committed:
            raise RuntimeError(f"uncommit({n}) exceeds committed "
                               f"({self.committed})")
        self.committed -= n

    # -- pages ---------------------------------------------------------------

    def alloc(self, n: int, committed: bool = True) -> List[int]:
        """Pop ``n`` pages (each at refcount 1).  ``committed=True`` draws
        on a prior :meth:`commit` reservation; ``committed=False`` (cache
        revival) must fit in what admissions have not reserved."""
        if committed:
            if n > self.committed:
                raise RuntimeError(
                    f"alloc({n}) draws past the commitment ({self.committed})")
            self.committed -= n
        elif n > self.available():
            raise PagesExhausted(
                f"alloc({n}, committed=False) exceeds available "
                f"({self.available()})")
        ids = [self.free.pop() for _ in range(n)]
        for pid in ids:
            self.refs[pid] = 1
        return ids

    def retain(self, pid: int) -> None:
        self.refs[pid] += 1

    def release(self, pid: int) -> int:
        """Drop one reference; returns 1 if the page went back to the free
        list, 0 if other holders remain.  Double-free raises."""
        if pid not in self.refs:
            raise KeyError(f"release of unallocated page {pid}")
        self.refs[pid] -= 1
        if self.refs[pid] == 0:
            del self.refs[pid]
            self.free.append(pid)
            return 1
        return 0

    def check(self) -> None:
        """Invariants (property tests): full partition, no overlap, and
        commitments covered by the free list."""
        if len(self.free) + len(self.refs) != self.n_pages:
            raise AssertionError("page leak: free + live != total")
        if set(self.free) & set(self.refs):
            raise AssertionError("page on free list while referenced")
        if not 0 <= self.committed <= len(self.free):
            raise AssertionError("commitments exceed the free list")


# ---------------------------------------------------------------------------
# Prefix cache
# ---------------------------------------------------------------------------

def _prefix_digests(prompt: np.ndarray) -> List[bytes]:
    """Running blake2b over the prompt: ``out[i]`` keys tokens ``[: i+1]``.
    One pass (``hashlib`` digests do not finalize), so probing every prefix
    length is O(T0) total."""
    h = hashlib.blake2b(digest_size=16)
    out: List[bytes] = []
    for t in prompt:
        h.update(int(t).to_bytes(4, "little", signed=True))
        out.append(h.digest())
    return out


@dataclasses.dataclass
class PrefixEntry:
    """One cached prompt prefill: its prompt pages + last-position logits.

    ``tail`` (when the prompt is not page-aligned) holds only
    ``tail_valid`` valid positions — readers must COW-copy it before
    writing at their own frontier.  Cold entries hold codec payloads
    instead of pages (``full_pages`` empty, ``tail`` None)."""
    digest: bytes
    n_tok: int
    full_pages: List[int]
    tail: Optional[int]
    tail_valid: int
    logits: Any                        # [1, 1, V] device, prefill last pos
    last_used: int = 0
    hits: int = 0
    cold: bool = False
    payloads: Optional[List[Dict[str, Any]]] = None
    n_full: int = 0                    # layout memo for cold revival
    had_tail: bool = False

    def pages(self) -> List[int]:
        return self.full_pages + ([self.tail] if self.tail is not None
                                  else [])


class PrefixCache:
    """Host-side index of :class:`PrefixEntry` keyed by prompt digest.

    ``lookup`` probes every prefix length of the incoming prompt, longest
    first.  Entries are evicted LRU (``last_used`` is an admission counter,
    not wall time) when admissions need their pages or the entry bound is
    hit; evicting only releases the *cache's* references, so pages shared
    with in-flight requests stay alive until those requests finish.
    """

    def __init__(self, alloc: PageAllocator, max_entries: int = 128):
        self.alloc = alloc
        self.max_entries = max_entries
        self.entries: Dict[bytes, PrefixEntry] = {}
        self.clock = 0                 # admission counter (LRU + cold age)
        self.evictions = 0

    def lookup(self, prompt: np.ndarray) -> Optional[PrefixEntry]:
        ds = _prefix_digests(prompt)
        for i in range(len(ds) - 1, -1, -1):
            e = self.entries.get(ds[i])
            if e is not None:
                return e
        return None

    def insert(self, prompt: np.ndarray, pages: List[int], logits,
               page_size: int) -> Optional[PrefixEntry]:
        """Remember a freshly prefilled prompt.  ``pages`` is the owning
        row's page list; the cache retains its own reference on each prompt
        page so they outlive the request."""
        digest = _prefix_digests(prompt)[-1]
        existing = self.entries.get(digest)
        if existing is not None:
            existing.last_used = self.clock
            return existing
        n_tok = int(len(prompt))
        n_full = n_tok // page_size
        tail = pages[n_full] if n_tok % page_size else None
        full = list(pages[:n_full])
        for pid in full + ([tail] if tail is not None else []):
            self.alloc.retain(pid)
        e = PrefixEntry(digest=digest, n_tok=n_tok, full_pages=full,
                        tail=tail, tail_valid=n_tok % page_size,
                        logits=logits, last_used=self.clock)
        while len(self.entries) >= self.max_entries:
            lru = min(self.entries, key=lambda d: self.entries[d].last_used)
            self.evict_entry(lru)
        self.entries[digest] = e
        return e

    def evict_entry(self, digest: bytes) -> int:
        """Drop one entry; returns how many pages went back to the free
        list (0 for cold entries or pages still shared with requests)."""
        e = self.entries.pop(digest)
        freed = 0
        if not e.cold:
            for pid in e.pages():
                freed += self.alloc.release(pid)
        self.evictions += 1
        return freed

    def make_room(self, n_short: int) -> int:
        """Evict LRU entries until ~``n_short`` pages came free (or no hot
        entry remains)."""
        gained = 0
        while gained < n_short:
            hot = [d for d, e in self.entries.items() if not e.cold]
            if not hot:
                break
            lru = min(hot, key=lambda d: self.entries[d].last_used)
            gained += self.evict_entry(lru)
        return gained

    def reclaimable(self) -> int:
        """Pages that evicting every idle entry would free right now
        (refcount 1 = held only by the cache)."""
        return sum(1 for e in self.entries.values() if not e.cold
                   for pid in e.pages() if self.alloc.refs.get(pid) == 1)


# ---------------------------------------------------------------------------
# Device helpers (jitted once; page ids are traced scalars)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_page(pool, src, dst):
    """Copy-on-write split: duplicate physical page ``src`` into ``dst``
    across every pool leaf.  The pool is donated (callers rebind the
    result), so only the touched page is written, not the whole pool."""
    return jax.tree_util.tree_map(lambda p: p.at[:, dst].set(p[:, src]),
                                  pool)


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_pages(pool, idx, values):
    """Scatter revived page contents into the pool.  Donated like
    :func:`_copy_page`, so only the ``idx`` pages are written in place
    instead of materializing a full pool-sized copy per leaf."""
    return jax.tree_util.tree_map(
        lambda leaf, v: leaf.at[:, idx].set(v.astype(leaf.dtype)),
        pool, values)


@jax.jit
def _set_row(tok, lengths, keys, temps, slot, tok0, length, key, temp):
    """Write one slot's decode-state row.  The slot index is traced — eager
    ``.at[int].set`` would bake it in and recompile per slot."""
    return (tok.at[slot].set(tok0), lengths.at[slot].set(length),
            keys.at[slot].set(key), temps.at[slot].set(temp))


# ---------------------------------------------------------------------------
# Paged pool
# ---------------------------------------------------------------------------

class PagedPool:
    """Paged drop-in for :class:`~repro.serving.engine.SlotPool`.

    Same host interface (``admit`` / ``evict`` / ``drain`` /
    ``decode_chunk`` / ``free_slots`` / ``n_active``) so
    :class:`~repro.serving.engine.ServingRuntime` drives either, plus
    ``can_admit`` (page-commitment check) and ``page_stats``.  Decode is
    ONE jitted executable per (plan, rows, max_pages, chunk): page tables,
    caps, and lengths are traced inputs, so admissions and page growth
    never recompile.
    """

    def __init__(self, session, plan, n_rows: int, *, n_pages: int,
                 page_size: int, max_pages: int, prefix_cache: bool = True,
                 cold_horizon: Optional[int] = None,
                 cold_codec: str = "int8", max_entries: int = 128):
        if n_pages < max_pages:
            raise ValueError(
                f"n_pages ({n_pages}) < max_pages ({max_pages}): a "
                "max-length request could never be admitted")
        from repro.serving.engine import _placeholder_keys
        self.session = session
        self.plan = plan
        self.n_rows = n_rows
        self.n_pages = n_pages
        self.page_size = page_size
        self.max_pages = max_pages
        self.cold_horizon = cold_horizon
        self.cold_codec = cold_codec
        # +1 page: the trash page (id == n_pages) absorbing idle-row writes
        self.pool = session.init_page_pool(n_pages + 1, page_size)
        self.trash = n_pages
        self.alloc = PageAllocator(n_pages)
        self.prefix = (PrefixCache(self.alloc, max_entries=max_entries)
                       if prefix_cache else None)
        self.page_table = np.full((n_rows, max_pages), self.trash, np.int32)
        self.row_pages: List[List[int]] = [[] for _ in range(n_rows)]
        self.row_committed = [0] * n_rows
        self.row_len = [0] * n_rows
        self.tok = jnp.zeros((n_rows,), jnp.int32)
        self.lengths = jnp.zeros((n_rows,), jnp.int32)
        self.keys = _placeholder_keys(n_rows)
        self.temps = jnp.zeros((n_rows,), jnp.float32)
        self.slots: List[Optional[Any]] = [None] * n_rows
        self.tracer = None                 # set by ServingRuntime._pool
        self.trace_worker = ""
        self.stats = {"prefix_hits": 0, "prefix_misses": 0, "full_hits": 0,
                      "partial_hits": 0, "cow_splits": 0, "cold_pages": 0,
                      "dequant_pages": 0, "admit_ms": 0.0}
        # benchmarks flip this on to charge prefill to admission wall time
        self.time_admits = False
        # pages the in-flight admit alloc'd/retained (rollback journal)
        self._acquired: List[int] = []

    # -- occupancy -----------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def _need(self, req: Request) -> int:
        return -(-req.total_len // self.page_size)

    def can_admit(self, req: Request) -> bool:
        """Whole-request page commitment against the free list (counting
        pages LRU prefix eviction could reclaim).  Conservative: prefix
        sharing would lower the true need, but a hit is only known at
        admission."""
        avail = self.alloc.available()
        if self.prefix is not None:
            avail += self.prefix.reclaimable()
        return self._need(req) <= avail

    # -- admission -----------------------------------------------------------

    def _reserve(self, n: int) -> bool:
        if self.alloc.available() < n and self.prefix is not None:
            self.prefix.make_room(n - self.alloc.available())
        if self.alloc.available() < n:
            return False
        self.alloc.commit(n)
        return True

    def admit(self, req: Request, slot: int, exec_key: str,
              extrapolated: bool, now: float):
        """Admit one request into ``slot``: prefix-cache probe, then the
        full-hit / partial-hit / miss path.  Commits the request's whole
        page need first, so later on-demand growth cannot starve."""
        from repro.serving.engine import _Active
        from repro.transport import plan_wire_bytes
        if self.slots[slot] is not None:
            raise RuntimeError(f"row {slot} is occupied")
        ps = self.page_size
        if req.total_len > self.max_pages * ps:
            raise ValueError(
                f"request needs {req.total_len} positions but the page "
                f"table is sized for {self.max_pages * ps}; raise "
                "ServingRuntime(max_len=)")
        t0 = time.perf_counter()
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        T0 = int(prompt.shape[0])
        P0 = -(-T0 // ps)
        total = self._need(req)

        entry = None
        reserved = 0
        if self.prefix is not None:
            self.prefix.clock += 1
            entry = self.prefix.lookup(prompt)
            if entry is not None and entry.cold:
                entry = self._revive(entry)
            if entry is not None:
                # Shield the entry from the LRU sweep _reserve may run:
                # its last_used is otherwise bumped only by the hit
                # handlers, so make_room could evict it out from under us
                # and free the very pages the hit is about to retain.
                entry.last_used = self.prefix.clock
                reserved = total - len(entry.full_pages)
                if not self._reserve(reserved):
                    entry, reserved = None, 0  # pressure: fall back to miss
                elif self.prefix.entries.get(entry.digest) is not entry:
                    # make_room evicted it anyway (it was the only hot
                    # entry); its cache-only pages are free again and the
                    # hit is void — return the reservation, run as a miss
                    self.alloc.uncommit(reserved)
                    entry, reserved = None, 0

        if entry is None:
            reserved = total
            if not self._reserve(total):
                raise PagesExhausted(
                    f"admission needs {total} pages; "
                    f"{self.alloc.available()} available")

        committed0 = self.alloc.committed
        self._acquired = []
        try:
            if entry is None:
                pages, first_tok, prompt_wire = self._admit_miss(
                    prompt, P0, slot, req)
            elif entry.n_tok == T0:
                pages, first_tok = self._admit_full_hit(entry, slot, req, T0)
                prompt_wire = 0        # no prefill ran, nothing crossed wire
            else:
                pages, first_tok = self._admit_partial_hit(
                    entry, prompt, P0, slot, req)
                prompt_wire = T0 - entry.n_tok

            self.page_table[slot, :len(pages)] = pages
            self.row_pages[slot] = pages
            self.row_committed[slot] = total - P0
            self.row_len[slot] = T0
            wire = plan_wire_bytes(self.plan, self.session.cfg, 1,
                                   prompt_wire) if prompt_wire else 0
            act = _Active(request=req, admitted_ts=now, exec_key=exec_key,
                          extrapolated=extrapolated, first_tok=first_tok,
                          codec=(self.plan.effective_codec if wire else ""),
                          wire_bytes=wire)
            self.slots[slot] = act
            if self.time_admits:
                jax.block_until_ready(self.tok)
                self.stats["admit_ms"] += 1e3 * (time.perf_counter() - t0)
        except BaseException:
            self._rollback_admit(slot, reserved, committed0)
            raise
        finally:
            self._acquired = []
        if self.prefix is not None and self.cold_horizon is not None:
            self._sweep_cold()
        return act

    def _rollback_admit(self, slot: int, reserved: int,
                        committed0: int) -> None:
        """Undo a failed admission: release every page it alloc'd or
        retained, return the unspent part of its reservation, and clear
        the row, so one bad admit cannot shrink the pool for everyone
        after it.  References the prefix cache took for itself (via
        ``insert``) are the cache's own and stay."""
        drawn = committed0 - self.alloc.committed
        for pid in self._acquired:
            self.alloc.release(pid)
        self.alloc.uncommit(reserved - drawn)
        self.slots[slot] = None
        self.row_pages[slot] = []
        self.row_committed[slot] = 0
        self.row_len[slot] = 0
        self.page_table[slot, :] = self.trash

    def _admit_miss(self, prompt, P0: int, slot: int, req: Request):
        """Prefill at page-aligned length, scatter into fresh pages, and
        remember the prompt in the prefix cache."""
        ps = self.page_size
        ids = self.alloc.alloc(P0)
        self._acquired.extend(ids)
        with maybe_span(self.tracer, "prefill", kind="serving",
                        worker=self.trace_worker,
                        prompt_len=int(prompt.shape[0]), hit="miss"):
            tok0, cache, key, logits = self.session.prime_slot(
                jnp.asarray(prompt[None]), total_len=P0 * ps,
                plan=self.plan, seed=req.seed,
                temperature=req.temperature, with_logits=True)
        with maybe_span(self.tracer, "admit", kind="serving",
                        worker=self.trace_worker, slot=slot, pages=P0):
            (self.pool, self.tok, self.lengths, self.keys, self.temps) = \
                self.session.admit_paged(self.pool, self.tok, self.lengths,
                                         self.keys, self.temps, cache,
                                         jnp.asarray(ids, jnp.int32), slot,
                                         tok0, len(prompt), key,
                                         req.temperature)
        if self.prefix is not None:
            self.stats["prefix_misses"] += 1
            self.prefix.insert(prompt, ids, logits, ps)
        return list(ids), tok0, len(prompt)

    def _cow_tail(self, entry: PrefixEntry) -> int:
        """COW split of an unaligned shared tail page: the admitting
        request writes at its frontier inside this page, so it gets a
        private copy (sharers keep reading the original)."""
        dst = self.alloc.alloc(1)[0]
        self._acquired.append(dst)
        with maybe_span(self.tracer, "cow_split", kind="serving",
                        worker=self.trace_worker, src=int(entry.tail),
                        dst=int(dst)):
            self.pool = _copy_page(self.pool, entry.tail, dst)
        self.stats["cow_splits"] += 1
        return dst

    def _admit_full_hit(self, entry: PrefixEntry, slot: int, req: Request,
                        T0: int):
        """Exact-prompt hit: zero prefill.  First token is sampled from the
        entry's cached logits with this request's own key — the same
        split/argmax/categorical tail a miss applies, so the token chain is
        identical."""
        pages = []
        for pid in entry.full_pages:
            self.alloc.retain(pid)
            self._acquired.append(pid)
            pages.append(pid)
        if entry.tail is not None:
            pages.append(self._cow_tail(entry))
        with maybe_span(self.tracer, "admit", kind="serving",
                        worker=self.trace_worker, slot=slot, hit="full"):
            (self.tok, self.lengths, self.keys, self.temps) = \
                self.session.hit_paged(self.tok, self.lengths, self.keys,
                                       self.temps, slot, entry.logits, T0,
                                       jax.random.key(req.seed),
                                       req.temperature)
        entry.hits += 1
        entry.last_used = self.prefix.clock
        self.stats["prefix_hits"] += 1
        self.stats["full_hits"] += 1
        return pages, self.tok[slot][None, None]

    def _admit_partial_hit(self, entry: PrefixEntry, prompt, P0: int,
                           slot: int, req: Request):
        """Prompt extends a cached prefix: retain the shared full pages,
        COW-copy the unaligned tail, then teacher-force only the suffix
        through the pool (scanned prefill ≡ single-pass for these
        families)."""
        n = entry.n_tok
        pages = []
        for pid in entry.full_pages:
            self.alloc.retain(pid)
            self._acquired.append(pid)
            pages.append(pid)
        if entry.tail is not None:
            pages.append(self._cow_tail(entry))
        grown = self.alloc.alloc(P0 - len(pages))
        self._acquired.extend(grown)
        pages.extend(grown)
        self.page_table[slot, :P0] = pages
        with maybe_span(self.tracer, "prefill", kind="serving",
                        worker=self.trace_worker, hit="partial",
                        cached=int(n),
                        prompt_len=int(prompt.shape[0])):
            tok0, self.pool, key, logits = self.session.suffix_paged(
                self.pool, jnp.asarray(self.page_table[slot:slot + 1]),
                jnp.asarray(prompt[None, n:]), jnp.asarray([n], jnp.int32),
                jax.random.key(req.seed), req.temperature, plan=self.plan)
        (self.tok, self.lengths, self.keys, self.temps) = _set_row(
            self.tok, self.lengths, self.keys, self.temps, slot, tok0[0, 0],
            len(prompt), key, float(req.temperature))
        entry.hits += 1
        entry.last_used = self.prefix.clock
        self.stats["prefix_hits"] += 1
        self.stats["partial_hits"] += 1
        if self.prefix is not None:
            self.prefix.insert(prompt, pages, logits, self.page_size)
        return pages, tok0

    # -- eviction ------------------------------------------------------------

    def evict(self, slot: int):
        act, self.slots[slot] = self.slots[slot], None
        for pid in self.row_pages[slot]:
            self.alloc.release(pid)
        self.alloc.uncommit(self.row_committed[slot])
        self.row_pages[slot] = []
        self.row_committed[slot] = 0
        self.row_len[slot] = 0
        self.page_table[slot, :] = self.trash
        return act

    def drain(self) -> List[Request]:
        """Drop every in-flight request (fault re-admission path)."""
        reqs = []
        for i, act in enumerate(self.slots):
            if act is not None:
                reqs.append(self.evict(i).request)
        return reqs

    # -- decode --------------------------------------------------------------

    def _ensure(self, row: int, n_steps: int) -> None:
        """Grow the row's page table to cover the whole next chunk, drawing
        on the commitment made at admission (never past the request's total
        need — once the request is done, extra steps clamp at ``caps``)."""
        ps = self.page_size
        act = self.slots[row]
        total = self._need(act.request)
        need = min(-(-(self.row_len[row] + n_steps) // ps), total)
        extra = need - len(self.row_pages[row])
        if extra > 0:
            ids = self.alloc.alloc(extra)
            start = len(self.row_pages[row])
            self.page_table[row, start:start + extra] = ids
            self.row_pages[row].extend(ids)
            self.row_committed[row] -= extra

    def decode_chunk(self, n_steps: int) -> float:
        """One chunk over all rows; appends tokens to active requests and
        returns the wall ms the chunk took (straggler signal)."""
        t0 = time.perf_counter()
        for row, act in enumerate(self.slots):
            if act is not None:
                self._ensure(row, n_steps)
        ps = self.page_size
        caps = np.asarray([max(len(p), 1) * ps - 1 for p in self.row_pages],
                          np.int32)
        toks, self.pool, self.lengths, self.keys = \
            self.session.paged_decode_chunk(
                self.pool, jnp.asarray(self.page_table), jnp.asarray(caps),
                self.tok, self.lengths, self.keys, self.temps,
                n_steps=n_steps, plan=self.plan)
        self.tok = toks[:, -1]
        out = np.asarray(toks)
        wall_ms = 1e3 * (time.perf_counter() - t0)
        for i, act in enumerate(self.slots):
            if act is None:
                continue
            self.row_len[i] += n_steps
            if act.done:
                continue
            need = act.request.n_new - act.emitted
            act.tokens.extend(int(t) for t in out[i, :need])
        return wall_ms

    # -- cold pages (lossy; off unless cold_horizon is set) ------------------

    def _codec(self):
        from repro.transport.codecs import CodecSpec, get_codec
        return get_codec(self.cold_codec), CodecSpec(param=0)

    def _sweep_cold(self) -> None:
        """Quantize pages of prefix entries idle past ``cold_horizon``
        admissions and return them to the free list.  The entry's valid
        region is stable (rows never write below their own frontier), so
        the snapshot is consistent even while sharers decode."""
        for e in list(self.prefix.entries.values()):
            if e.cold or self.prefix.clock - e.last_used < self.cold_horizon:
                continue
            codec, spec = self._codec()
            idx = jnp.asarray(e.pages(), jnp.int32)
            with maybe_span(self.tracer, "codec_encode", kind="serving",
                            worker=self.trace_worker,
                            codec=self.cold_codec,
                            pages=int(idx.shape[0]), cold=True):
                leaves, _ = jax.tree_util.tree_flatten(self.pool)
                e.payloads = [codec.encode(leaf[:, idx].astype(jnp.float32),
                                           spec) for leaf in leaves]
            e.n_full = len(e.full_pages)
            e.had_tail = e.tail is not None
            for pid in e.pages():
                self.alloc.release(pid)
            e.full_pages, e.tail, e.cold = [], None, True
            self.stats["cold_pages"] += int(idx.shape[0])

    def _revive(self, e: PrefixEntry) -> Optional[PrefixEntry]:
        """Dequantize a cold entry back into fresh (uncommitted) pages;
        under pressure the entry is dropped instead and the admission runs
        as a miss."""
        n = e.n_full + (1 if e.had_tail else 0)
        if self.alloc.available() < n:
            self.prefix.make_room(n - self.alloc.available())
        if self.alloc.available() < n:
            self.prefix.evict_entry(e.digest)
            return None
        codec, spec = self._codec()
        ids = self.alloc.alloc(n, committed=False)
        idx = jnp.asarray(ids, jnp.int32)
        with maybe_span(self.tracer, "codec_decode", kind="serving",
                        worker=self.trace_worker, codec=self.cold_codec,
                        pages=int(n), cold=True):
            leaves, treedef = jax.tree_util.tree_flatten(self.pool)
            values = jax.tree_util.tree_unflatten(treedef, [
                codec.decode(p, spec, dtype=leaf.dtype)
                for leaf, p in zip(leaves, e.payloads)])
            self.pool = _write_pages(self.pool, idx, values)
        e.full_pages = list(ids[:e.n_full])
        e.tail = ids[e.n_full] if e.had_tail else None
        e.cold, e.payloads = False, None
        self.stats["dequant_pages"] += n
        return e

    # -- telemetry -----------------------------------------------------------

    def page_stats(self) -> Dict[str, Any]:
        free = len(self.alloc.free)
        out = {"pages_total": self.n_pages, "pages_free": free,
               "pages_committed": self.alloc.committed,
               "page_occupancy": 1.0 - free / self.n_pages}
        out.update(self.stats)
        if self.prefix is not None:
            out["prefix_entries"] = len(self.prefix.entries)
            out["prefix_evictions"] = self.prefix.evictions
            looked = self.stats["prefix_hits"] + self.stats["prefix_misses"]
            out["prefix_hit_rate"] = (self.stats["prefix_hits"] / looked
                                      if looked else 0.0)
        return out
