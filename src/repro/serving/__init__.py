"""`repro.serving` — the policy-driven serving runtime.

Request traffic goes queue → scheduler → runtime:

* :class:`Request` / :class:`RequestQueue` — bounded intake with arrival
  timestamps and per-request SLO deadlines.
* :class:`AdaptiveScheduler` — micro-batch formation from the compiled
  policy table (batch size AND mode/CR/codec chosen per the active
  objective).
* :class:`ServingRuntime` — continuous-batching decode on a slot-based
  KV-cache pool (admit between chunks, evict finished, one executable per
  (plan, slot-count)), with fault/straggler hooks; completions carry the
  serving plan's exchange codec and modeled bytes-on-wire.
* :class:`PagedPool` / :class:`PageAllocator` / :class:`PrefixCache` —
  paged mode (``ServingRuntime(page_size=..., n_pages=...)``): a shared
  block pool of fixed-size KV pages with commitment-based admission,
  copy-on-write prefix sharing, and optional cold-page quantization.

The deprecated ``AdaptiveDispatcher``/``ServeEngine`` shims have been
**removed** — use ``repro.api.InferenceSession`` (single batches /
generation) or :class:`ServingRuntime` (request traffic).  The step
builders stay canonical for dry-run shape analysis.
"""
from repro.serving.engine import (Completion, ServingRuntime, SlotPool,
                                  build_decode_step, build_prefill_step)
from repro.serving.pages import (PageAllocator, PagedPool, PagesExhausted,
                                 PrefixCache, PrefixEntry)
from repro.serving.queue import QueueFull, Request, RequestQueue
from repro.serving.scheduler import (AdaptiveScheduler, FailoverEvent,
                                     FaultHook, MicroBatch, RebalanceEvent,
                                     StragglerHook)

__all__ = ["Request", "RequestQueue", "QueueFull",
           "AdaptiveScheduler", "MicroBatch",
           "ServingRuntime", "SlotPool", "Completion",
           "PagedPool", "PageAllocator", "PrefixCache", "PrefixEntry",
           "PagesExhausted",
           "FaultHook", "StragglerHook", "FailoverEvent", "RebalanceEvent",
           "build_prefill_step", "build_decode_step"]
