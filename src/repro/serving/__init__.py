"""Legacy serving layer — superseded by :mod:`repro.api`.

``AdaptiveDispatcher`` and ``ServeEngine`` are deprecation shims;
``repro.api.InferenceSession`` is the supported runtime surface. The step
builders stay canonical for dry-run shape analysis.
"""
from repro.serving.dispatcher import AdaptiveDispatcher, DispatchRecord
from repro.serving.engine import (ServeEngine, build_decode_step,
                                  build_prefill_step)

__all__ = ["ServeEngine", "build_prefill_step", "build_decode_step",
           "AdaptiveDispatcher", "DispatchRecord"]
