from repro.serving.engine import ServeEngine, build_prefill_step, build_decode_step
from repro.serving.dispatcher import AdaptiveDispatcher

__all__ = ["ServeEngine", "build_prefill_step", "build_decode_step",
           "AdaptiveDispatcher"]
