"""DEPRECATED adaptive dispatcher — superseded by ``repro.api``.

``repro.api.InferenceSession`` now owns the runtime loop (per-plan
executables + bandwidth observation + policy dispatch); this class is kept
as a thin compatibility shim for code that hand-wires ``{"mode@cr": fn}``
executable tables. New code should do::

    from repro.api import ExecutionPlan, InferenceSession
    session = InferenceSession.from_config(arch, plans=[...])
    session.dispatch(batch_inputs)
"""
from __future__ import annotations

import time
import warnings
from typing import Any, Callable, Dict

from repro.api.session import DispatchRecord          # canonical home
from repro.core.perfmap import PerfMap
from repro.core.policy import AdaptivePolicy, Decision, Objective

__all__ = ["AdaptiveDispatcher", "DispatchRecord"]


class AdaptiveDispatcher:
    """Routes batches to per-mode executables per the profiled policy.

    .. deprecated:: use :class:`repro.api.InferenceSession` instead.
    """

    def __init__(self, perfmap: PerfMap,
                 executables: Dict[str, Callable],
                 objective: Objective = "latency",
                 bandwidth_alpha: float = 0.3):
        """``executables``: {"local": fn, "prism@9.9": fn, ...} — each fn
        takes the request batch pytree and returns outputs."""
        warnings.warn("AdaptiveDispatcher is deprecated and will be removed "
                      "in the next release; use repro.api.InferenceSession",
                      DeprecationWarning, stacklevel=2)
        from repro.utils.bandwidth import BandwidthEstimator
        self.policy = AdaptivePolicy(perfmap)
        self.execs = executables
        self.objective: Objective = objective
        self._bwest = BandwidthEstimator(400.0, bandwidth_alpha)
        self.history: list[DispatchRecord] = []

    def observe_bandwidth(self, mbps: float) -> None:
        self._bwest.observe(mbps)

    @property
    def bandwidth(self) -> float:
        return self._bwest.mbps

    @property
    def _bw(self) -> float:
        return self._bwest.mbps

    def _key(self, d: Decision) -> str:
        return d.exec_key

    def dispatch(self, batch_inputs: Any, batch_size: int) -> Any:
        d = self.policy.decide(batch_size, self._bw, self.objective)
        key = self._key(d)
        substituted = False
        if key not in self.execs:
            # fall back to any same-mode executable, then to any executable
            # at all — never KeyError just because "local" is unregistered
            # (exact mode match, same semantics as InferenceSession)
            key = next((k for k in self.execs
                        if k.split("@")[0] == d.mode), None)
            if key is None:
                if not self.execs:
                    raise LookupError("no executables registered")
                key = next(iter(self.execs))
            substituted = True
        t0 = time.perf_counter()
        out = self.execs[key](batch_inputs)
        wall = (time.perf_counter() - t0) * 1e3
        self.history.append(DispatchRecord(batch_size, self._bw, d, wall,
                                           exec_key=key,
                                           substituted=substituted,
                                           extrapolated=d.extrapolated))
        return out
