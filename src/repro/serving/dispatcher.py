"""Adaptive dispatcher: the paper's runtime loop around the policy.

Holds one jitted executable per execution mode (local / prism@CR) and routes
each arriving request batch to the one the profiled map predicts fastest
(or most energy-efficient) under current network conditions. Bandwidth is
observed via an EWMA probe the caller updates (`observe_bandwidth`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

from repro.core.perfmap import PerfMap
from repro.core.policy import AdaptivePolicy, Decision, Objective


@dataclasses.dataclass
class DispatchRecord:
    batch: int
    bandwidth_mbps: float
    decision: Decision
    wall_ms: float


class AdaptiveDispatcher:
    """Routes batches to per-mode executables per the profiled policy."""

    def __init__(self, perfmap: PerfMap,
                 executables: Dict[str, Callable],
                 objective: Objective = "latency",
                 bandwidth_alpha: float = 0.3):
        """``executables``: {"local": fn, "prism@9.9": fn, ...} — each fn
        takes the request batch pytree and returns outputs."""
        self.policy = AdaptivePolicy(perfmap)
        self.execs = executables
        self.objective: Objective = objective
        self._bw = 400.0
        self._alpha = bandwidth_alpha
        self.history: list[DispatchRecord] = []

    def observe_bandwidth(self, mbps: float) -> None:
        self._bw = self._alpha * mbps + (1 - self._alpha) * self._bw

    @property
    def bandwidth(self) -> float:
        return self._bw

    def _key(self, d: Decision) -> str:
        return "local" if d.mode == "local" else f"{d.mode}@{d.cr:g}"

    def dispatch(self, batch_inputs: Any, batch_size: int) -> Any:
        d = self.policy.decide(batch_size, self._bw, self.objective)
        key = self._key(d)
        if key not in self.execs:           # fall back to any same-mode exec
            key = next((k for k in self.execs if k.startswith(d.mode)),
                       "local")
        t0 = time.perf_counter()
        out = self.execs[key](batch_inputs)
        wall = (time.perf_counter() - t0) * 1e3
        self.history.append(DispatchRecord(batch_size, self._bw, d, wall))
        return out
