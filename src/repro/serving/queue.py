"""Request queue for the serving runtime.

A :class:`Request` is one generation job (prompt → ``n_new`` tokens) with an
arrival timestamp and an optional per-request SLO deadline; the bounded
:class:`RequestQueue` holds admitted-but-unscheduled requests and hands the
scheduler deadline-ordered candidates.  PRISM-style systems treat
distributed edge inference as a *request-serving* problem (arXiv
2507.12145) — this module is the front door of that framing.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

_ids = itertools.count()


class QueueFull(RuntimeError):
    """The bounded request queue rejected an arrival (backpressure).

    ``reason`` tells telemetry *why* the put was shed: ``"full"`` (the
    bound) or ``"dead_worker"`` (the fleet router refused a worker that
    missed its heartbeat)."""

    def __init__(self, msg: str, reason: str = "full"):
        super().__init__(msg)
        self.reason = reason


@dataclasses.dataclass
class Request:
    """One generation job.

    ``prompt`` is a 1-D token id array (length T0); ``slo_ms`` is the
    per-request latency objective measured from ``arrival_ts`` (None = best
    effort).  ``seed``/``temperature`` pin the sampling chain so a served
    request is token-exact with ``session.generate(prompt[None], n_new,
    seed=seed)``.
    """
    prompt: np.ndarray
    n_new: int
    slo_ms: Optional[float] = None
    seed: int = 0
    temperature: float = 0.0
    arrival_ts: float = dataclasses.field(default_factory=time.monotonic)
    id: int = dataclasses.field(default_factory=lambda: next(_ids))
    # trace context: set by whichever tier first sees the request (router
    # or runtime) and carried across the RPC wire so the subprocess
    # worker's spans land in the same tree
    trace_id: str = ""
    parent_span: str = ""

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt)
        if self.prompt.ndim == 2 and self.prompt.shape[0] == 1:
            self.prompt = self.prompt[0]
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError("prompt must be a non-empty 1-D token array, "
                             f"got shape {self.prompt.shape}")
        if self.n_new <= 0:
            raise ValueError(f"n_new must be >= 1, got {self.n_new}")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.n_new

    def deadline(self) -> float:
        """Absolute deadline (monotonic clock); +inf when best-effort."""
        if self.slo_ms is None:
            return float("inf")
        return self.arrival_ts + self.slo_ms / 1e3


class RequestQueue:
    """Bounded FIFO with earliest-deadline-first scheduling order.

    ``put`` raises :class:`QueueFull` beyond ``max_size`` — serving systems
    need explicit backpressure, not an unbounded buffer.  ``pop`` hands out
    the earliest-deadline request (arrival order among equals), which is
    what the scheduler admits into free slots.

    ``shed_expired=True`` makes ``pop``/``pop_many`` drop requests whose
    SLO deadline has already passed (counted under the ``"expired"``
    rejection reason, kept in ``self.expired``) instead of dispatching
    work that can no longer meet its deadline — opt-in, because a
    best-effort deployment may prefer late answers over none.
    """

    def __init__(self, max_size: int = 1024, shed_expired: bool = False):
        if max_size <= 0:
            raise ValueError("max_size must be >= 1")
        self.max_size = max_size
        self.shed_expired = shed_expired
        self._q: Deque[Request] = deque()
        self.expired: List[Request] = []
        # shed accounting: every refused put, by reason — the router's shed
        # rate must be visible in telemetry, not a silent exception
        self.rejections: Dict[str, int] = {}

    @property
    def rejected(self) -> int:
        """Total puts this queue refused (all reasons)."""
        return sum(self.rejections.values())

    def reject(self, reason: str) -> None:
        """Record an externally-decided rejection (e.g. the fleet router
        refusing a dead worker before ever calling ``put``)."""
        self.rejections[reason] = self.rejections.get(reason, 0) + 1

    def put(self, req: Request, force: bool = False) -> Request:
        """``force=True`` bypasses the bound — reserved for the runtime
        re-queuing work it already admitted (failover, overflow); dropping
        an in-flight request to enforce backpressure would lose it."""
        if not force and len(self._q) >= self.max_size:
            self.reject("full")
            raise QueueFull(f"queue at capacity ({self.max_size})")
        self._q.append(req)
        return req

    def drain(self) -> List[Request]:
        """Remove and return every queued request (dead-worker path: the
        fleet router re-routes them; EDF order is recovered by the target
        queue's ``pop``, which orders by deadline, not insertion)."""
        out = list(self._q)
        self._q.clear()
        return out

    def shed_expired_now(self, now: Optional[float] = None) -> List[Request]:
        """Drop every queued request whose deadline has passed (counted
        under the ``"expired"`` reason); returns what was shed."""
        now = time.monotonic() if now is None else now
        shed = [r for r in self._q if r.deadline() < now]
        if shed:
            self._q = deque(r for r in self._q if r.deadline() >= now)
            self.expired.extend(shed)
            for _ in shed:
                self.reject("expired")
        return shed

    def pop(self, now: Optional[float] = None) -> Request:
        """Earliest deadline first; FIFO among equal deadlines.  With
        ``shed_expired``, deadline-passed requests are dropped first."""
        if self.shed_expired:
            self.shed_expired_now(now)
        if not self._q:
            raise IndexError("pop from empty RequestQueue")
        best_i = min(range(len(self._q)),
                     key=lambda i: (self._q[i].deadline(),
                                    self._q[i].arrival_ts))
        self._q.rotate(-best_i)
        req = self._q.popleft()
        self._q.rotate(best_i)
        return req

    def pop_many(self, n: int, now: Optional[float] = None) -> List[Request]:
        out: List[Request] = []
        while self._q and len(out) < n:
            try:
                out.append(self.pop(now))
            except IndexError:      # every remaining request expired
                break
        return out

    def oldest_wait_ms(self, now: Optional[float] = None) -> float:
        """Milliseconds the longest-waiting request has queued (0 if empty)."""
        if not self._q:
            return 0.0
        now = time.monotonic() if now is None else now
        return 1e3 * (now - min(r.arrival_ts for r in self._q))

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def __iter__(self):
        return iter(self._q)
