"""Length-prefixed, versioned, CRC-framed wire protocol for fleet workers.

One frame carries one message::

    +----+-----+------+--------+--------+-------+================+=========+
    | RW | ver | kind | hlen   | plen   | crc32 |  JSON header   | payload |
    | 2B | u16 | u8   | u32    | u64    | u32   |  (hlen bytes)  | (plen)  |
    +----+-----+------+--------+--------+-------+================+=========+

The JSON header holds the message's scalar fields plus per-tensor metadata;
the payload is the concatenation of the *raw encoded leaves* of every tensor
field, serialized through the :mod:`repro.transport` codec registry.  That
makes bytes-on-wire for a tensor exactly ``codec.wire_bytes(shape, dtype,
spec)`` — the same quantity the profiler sweeps over and the policy table
charges — an invariant the property tests assert against real sockets.

Versioning rule: the version is bumped only when an existing field or kind
changes meaning; *adding* header fields or new kinds is compatible.  A
receiver accepts frames with ``version <= PROTOCOL_VERSION`` (unknown header
fields are ignored) and rejects newer frames with :class:`FrameError` —
kind ids and field names are never reused.

Failures surface as typed :class:`repro.transport.TransportError`
subclasses so the fleet's existing retry/breaker machinery (which keys on
``TransportError.retryable``) handles real socket faults unchanged:

* :class:`WireTimeout`  — no/partial frame within the deadline
* :class:`WireClosed`   — EOF, connection reset, broken pipe
* :class:`FrameError`   — bad magic, unsupported version, CRC mismatch,
  malformed header, truncated payload (stream desync: close and reconnect)
"""
from __future__ import annotations

import dataclasses
import json
import socket
import struct
import zlib
from typing import Any, Dict, List, Optional, Tuple, Type

import numpy as np

from repro.transport.codecs import CodecSpec, get_codec
from repro.transport.links import TransportError

PROTOCOL_VERSION = 1

MAGIC = b"RW"
# magic(2s) version(u16) kind(u8) header_len(u32) payload_len(u64) crc(u32)
_FRAME = struct.Struct(">2sHBIQI")
FRAME_OVERHEAD = _FRAME.size

# Refuse absurd frames before allocating: headers are small JSON; payloads
# are bounded by the largest tensor the fleet ships (KV partitions, token
# arrays).  A corrupt length field otherwise turns into an OOM.
MAX_HEADER_BYTES = 16 << 20
MAX_PAYLOAD_BYTES = 4 << 30


class WireTimeout(TransportError):
    """recv/send did not complete within the deadline."""

    def __init__(self, msg, worker=""):
        super().__init__(msg, worker=worker, stage="rpc-timeout")


class WireClosed(TransportError):
    """Peer closed the connection (EOF, reset, broken pipe)."""

    def __init__(self, msg, worker=""):
        super().__init__(msg, worker=worker, stage="rpc-closed")


class FrameError(TransportError):
    """Corrupt or incompatible frame: the stream is desynchronized and the
    connection must be dropped (the client reconnects and re-submits)."""

    def __init__(self, msg, worker=""):
        super().__init__(msg, worker=worker, stage="rpc-frame")


# ---------------------------------------------------------------------------
# tensor (de)serialization through the codec registry
# ---------------------------------------------------------------------------

def _dtype_name(dt) -> str:
    return np.dtype(dt).name


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # ships with jax; covers bfloat16 et al.
        return np.dtype(getattr(ml_dtypes, name))


def pack_tensor(x, codec: str = "identity",
                spec: Optional[CodecSpec] = None) -> Tuple[Dict, bytes]:
    """Encode ``x`` with a registered codec and flatten to (meta, bytes).

    The byte string is exactly the encoded leaves back to back — its length
    is the codec's ``wire_bytes`` for this tensor (asserted here, so a codec
    whose accounting drifts from its encoding fails loudly at the wire).
    """
    c = get_codec(codec)
    spec = spec or CodecSpec()
    arr = np.asarray(x)
    payload = c.encode(arr, spec)
    leaves: List[Dict] = []
    chunks: List[bytes] = []
    for k in sorted(payload):
        leaf = np.ascontiguousarray(np.asarray(payload[k]))
        raw = leaf.tobytes()
        leaves.append({"k": k, "dtype": _dtype_name(leaf.dtype),
                       "shape": list(leaf.shape), "n": len(raw)})
        chunks.append(raw)
    blob = b"".join(chunks)
    expect = c.wire_bytes(arr.shape, arr.dtype, spec)
    if len(blob) != expect:
        raise FrameError(f"codec {codec!r} wire accounting drifted: encoded "
                         f"{len(blob)} bytes but wire_bytes says {expect}")
    meta = {"codec": codec, "L": spec.L, "param": spec.param,
            "shape": list(arr.shape), "dtype": _dtype_name(arr.dtype),
            "leaves": leaves}
    return meta, blob


def unpack_tensor(meta: Dict, blob: bytes) -> np.ndarray:
    """Inverse of :func:`pack_tensor`: rebuild leaves, decode through the
    codec.  Bit-exact with a local decode of the same encoded payload."""
    payload = {}
    off = 0
    for leaf in meta["leaves"]:
        n = int(leaf["n"])
        if off + n > len(blob):
            raise FrameError(f"tensor payload truncated: leaf {leaf['k']!r} "
                             f"needs {n} bytes at offset {off}, "
                             f"have {len(blob)}")
        dt = _resolve_dtype(leaf["dtype"])
        payload[leaf["k"]] = np.frombuffer(
            blob, dtype=dt, count=n // dt.itemsize, offset=off,
        ).reshape([int(s) for s in leaf["shape"]])
        off += n
    if off != len(blob):
        raise FrameError(f"tensor payload has {len(blob) - off} trailing "
                         "bytes")
    c = get_codec(meta["codec"])
    spec = CodecSpec(L=int(meta.get("L", 0)), param=int(meta.get("param", 0)))
    out = c.decode(payload, spec, shape=tuple(int(s) for s in meta["shape"]),
                   dtype=_resolve_dtype(meta["dtype"]))
    return np.asarray(out)


# ---------------------------------------------------------------------------
# messages
# ---------------------------------------------------------------------------

_KINDS: Dict[int, Type["Message"]] = {}


def message(cls):
    """Register a dataclass message under its ``KIND`` byte."""
    cls = dataclasses.dataclass(cls)
    kind = cls.KIND
    if kind in _KINDS:
        raise ValueError(f"kind {kind} already taken by "
                         f"{_KINDS[kind].__name__}")
    _KINDS[kind] = cls
    return cls


class Message:
    """Base: scalar dataclass fields ride in the JSON header; fields named
    in ``TENSORS`` (value → codec-field or fixed codec name) ride in the
    payload through the codec registry."""

    KIND = 0
    TENSORS: Dict[str, str] = {}   # field -> codec name | "@field" indirection

    def _codec_for(self, field: str) -> str:
        src = self.TENSORS[field]
        if src.startswith("@"):
            return getattr(self, src[1:])
        return src

    def _spec_for(self, field: str) -> CodecSpec:
        return CodecSpec(L=int(getattr(self, "codec_l", 0)),
                         param=int(getattr(self, "codec_param", 0)))

    def encode_frame(self) -> bytes:
        scalars = {}
        for f in dataclasses.fields(self):
            if f.name in self.TENSORS:
                continue
            scalars[f.name] = _jsonable(getattr(self, f.name))
        tensors = []
        blobs = []
        for field in self.TENSORS:
            val = getattr(self, field)
            if val is None:
                continue
            meta, blob = pack_tensor(val, self._codec_for(field),
                                     self._spec_for(field))
            meta["field"] = field
            tensors.append(meta)
            blobs.append(blob)
        header = json.dumps({"f": scalars, "t": tensors},
                            separators=(",", ":")).encode()
        payload = b"".join(blobs)
        crc = zlib.crc32(header)
        crc = zlib.crc32(payload, crc)
        return _FRAME.pack(MAGIC, PROTOCOL_VERSION, self.KIND,
                           len(header), len(payload), crc) + header + payload

    @classmethod
    def decode_frame(cls, kind: int, header: bytes, payload: bytes
                     ) -> "Message":
        try:
            doc = json.loads(header.decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise FrameError(f"malformed frame header: {e}") from None
        mcls = _KINDS.get(kind)
        if mcls is None:
            raise FrameError(f"unknown message kind {kind}")
        known = {f.name for f in dataclasses.fields(mcls)}
        # forward compatibility: ignore header fields this build doesn't know
        fields = {k: v for k, v in doc.get("f", {}).items() if k in known}
        off = 0
        for meta in doc.get("t", []):
            n = sum(int(l["n"]) for l in meta["leaves"])
            if off + n > len(payload):
                raise FrameError(
                    f"frame payload truncated: tensor {meta.get('field')!r} "
                    f"needs {n} bytes at offset {off}, have {len(payload)}")
            if meta.get("field") in mcls.TENSORS:
                fields[meta["field"]] = unpack_tensor(
                    meta, payload[off:off + n])
            off += n
        try:
            return mcls(**fields)
        except TypeError as e:
            raise FrameError(f"{mcls.__name__}: {e}") from None


def _jsonable(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, tuple):
        return list(v)
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    return v


@message
class Hello(Message):
    """Client → worker greeting; the reply describes the serving runtime."""
    KIND = 1
    name: str = ""
    protocol: int = PROTOCOL_VERSION


@message
class HelloAck(Message):
    KIND = 2
    name: str = ""
    pid: int = 0
    arch: str = ""
    n_slots: int = 0
    chunk: int = 0
    max_len: int = 0
    queue_size: int = 0


@message
class SubmitRequest(Message):
    """One serving request; the prompt tensor rides through ``codec``."""
    KIND = 3
    request_id: int = 0
    n_new: int = 0
    seed: int = 0
    temperature: float = 0.0
    slo_ms: Optional[float] = None
    arrival_ts: float = 0.0
    codec: str = "identity"
    codec_l: int = 0
    codec_param: int = 0
    # trace context (added post-v1; unknown header fields are ignored by
    # older builds, so the version stays 1): the worker stamps its spans
    # with trace_id and parents them under parent_span — the client-side
    # dispatch span — so one request yields one tree across the process
    # boundary.
    trace_id: str = ""
    parent_span: str = ""
    prompt: Optional[np.ndarray] = None
    TENSORS = {"prompt": "@codec"}


@message
class TokenChunk(Message):
    """Streamed decode progress: tokens[start:start+len) of a request."""
    KIND = 4
    request_id: int = 0
    start: int = 0
    # spans the worker finished since the last chunk for this request
    # (span_to_dict docs); empty for untraced runs and ignored by old
    # clients.
    spans: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    tokens: Optional[np.ndarray] = None
    TENSORS = {"tokens": "identity"}


@message
class CompletionMsg(Message):
    KIND = 5
    request_id: int = 0
    plan_key: str = ""
    admitted_ts: float = 0.0
    finished_ts: float = 0.0
    codec: str = ""
    wire_bytes: int = 0
    extrapolated: bool = False
    # remaining finished spans for this request (those not already shipped
    # on TokenChunk frames)
    spans: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    tokens: Optional[np.ndarray] = None
    TENSORS = {"tokens": "identity"}


@message
class Heartbeat(Message):
    """Ping (client → worker) / pong (worker → client, ``pong=True``); the
    pong carries the remote runtime's ``stats_snapshot()``."""
    KIND = 6
    seq: int = 0
    t: float = 0.0
    pong: bool = False
    stats: Dict[str, Any] = dataclasses.field(default_factory=dict)


@message
class Calibrate(Message):
    """Run ``calibrate_codec_bws`` on the worker's own process."""
    KIND = 7
    shape: Tuple[int, ...] = (4, 64, 256)
    iters: int = 3
    warmup: int = 1


@message
class CalibrateResult(Message):
    KIND = 8
    bws: Dict[str, float] = dataclasses.field(default_factory=dict)
    measured: bool = True


@message
class Profile(Message):
    """Re-run the profiling sweep on the worker; optional measured codec
    bandwidths to install first (empty dict = keep current)."""
    KIND = 9
    codec_bws: Dict[str, float] = dataclasses.field(default_factory=dict)
    bandwidths: List[float] = dataclasses.field(default_factory=list)


@message
class ProfileResult(Message):
    KIND = 10
    perfmap: Dict[str, Any] = dataclasses.field(default_factory=dict)


@message
class Drain(Message):
    KIND = 11


@message
class DrainResult(Message):
    """Ids of requests the worker gave back (client re-routes them)."""
    KIND = 12
    request_ids: List[int] = dataclasses.field(default_factory=list)


@message
class SetBandwidth(Message):
    KIND = 13
    mbps: float = 0.0


@message
class Shutdown(Message):
    KIND = 14


@message
class ErrorMsg(Message):
    KIND = 15
    detail: str = ""
    request_id: int = -1


# ---------------------------------------------------------------------------
# socket I/O
# ---------------------------------------------------------------------------

def _recv_exact(sock: socket.socket, n: int, *, worker: str = "",
                first: bool = False) -> bytes:
    """Read exactly ``n`` bytes; EOF at a frame boundary is a clean close,
    EOF mid-frame is a truncated frame — both are :class:`WireClosed` but
    the message distinguishes them for the fault log."""
    buf = bytearray()
    while len(buf) < n:
        try:
            part = sock.recv(n - len(buf))
        except socket.timeout:
            raise WireTimeout(
                f"timed out after {len(buf)}/{n} bytes", worker=worker
            ) from None
        except (ConnectionResetError, BrokenPipeError) as e:
            raise WireClosed(f"connection reset: {e}", worker=worker) \
                from None
        except OSError as e:
            raise WireClosed(f"socket error: {e}", worker=worker) from None
        if not part:
            if first and not buf:
                raise WireClosed("peer closed the connection", worker=worker)
            raise WireClosed(
                f"connection closed mid-frame ({len(buf)}/{n} bytes)",
                worker=worker)
        buf += part
    return bytes(buf)


def send_message(sock: socket.socket, msg: Message, *,
                 worker: str = "") -> int:
    """Send one frame; returns the exact bytes written to the socket."""
    frame = msg.encode_frame()
    try:
        sock.sendall(frame)
    except socket.timeout:
        raise WireTimeout(f"send of {len(frame)}B frame timed out",
                          worker=worker) from None
    except (ConnectionResetError, BrokenPipeError) as e:
        raise WireClosed(f"connection reset on send: {e}", worker=worker) \
            from None
    except OSError as e:
        raise WireClosed(f"socket error on send: {e}", worker=worker) \
            from None
    return len(frame)


def recv_message(sock: socket.socket, *, timeout: Optional[float] = None,
                 worker: str = "") -> Tuple[Message, int]:
    """Receive one frame; returns (message, bytes read off the socket).

    Raises :class:`WireTimeout` / :class:`WireClosed` / :class:`FrameError`.
    """
    old = sock.gettimeout()
    if timeout is not None:
        sock.settimeout(timeout)
    try:
        head = _recv_exact(sock, FRAME_OVERHEAD, worker=worker, first=True)
        magic, version, kind, hlen, plen, crc = _FRAME.unpack(head)
        if magic != MAGIC:
            raise FrameError(f"bad magic {magic!r} (stream desync?)",
                             worker=worker)
        if version > PROTOCOL_VERSION:
            raise FrameError(
                f"peer speaks protocol v{version}; this build reads "
                f"<= v{PROTOCOL_VERSION}", worker=worker)
        if hlen > MAX_HEADER_BYTES or plen > MAX_PAYLOAD_BYTES:
            raise FrameError(f"implausible frame lengths header={hlen} "
                             f"payload={plen}", worker=worker)
        header = _recv_exact(sock, hlen, worker=worker)
        payload = _recv_exact(sock, plen, worker=worker)
        got = zlib.crc32(payload, zlib.crc32(header))
        if got != crc:
            raise FrameError(f"CRC mismatch (expected {crc:#010x}, got "
                             f"{got:#010x})", worker=worker)
        msg = Message.decode_frame(kind, header, payload)
        return msg, FRAME_OVERHEAD + hlen + plen
    finally:
        try:
            sock.settimeout(old)
        except OSError:
            pass   # peer may have vanished; the raised error already says so
