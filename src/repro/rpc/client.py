"""RpcWorker — a fleet worker living in another process.

Implements the :class:`repro.fleet.registry.Worker` interface over the
:mod:`repro.rpc.wire` protocol, so a subprocess running
``python -m repro.rpc.worker`` drops in beside ``WorkerHandle``/``SimWorker``
in a :class:`~repro.fleet.registry.DeviceRegistry` — same scoring, same
EDF drain→re-route, same circuit breakers.  The differences are exactly the
point:

* **liveness is real**: heartbeats cross the wire; a dead socket or dead
  process flips ``healthy`` off, the router stops beating the worker, and
  the existing heartbeat-death drain path re-routes its requests;
* **faults are measured, not modeled**: connection resets, timeouts and
  truncated frames raise typed :class:`TransportError`\\ s that feed the
  same :class:`~repro.runtime.fault.RetryPolicy` capped backoff and
  :class:`~repro.runtime.fault.CircuitBreaker` machinery the chaos tier
  exercises with ``ChaosEvent`` models;
* **calibration is measured on the worker's process**
  (:meth:`measure_codec_bws` → ``Calibrate``), and profiling sweeps run
  remotely (:meth:`reprofile` → ``Profile``), so the policy table prices
  codecs the way *that* process pays for them;
* **the chaos bridge realizes faults on the wire**: an armed ``error``
  becomes an actual half-written frame + hard close, ``straggle`` a real
  delay, and ``kill``/``revive`` a real ``SIGKILL``/respawn
  (:meth:`kill_process`/:meth:`respawn`, driven by ``ChaosController`` and
  ``DeviceRegistry.readmit``).

Exactly-once: the client mirrors every unfinished request (``_owned`` +
the outbox queue), blindly re-submits after a reconnect, and relies on the
server's request-id dedup; completions for unknown ids are dropped as
stale.  Token-exactness is inherited from ``seed``/``temperature`` pinning
plus deterministic session construction (same arch/vocab/seed in every
process).
"""
from __future__ import annotations

import os
import select
import socket
import subprocess
import sys
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.chaos.schedule import DispatchFault
from repro.core.perfmap import PerfMap
from repro.obs import MetricsRegistry, StatsDict, request_trace_id
from repro.core.policy import AdaptivePolicy, resolve_objective
from repro.fleet.registry import Worker, scaled_hardware
from repro.profiling.hardware import (JETSON_ORIN_NANO, WIFI_GLOO,
                                      HardwareProfile, LinkProfile)
from repro.runtime.fault import RetryPolicy
from repro.rpc import wire
from repro.rpc.wire import (
    Calibrate, CalibrateResult, CompletionMsg, Drain, DrainResult, ErrorMsg,
    Heartbeat, Hello, HelloAck, Profile, ProfileResult, SetBandwidth,
    Shutdown, SubmitRequest, TokenChunk, TransportError, WireClosed,
    WireTimeout,
)
from repro.serving.engine import Completion
from repro.serving.queue import Request, RequestQueue


class RpcWorker(Worker):
    """A process-boundary fleet worker (spawned subprocess or remote addr).

    The bounded EDF ``queue`` holds accepted-but-unsent requests (the
    outbox); ``_owned`` mirrors everything submitted over the wire and not
    yet completed, so :meth:`drain_requests` can hand the router the full
    set even after the process died taking its state with it.
    """

    def __init__(self, name: str, *,
                 address: Optional[Tuple[str, int]] = None,
                 arch: str = "llama3.2-1b", vocab: int = 64, seed: int = 0,
                 n_slots: int = 2, chunk: int = 4, max_len: int = 64,
                 queue_size: int = 64, hw_scale: float = 1.0,
                 prism_l: int = 4, prism_cr: float = 9.9,
                 bandwidth_mbps: float = 400.0,
                 hardware: Optional[HardwareProfile] = None,
                 link: LinkProfile = WIFI_GLOO,
                 objective="latency", allow_modes=("local", "prism"),
                 retry: Optional[RetryPolicy] = None,
                 io_timeout_s: float = 10.0,
                 heartbeat_every_s: float = 0.25,
                 heartbeat_timeout_s: float = 60.0,
                 connect_timeout_s: float = 300.0,
                 profile_timeout_s: float = 600.0,
                 poll_s: float = 0.002,
                 spawn: bool = True, shed_expired: bool = False,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer=None):
        self.name = name
        self.arch = arch
        self._spawn_args = dict(arch=arch, vocab=vocab, seed=seed,
                                n_slots=n_slots, chunk=chunk,
                                max_len=max_len, queue_size=queue_size,
                                hw_scale=hw_scale, prism_l=prism_l,
                                prism_cr=prism_cr)
        self.hardware = hardware or (
            scaled_hardware(JETSON_ORIN_NANO, hw_scale)
            if hw_scale != 1.0 else JETSON_ORIN_NANO)
        self.link = link
        self.n_slots = n_slots
        self.max_len = max_len
        self.queue = RequestQueue(queue_size, shed_expired=shed_expired)
        self.codec_bws: Dict[str, float] = {}
        self.codec_bws_measured = False
        self.objective = resolve_objective(objective)
        self._allow_modes = tuple(allow_modes)
        self.retry = retry or RetryPolicy()
        self.io_timeout_s = io_timeout_s
        self.heartbeat_every_s = heartbeat_every_s
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.connect_timeout_s = connect_timeout_s
        self.profile_timeout_s = profile_timeout_s
        self.poll_s = poll_s
        self._bandwidth = float(bandwidth_mbps)
        self.perfmap: Optional[PerfMap] = None
        self.policy: Optional[AdaptivePolicy] = None
        self.profiled_count = 0
        # wire state
        self.proc: Optional[subprocess.Popen] = None
        self.sock: Optional[socket.socket] = None
        self.address = address
        self.healthy = True
        self.chaos = None                     # set by ChaosController.attach
        self._owned: Dict[int, Request] = {}  # sent, not yet completed
        self._fresh: List[Completion] = []    # completed since last step()
        self.completions: List[Completion] = []
        self._faults: List[DispatchFault] = []
        self._consec = 0                      # consecutive wire failures
        self._retry_at = 0.0                  # reconnect backoff gate
        self._stall_until = 0.0
        self._hb_seq = 0
        self._last_ping = 0.0
        self._last_rx = time.monotonic()
        self.remote_stats: Dict[str, Any] = {}
        self.metrics = metrics or MetricsRegistry()
        # per-request client-side "dispatch" span: opened when the request
        # goes over the wire, its span id rides SubmitRequest.parent_span
        # so the subprocess worker's spans land under it, finished when the
        # completion surfaces (or the request drains away)
        self.tracer = tracer
        self._dispatch_spans: Dict[int, Any] = {}
        self.stats = StatsDict(
            self.metrics, "rpc.client",
            {"submitted": 0, "served": 0, "tokens": 0,
             "streamed_tokens": 0, "retries": 0, "reconnects": 0,
             "timeouts": 0, "transport_errors": 0, "straggled": 0,
             "stale_completions": 0, "remote_errors": 0,
             "frames_in": 0, "frames_out": 0,
             "bytes_in": 0, "bytes_out": 0},
            labels={"worker": name})
        if address is None and spawn:
            self._spawn()
        self._connect()
        self.reprofile()                      # pull the worker's own table

    # -- process / connection lifecycle --------------------------------------

    def _spawn(self) -> None:
        a = self._spawn_args
        cmd = [sys.executable, "-m", "repro.rpc.worker",
               "--host", "127.0.0.1", "--port", "0", "--name", self.name,
               "--arch", a["arch"], "--vocab", str(a["vocab"]),
               "--seed", str(a["seed"]), "--n-slots", str(a["n_slots"]),
               "--chunk", str(a["chunk"]), "--max-len", str(a["max_len"]),
               "--queue-size", str(a["queue_size"]),
               "--hw-scale", str(a["hw_scale"]),
               "--prism-l", str(a["prism_l"]),
               "--prism-cr", str(a["prism_cr"])]
        env = dict(os.environ)
        env["PYTHONUNBUFFERED"] = "1"
        self.proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, text=True,
                                     env=env)
        deadline = time.monotonic() + self.connect_timeout_s
        while True:
            if time.monotonic() > deadline:
                self.kill_process()
                raise WireTimeout(f"worker {self.name!r} did not print "
                                  f"RPC_READY within {self.connect_timeout_s}"
                                  "s", worker=self.name)
            ready, _, _ = select.select([self.proc.stdout], [], [], 0.5)
            if not ready:
                if self.proc.poll() is not None:
                    raise WireClosed(
                        f"worker {self.name!r} exited with code "
                        f"{self.proc.returncode} before RPC_READY",
                        worker=self.name)
                continue
            line = self.proc.stdout.readline()
            if not line:
                raise WireClosed(
                    f"worker {self.name!r} closed stdout before RPC_READY "
                    f"(exit code {self.proc.poll()})", worker=self.name)
            if line.startswith("RPC_READY"):
                fields = dict(kv.split("=") for kv in line.split()[1:])
                self.address = ("127.0.0.1", int(fields["port"]))
                break

    def _connect(self) -> None:
        if self.address is None:
            raise ValueError(f"worker {self.name!r} has no address "
                             "(spawn=False needs address=)")
        try:
            sock = socket.create_connection(self.address, timeout=5.0)
        except OSError as e:
            raise WireClosed(f"connect to {self.address} failed: {e}",
                             worker=self.name) from None
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.settimeout(self.io_timeout_s)
        self.sock = sock
        self._last_rx = time.monotonic()
        ack = self._rpc_call(Hello(name=self.name), HelloAck,
                             timeout=self.io_timeout_s)
        self.n_slots = ack.n_slots or self.n_slots
        self.max_len = ack.max_len or self.max_len
        self.remote_pid = ack.pid
        # re-submit everything the wire drop left in limbo: the server's
        # request-id dedup makes duplicates harmless (exactly-once)
        for req in sorted(self._owned.values(),
                          key=lambda r: (r.deadline(), r.arrival_ts)):
            self._send(self._submit_msg(req))

    def kill_process(self) -> None:
        """SIGKILL the subprocess (the chaos `kill` realization)."""
        if self.proc is not None and self.proc.poll() is None:
            self.proc.kill()
            try:
                self.proc.wait(timeout=10.0)
            except subprocess.TimeoutExpired:
                pass

    def respawn(self) -> None:
        """Bring a dead worker back: fresh subprocess, fresh socket, same
        deterministic session (readmission path — DeviceRegistry.readmit
        calls this before re-calibrating)."""
        self.kill_process()
        self._drop_sock()
        self._spawn()
        self._consec = 0
        self._retry_at = 0.0
        self.healthy = True
        self._connect()

    def close(self) -> None:
        """Clean shutdown: ask the worker to exit, then make sure it did."""
        if self.sock is not None:
            try:
                wire.send_message(self.sock, Shutdown(), worker=self.name)
            except TransportError:
                pass
        self._drop_sock()
        if self.proc is not None:
            try:
                self.proc.wait(timeout=5.0)
            except subprocess.TimeoutExpired:
                self.kill_process()
            if self.proc.stdout is not None:
                self.proc.stdout.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _drop_sock(self) -> None:
        if self.sock is not None:
            try:
                self.sock.close()
            except OSError:
                pass
            self.sock = None

    # -- wire plumbing -------------------------------------------------------

    def _send(self, msg) -> None:
        if self.sock is None:
            raise WireClosed("not connected", worker=self.name)
        n = wire.send_message(self.sock, msg, worker=self.name)
        self.stats["frames_out"] += 1
        self.stats["bytes_out"] += n

    def _recv(self, timeout: Optional[float] = None):
        if self.sock is None:
            raise WireClosed("not connected", worker=self.name)
        msg, n = wire.recv_message(
            self.sock, timeout=self.io_timeout_s if timeout is None
            else timeout, worker=self.name)
        self._last_rx = time.monotonic()
        self.stats["frames_in"] += 1
        self.stats["bytes_in"] += n
        return msg

    def _rpc_call(self, msg, want, *, timeout: float):
        """Send a control message and pump until its reply arrives (serving
        traffic received in between is dispatched normally, not dropped)."""
        self._send(msg)
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            readable, _, _ = select.select([self.sock], [], [], 0.1)
            if not readable:
                if self.proc is not None and self.proc.poll() is not None:
                    raise WireClosed(
                        f"worker process died (exit {self.proc.returncode}) "
                        f"awaiting {want.__name__}", worker=self.name)
                continue
            reply = self._recv()
            if isinstance(reply, want):
                return reply
            if isinstance(reply, ErrorMsg) and reply.request_id < 0:
                raise TransportError(f"remote error: {reply.detail}",
                                     worker=self.name, stage="rpc-remote")
            self._dispatch(reply)
        raise WireTimeout(f"no {want.__name__} within {timeout}s",
                          worker=self.name)

    def _dispatch(self, msg) -> None:
        if isinstance(msg, CompletionMsg):
            req = self._owned.pop(msg.request_id, None)
            if req is None:       # duplicate/stale (e.g. re-routed already)
                self.stats["stale_completions"] += 1
                return
            comp = Completion(
                request_id=msg.request_id,
                tokens=np.asarray(msg.tokens, np.int32),
                plan_key=msg.plan_key, arrival_ts=req.arrival_ts,
                admitted_ts=msg.admitted_ts, finished_ts=time.monotonic(),
                slo_ms=req.slo_ms, extrapolated=msg.extrapolated,
                codec=msg.codec, wire_bytes=msg.wire_bytes,
                worker=self.name)
            self._fresh.append(comp)
            self.completions.append(comp)
            self.stats["served"] += 1
            self.stats["tokens"] += len(comp.tokens)
            if self.tracer is not None:
                # re-parenting is implicit: the worker stamped its spans
                # with SubmitRequest.parent_span, so ingest lands them
                # under this client's dispatch span
                self.tracer.ingest(msg.spans)
                d = self._dispatch_spans.pop(msg.request_id, None)
                if d is not None:
                    self.tracer.finish(d, at=comp.finished_ts)
        elif isinstance(msg, TokenChunk):
            self.stats["streamed_tokens"] += int(np.asarray(msg.tokens).size)
            if self.tracer is not None and msg.spans:
                self.tracer.ingest(msg.spans)
        elif isinstance(msg, Heartbeat):
            self.remote_stats = dict(msg.stats)
        elif isinstance(msg, ErrorMsg):
            self.stats["remote_errors"] += 1
            req = self._owned.pop(msg.request_id, None)
            if req is not None:   # per-request rejection: let the router
                self._faults.append(DispatchFault(    # re-place it
                    worker=self.name, kind="error", t=time.monotonic(),
                    retried=(), gave_up=(req,)))
                self._close_dispatch_span(msg.request_id, "remote_error")

    # -- Worker interface: placement inputs ----------------------------------

    @property
    def bandwidth(self) -> float:
        return self._bandwidth

    def observe_bandwidth(self, mbps: float) -> None:
        self._bandwidth = float(mbps)
        if self.sock is not None and self.healthy:
            try:
                self._send(SetBandwidth(mbps=float(mbps)))
            except TransportError as e:
                self._on_wire_error(e, time.monotonic())

    def table(self, objective=None):
        if self.policy is None:
            raise RuntimeError(f"worker {self.name!r} has no policy table "
                               "yet (reprofile failed?)")
        return self.policy.table(objective or self.objective)

    @property
    def in_flight(self) -> int:
        return len(self._owned)

    # -- Worker interface: intake / service ----------------------------------

    def submit_request(self, req: Request, force: bool = False) -> Request:
        if req.total_len > self.max_len:
            raise ValueError(
                f"request needs {req.total_len} positions but worker "
                f"{self.name!r} pools are sized for {self.max_len}")
        return self.queue.put(req, force=force)

    def _submit_msg(self, req: Request) -> SubmitRequest:
        msg = SubmitRequest(
            request_id=req.id, n_new=req.n_new, seed=req.seed,
            temperature=req.temperature, slo_ms=req.slo_ms,
            arrival_ts=req.arrival_ts,
            prompt=np.asarray(req.prompt, np.int32))
        if self.tracer is not None:
            if not req.trace_id:
                req.trace_id = request_trace_id(req.id)
            d = self._dispatch_spans.get(req.id)
            if d is None:
                d = self.tracer.start(
                    "dispatch", kind="rpc", trace_id=req.trace_id,
                    parent_id=req.parent_span or None, worker=self.name,
                    request_id=req.id)
                self._dispatch_spans[req.id] = d
            msg.trace_id = req.trace_id
            msg.parent_span = d.span_id
        return msg

    def step(self, now: Optional[float] = None) -> List[Completion]:
        """One client round: realize armed chaos, flush the outbox, keep
        heartbeats flowing, pump inbound frames.  Any wire failure lands in
        the fault stream (→ breaker) and starts capped-backoff reconnects;
        a dead process (or exhausted budget) flips ``healthy`` off so the
        router's heartbeat-death path drains us."""
        mono = time.monotonic()
        if not self.healthy:
            done, self._fresh = self._fresh, []
            return done
        try:
            self._consume_chaos(mono)
            if self.sock is None:
                self._reconnect(mono)
            if self.sock is not None:
                self._flush_outbox(mono)
                self._heartbeat(mono)
                self._pump()
                self._check_liveness(mono)
        except TransportError as e:
            self._on_wire_error(e, mono)
        done, self._fresh = self._fresh, []
        return done

    def _flush_outbox(self, mono: float) -> None:
        if mono < self._stall_until:
            return
        while self.queue:
            reqs = self.queue.pop_many(1, now=mono)
            if not reqs:
                return             # everything left had expired
            req = reqs[0]
            try:
                self._send(self._submit_msg(req))
            except TransportError:
                self.queue.put(req, force=True)   # keep ownership
                raise
            self._owned[req.id] = req
            self.stats["submitted"] += 1

    def _heartbeat(self, mono: float) -> None:
        if mono - self._last_ping < self.heartbeat_every_s:
            return
        self._hb_seq += 1
        self._last_ping = mono
        self._send(Heartbeat(seq=self._hb_seq, t=mono))

    def _pump(self) -> None:
        # With work in flight and nothing produced yet, wait a moment for
        # the wire instead of returning instantly: spin-loops like
        # ``FleetRouter.run`` then advance in wall-clock time rather than
        # exhausting their step budget while the remote process computes.
        wait = self.poll_s if (self._owned and not self._fresh) else 0.0
        while self.sock is not None:
            readable, _, _ = select.select([self.sock], [], [], wait)
            if not readable:
                return
            self._dispatch(self._recv())
            wait = 0.0

    def _check_liveness(self, mono: float) -> None:
        if mono - self._last_rx > self.heartbeat_timeout_s:
            raise WireTimeout(
                f"no traffic from worker {self.name!r} for "
                f"{mono - self._last_rx:.1f}s", worker=self.name)

    def next_event_at(self, now: float) -> float:
        return now if (self.queue or self._owned) else float("inf")

    # -- failure handling ----------------------------------------------------

    def _close_dispatch_span(self, request_id: int, reason: str) -> None:
        if self.tracer is None:
            return
        d = self._dispatch_spans.pop(request_id, None)
        if d is not None and d.open:
            d.attrs["outcome"] = reason
            self.tracer.finish(d)

    def _on_wire_error(self, err: TransportError, mono: float) -> None:
        self._drop_sock()
        self._consec += 1
        kind = "timeout" if isinstance(err, WireTimeout) else "error"
        self.stats["timeouts" if kind == "timeout"
                   else "transport_errors"] += 1
        self._faults.append(DispatchFault(
            worker=self.name, kind=kind, t=mono,
            retried=tuple(self._owned), gave_up=()))
        if self.tracer is not None:
            # the reconnect will re-submit these under the same dispatch
            # span; the retry leaf marks the wire fault in the request tree
            for rid, req in self._owned.items():
                d = self._dispatch_spans.get(rid)
                self.tracer.record(
                    "retry", start=mono, end=mono, kind="rpc",
                    trace_id=req.trace_id or request_trace_id(rid),
                    parent_id=d.span_id if d is not None else None,
                    worker=self.name, reason=kind, attempt=self._consec)
        # no dead-process short-circuit: a killed worker is discovered the
        # way a crashed remote one would be — reconnects genuinely fail,
        # each failure feeds the breaker, and only an exhausted retry
        # budget flips `healthy` (router fails us → drain → re-route)
        if self._consec > self.retry.max_retries:
            self.healthy = False
        else:
            self.stats["retries"] += 1
            self._retry_at = mono + self.retry.backoff_s(self._consec - 1)

    def _reconnect(self, mono: float) -> None:
        if mono < self._retry_at:
            return
        self._connect()               # re-submits owned requests (dedup'd)
        self._consec = 0
        self.stats["reconnects"] += 1

    def drain_requests(self) -> List[Request]:
        """Everything this worker still owes: unsent outbox + the wire
        mirror of in-flight work (survives the process dying, which is the
        whole reason the mirror exists)."""
        reqs = self.queue.drain()
        reqs.extend(self._owned.values())
        self._owned.clear()
        for req in reqs:
            self._close_dispatch_span(req.id, "drained")
        return reqs

    def pop_faults(self) -> List[DispatchFault]:
        out, self._faults = self._faults, []
        return out

    # -- chaos bridge: modeled events become real wire faults ----------------

    def _consume_chaos(self, mono: float) -> None:
        if self.chaos is None:
            return
        fault = self.chaos.dispatch_fault(self.name, mono)
        if fault is None:
            return
        if fault.kind == "straggle":
            # realized as an actual stall of this client round
            time.sleep(min(0.01 * max(fault.value, 1.0), 0.25))
            self.stats["straggled"] += 1
        elif fault.kind == "error":
            self._sabotage_wire()

    def _sabotage_wire(self) -> None:
        """Realize an armed transport error as *real* bytes: half a frame,
        then a hard close — the server sees an actual truncated frame and
        drops the conn; we see an actual dead socket and retry/back off."""
        if self.sock is None:
            return
        frame = Heartbeat(seq=-1).encode_frame()
        try:
            self.sock.sendall(frame[:len(frame) // 2])
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        raise WireClosed("chaos: wire sabotaged (truncated frame + close)",
                         worker=self.name)

    def apply_stall(self, t: float, duration: float) -> None:
        """Scripted stall: stop flushing the outbox for ``duration`` (the
        wire stays up — requests just sit in the EDF queue)."""
        self._stall_until = max(self._stall_until,
                                time.monotonic() + duration)

    # -- calibration / profiling over the wire -------------------------------

    def measure_codec_bws(self, *, shape=(4, 64, 256), iters: int = 3,
                          warmup: int = 1) -> Dict[str, float]:
        """Truly measured codec decode throughputs — run by
        ``calibrate_codec_bws`` on the worker's own process, not scaled
        from a host estimate."""
        res = self._rpc_call(
            Calibrate(shape=tuple(shape), iters=iters, warmup=warmup),
            CalibrateResult, timeout=self.profile_timeout_s)
        self.codec_bws = {k: float(v) for k, v in res.bws.items()}
        self.codec_bws_measured = bool(res.measured)
        return dict(self.codec_bws)

    def reprofile(self, codec_bws: Optional[Dict[str, float]] = None) -> None:
        """Re-run the profiling sweep on the worker's process and rebuild
        the local policy table from the shipped perf map."""
        if codec_bws is not None:
            self.codec_bws = dict(codec_bws)
        res = self._rpc_call(Profile(codec_bws=self.codec_bws or {}),
                             ProfileResult, timeout=self.profile_timeout_s)
        self.perfmap = PerfMap.from_doc(res.perfmap,
                                        source=f"rpc:{self.name}")
        self.policy = AdaptivePolicy(self.perfmap,
                                     allow_modes=self._allow_modes)
        self.profiled_count += 1

    def drain_remote(self) -> List[int]:
        """Ask the worker to give back everything it holds (ids); used by
        graceful scale-down, not the dead-worker path."""
        res = self._rpc_call(Drain(), DrainResult, timeout=self.io_timeout_s)
        return list(res.request_ids)

    # -- telemetry -----------------------------------------------------------

    def stats_snapshot(self) -> Dict:
        snap = dict(self.stats)
        snap["queue_depth"] = len(self.queue)
        snap["in_flight"] = len(self._owned)
        snap["completed"] = len(self.completions)
        snap["rejected"] = self.queue.rejected
        snap["rejections"] = dict(self.queue.rejections)
        snap["expired"] = self.queue.rejections.get("expired", 0)
        snap["profiled_count"] = self.profiled_count
        snap["healthy"] = self.healthy
        snap["codec_bws_measured"] = self.codec_bws_measured
        snap["remote"] = dict(self.remote_stats)
        return snap

    @property
    def served_tokens(self) -> int:
        return self.stats["tokens"]
