"""repro.rpc — the fleet's real worker-process boundary.

* :mod:`repro.rpc.wire` — length-prefixed, versioned, CRC-framed message
  protocol; tensor payloads serialized through the
  :mod:`repro.transport` codec registry so bytes-on-wire is the same
  quantity the policy sweeps over.
* :mod:`repro.rpc.worker` — ``WorkerServer`` + the
  ``python -m repro.rpc.worker`` subprocess entrypoint (session +
  ``ServingRuntime`` + on-process calibration/profiling).
* :mod:`repro.rpc.client` — :class:`RpcWorker`, a drop-in
  :class:`~repro.fleet.registry.Worker` whose heartbeats, faults, and
  calibration cross an actual socket.
"""
from repro.rpc.wire import (  # noqa: F401
    FRAME_OVERHEAD, PROTOCOL_VERSION, FrameError, Message, TransportError,
    WireClosed, WireTimeout, pack_tensor, recv_message, send_message,
    unpack_tensor,
)
from repro.rpc.client import RpcWorker  # noqa: F401


def __getattr__(name):
    # lazy: `python -m repro.rpc.worker` must not find repro.rpc.worker
    # already imported by its own package __init__ (runpy warns)
    if name in ("WorkerServer", "worker_main"):
        from repro.rpc import worker
        return getattr(worker, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "FRAME_OVERHEAD", "PROTOCOL_VERSION", "FrameError", "Message",
    "TransportError", "WireClosed", "WireTimeout", "pack_tensor",
    "recv_message", "send_message", "unpack_tensor", "RpcWorker",
    "WorkerServer", "worker_main",
]
