"""The worker side of the fleet's process boundary.

:class:`WorkerServer` owns an :class:`~repro.api.InferenceSession` +
:class:`~repro.serving.ServingRuntime` and serves the :mod:`repro.rpc.wire`
protocol over a local TCP socket — decode progress streams out as
``TokenChunk`` frames, finished requests as ``CompletionMsg``.  Codec
calibration (``Calibrate``) and profiling sweeps (``Profile``) run **in this
process**, so the numbers the registry installs are truly measured on the
worker, not eff_inf-scaled host estimates.

Exactly-once: the server deduplicates ``SubmitRequest`` by request id.  A
client that reconnects after a wire fault blindly re-submits everything it
still owns; a duplicate of a finished request gets its cached completion
re-sent, a duplicate of an in-flight request is ignored.  The listener
accepts sequential reconnections from the (single) client for the same
reason.

``worker_main()`` is the subprocess entrypoint
(``python -m repro.rpc.worker --port 0 ...``); it prints a single
``RPC_READY port=<p> pid=<p>`` line to stdout once the session is built and
profiled, which the spawning :class:`~repro.rpc.client.RpcWorker` parses.
"""
from __future__ import annotations

import argparse
import os
import select
import socket
import sys
from typing import Dict, Optional

import numpy as np

from repro.obs import MetricsRegistry, StatsDict, Tracer, span_to_dict
from repro.rpc import wire
from repro.rpc.wire import (
    Calibrate, CalibrateResult, CompletionMsg, Drain, DrainResult, ErrorMsg,
    Heartbeat, Hello, HelloAck, Profile, ProfileResult, SetBandwidth,
    Shutdown, SubmitRequest, TokenChunk, TransportError,
)
from repro.serving.engine import ServingRuntime
from repro.serving.queue import Request
from repro.transport.codecs import calibrate_codec_bws, codec_overrides
from repro.profiling.sweep import SweepSpec


class WorkerServer:
    """Single-threaded serve loop: alternate between draining the socket
    and stepping the runtime, so decode keeps making progress while frames
    trickle in.  Also usable in-process (tests run it on a thread over a
    socketpair) — the protocol does not care."""

    def __init__(self, session, *, name: str = "worker",
                 arch: str = "", n_slots: int = 4, chunk: int = 8,
                 max_len: int = 256, queue_size: int = 64,
                 hardware=None, link=None, sweep: Optional[SweepSpec] = None):
        self.session = session
        self.name = name
        self.arch = arch
        self.hardware = hardware
        self.link = link
        self.sweep = sweep or SweepSpec()
        self.metrics = MetricsRegistry()
        self.runtime = ServingRuntime(session, n_slots=n_slots, chunk=chunk,
                                      max_len=max_len, queue_size=queue_size,
                                      metrics=self.metrics, worker=name)
        self.runtime.on_progress = self._on_progress
        # exactly-once bookkeeping: id -> cached CompletionMsg (None while
        # the request is still queued/in flight)
        self._seen: Dict[int, Optional[CompletionMsg]] = {}
        self._streamed: Dict[int, int] = {}    # id -> chunk tokens sent
        self._conn: Optional[socket.socket] = None
        self._shutdown = False
        # tracing is demand-driven: stays None (zero cost) until a traced
        # SubmitRequest arrives, then spans ship back on TokenChunk /
        # CompletionMsg frames exactly once each
        self.tracer: Optional[Tracer] = None
        self._trace_ids: Dict[int, str] = {}     # id -> trace id
        self._shipped: Dict[int, set] = {}       # id -> span ids sent
        self.stats = StatsDict(
            self.metrics, "rpc.server",
            {"frames_in": 0, "frames_out": 0, "bytes_in": 0,
             "bytes_out": 0, "submits": 0, "dup_submits": 0,
             "calibrations": 0, "profiles": 0, "reconnects": 0,
             "frame_errors": 0},
            labels={"worker": name})

    # -- streaming -----------------------------------------------------------

    def _on_progress(self, request_id: int, tokens) -> None:
        """Stream newly decoded chunk tokens (positions 1.. of the output;
        position 0 stays on device until completion — the CompletionMsg is
        the authoritative, token-exact record)."""
        if self._conn is None:
            return
        sent = self._streamed.get(request_id, 0)
        fresh = tokens[sent:]
        if not fresh:
            return
        self._streamed[request_id] = sent + len(fresh)
        self._send(TokenChunk(request_id=request_id, start=1 + sent,
                              spans=self._fresh_spans(request_id),
                              tokens=np.asarray(fresh, np.int32)))

    def _fresh_spans(self, request_id: int):
        """Finished spans of this request's trace not yet shipped — each
        span rides exactly one frame (the client ingest dedups anyway)."""
        if self.tracer is None:
            return []
        tid = self._trace_ids.get(request_id)
        if not tid:
            return []
        shipped = self._shipped.setdefault(request_id, set())
        out = []
        for sp in self.tracer.trace(tid):
            if sp.open or sp.span_id in shipped:
                continue
            shipped.add(sp.span_id)
            out.append(span_to_dict(sp))
        return out

    # -- plumbing ------------------------------------------------------------

    def _send(self, msg) -> None:
        if self._conn is None:
            return
        try:
            self.stats["bytes_out"] += wire.send_message(
                self._conn, msg, worker=self.name)
            self.stats["frames_out"] += 1
        except TransportError:
            # client vanished mid-send; drop the conn, keep state — the
            # reconnecting client re-submits and dedup re-sends completions
            self._drop_conn()

    def _drop_conn(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            except OSError:
                pass
            self._conn = None

    # -- serve loop ----------------------------------------------------------

    def serve_forever(self, host: str = "127.0.0.1", port: int = 0,
                      *, ready=print) -> None:
        listener = socket.create_server((host, port))
        listener.settimeout(0.1)
        actual = listener.getsockname()[1]
        ready(f"RPC_READY port={actual} pid={os.getpid()}", flush=True)
        try:
            while not self._shutdown:
                if self._conn is None:
                    try:
                        conn, _ = listener.accept()
                    except socket.timeout:
                        continue
                    conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                    self._conn = conn
                    self.stats["reconnects"] += 1
                self.serve_conn(self._conn)
        finally:
            self._drop_conn()
            listener.close()

    def serve_conn(self, conn: socket.socket) -> None:
        """Serve one connection until it drops or Shutdown arrives.  Used
        directly by in-process tests (socketpair); ``serve_forever`` wraps
        it with an accept loop."""
        self._conn = conn
        while not self._shutdown and self._conn is not None:
            busy = bool(self.runtime.queue) or not self.runtime.idle
            try:
                readable, _, _ = select.select(
                    [conn], [], [], 0.0 if busy else 0.02)
            except (OSError, ValueError):
                self._drop_conn()
                return
            if readable:
                try:
                    msg, n = wire.recv_message(conn, timeout=2.0,
                                               worker=self.name)
                except wire.FrameError:
                    # stream desync (truncated/corrupt frame): the only
                    # safe recovery is dropping the conn; the client
                    # reconnects and re-submits
                    self.stats["frame_errors"] += 1
                    self._drop_conn()
                    return
                except TransportError:
                    self._drop_conn()
                    return
                self.stats["frames_in"] += 1
                self.stats["bytes_in"] += n
                self._handle(msg)
            if busy:
                for comp in self.runtime.step():
                    done = CompletionMsg(
                        request_id=comp.request_id, plan_key=comp.plan_key,
                        admitted_ts=comp.admitted_ts,
                        finished_ts=comp.finished_ts, codec=comp.codec,
                        wire_bytes=comp.wire_bytes,
                        extrapolated=comp.extrapolated,
                        spans=self._fresh_spans(comp.request_id),
                        tokens=np.asarray(comp.tokens, np.int32))
                    self._seen[comp.request_id] = done
                    self._streamed.pop(comp.request_id, None)
                    self._shipped.pop(comp.request_id, None)
                    self._trace_ids.pop(comp.request_id, None)
                    self._send(done)

    # -- message handlers ----------------------------------------------------

    def _handle(self, msg) -> None:
        handler = getattr(self, f"_on_{type(msg).__name__}", None)
        if handler is None:
            self._send(ErrorMsg(detail=f"unhandled {type(msg).__name__}"))
            return
        try:
            handler(msg)
        except TransportError:
            raise
        except Exception as e:   # a bad request must not kill the worker
            self._send(ErrorMsg(
                detail=f"{type(msg).__name__}: {type(e).__name__}: {e}",
                request_id=getattr(msg, "request_id", -1)))

    def _on_Hello(self, msg: Hello) -> None:
        self._send(HelloAck(
            name=self.name, pid=os.getpid(), arch=self.arch,
            n_slots=self.runtime.n_slots, chunk=self.runtime.chunk,
            max_len=self.runtime.max_len,
            queue_size=self.runtime.queue.max_size))

    def _on_SubmitRequest(self, msg: SubmitRequest) -> None:
        if msg.request_id in self._seen:
            self.stats["dup_submits"] += 1
            done = self._seen[msg.request_id]
            if done is not None:      # finished before the client's retry
                self._send(done)
            return                    # still in flight: first submit wins
        self._seen[msg.request_id] = None
        self.stats["submits"] += 1
        req = Request(prompt=np.asarray(msg.prompt, np.int32),
                      n_new=msg.n_new, slo_ms=msg.slo_ms, seed=msg.seed,
                      temperature=msg.temperature,
                      arrival_ts=msg.arrival_ts or self.runtime.clock(),
                      id=msg.request_id)     # preserve the fleet-wide id
        if msg.trace_id:
            # the client is tracing: adopt its trace context so this
            # process's spans re-parent under the client dispatch span
            if self.tracer is None:
                self.tracer = Tracer(name=f"rpc:{self.name}")
                self.runtime.tracer = self.tracer
            req.trace_id = msg.trace_id
            req.parent_span = msg.parent_span
            self._trace_ids[msg.request_id] = msg.trace_id
        self.runtime.submit_request(req)

    def _on_Heartbeat(self, msg: Heartbeat) -> None:
        self._send(Heartbeat(seq=msg.seq, t=msg.t, pong=True,
                             stats=self._stats()))

    def _on_Calibrate(self, msg: Calibrate) -> None:
        bws = calibrate_codec_bws(shape=tuple(msg.shape), iters=msg.iters,
                                  warmup=msg.warmup, force=True)
        self.stats["calibrations"] += 1
        self._send(CalibrateResult(bws={k: float(v) for k, v in bws.items()},
                                   measured=True))

    def _on_Profile(self, msg: Profile) -> None:
        sweep = self.sweep
        if msg.bandwidths:
            sweep = SweepSpec(batches=sweep.batches, crs=sweep.crs,
                              bandwidths_mbps=tuple(msg.bandwidths),
                              P=sweep.P, warmup_runs=sweep.warmup_runs,
                              codecs=sweep.codecs)
        with codec_overrides(msg.codec_bws or {}):
            pm = self.session.profile(sweep, backend="simulated",
                                      hardware=self.hardware, link=self.link)
        self.stats["profiles"] += 1
        self._send(ProfileResult(perfmap=pm.to_doc()))

    def _on_Drain(self, msg: Drain) -> None:
        reqs = self.runtime.drain_requests()
        for r in reqs:
            self._seen.pop(r.id, None)     # re-routes elsewhere; forget it
            self._streamed.pop(r.id, None)
            self._shipped.pop(r.id, None)
            self._trace_ids.pop(r.id, None)
        self._send(DrainResult(request_ids=[r.id for r in reqs]))

    def _on_SetBandwidth(self, msg: SetBandwidth) -> None:
        self.session.observe_bandwidth(msg.mbps)

    def _on_Shutdown(self, msg: Shutdown) -> None:
        self._shutdown = True
        self._send(Heartbeat(pong=True, stats=self._stats()))

    def _stats(self) -> Dict:
        snap = self.runtime.stats_snapshot()
        snap.update(self.stats)
        snap["pid"] = os.getpid()
        return snap


# ---------------------------------------------------------------------------
# subprocess entrypoint
# ---------------------------------------------------------------------------

def build_session(arch: str, *, vocab: int = 64, seed: int = 0,
                  prism_l: int = 4, prism_cr: float = 9.9,
                  hw_scale: float = 1.0):
    """Deterministic session construction shared by every worker process:
    same (arch, vocab, seed) → identical parameters → token-exact re-serves
    across the fleet."""
    from repro.api import ExecutionPlan, InferenceSession
    from repro.fleet.registry import scaled_hardware
    from repro.profiling.hardware import JETSON_ORIN_NANO, WIFI_GLOO
    plans = [ExecutionPlan.local(),
             ExecutionPlan.prism_sim(L=prism_l, cr=prism_cr)]
    session = InferenceSession.from_config(
        arch, plans, reduced={"vocab_size": vocab}, seed=seed)
    hardware = scaled_hardware(JETSON_ORIN_NANO, hw_scale) \
        if hw_scale != 1.0 else JETSON_ORIN_NANO
    return session, hardware, WIFI_GLOO


def worker_main(argv=None) -> int:
    p = argparse.ArgumentParser(description="repro.rpc subprocess worker")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0)
    p.add_argument("--name", default="rpc-worker")
    p.add_argument("--arch", default="llama3.2-1b")
    p.add_argument("--vocab", type=int, default=64)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--n-slots", type=int, default=2)
    p.add_argument("--chunk", type=int, default=4)
    p.add_argument("--max-len", type=int, default=64)
    p.add_argument("--queue-size", type=int, default=64)
    p.add_argument("--hw-scale", type=float, default=1.0)
    p.add_argument("--prism-l", type=int, default=4)
    p.add_argument("--prism-cr", type=float, default=9.9)
    p.add_argument("--bandwidths", default="",
                   help="comma-separated Mb/s grid for the profile sweep")
    args = p.parse_args(argv)

    session, hardware, link = build_session(
        args.arch, vocab=args.vocab, seed=args.seed, prism_l=args.prism_l,
        prism_cr=args.prism_cr, hw_scale=args.hw_scale)
    sweep = SweepSpec()
    if args.bandwidths:
        sweep = SweepSpec(bandwidths_mbps=tuple(
            float(b) for b in args.bandwidths.split(",")))
    # profile up-front on *this* process so the first Profile reply is warm
    session.profile(sweep, backend="simulated", hardware=hardware, link=link)
    server = WorkerServer(session, name=args.name, arch=args.arch,
                          n_slots=args.n_slots, chunk=args.chunk,
                          max_len=args.max_len, queue_size=args.queue_size,
                          hardware=hardware, link=link, sweep=sweep)
    try:
        server.serve_forever(args.host, args.port)
    except KeyboardInterrupt:
        pass
    return 0


if __name__ == "__main__":
    sys.exit(worker_main())
