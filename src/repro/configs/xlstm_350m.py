"""xlstm-350m — sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM), no FFN (d_ff=0;
blocks carry their own up/down projections). [arXiv:2405.04517]

PRISM inapplicability: no softmax attention — sequence distribution uses
state hand-off (the (d_k×d_v) mLSTM memory is already N-independent), see
DESIGN.md §4.
"""
from repro.configs.base import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    norm_type="layernorm",
    tie_embeddings=True,
    ssm=SSMCfg(state_size=16, slstm_every=8, mlstm_heads=4,
               proj_factor=2.0, chunk=128),
    source="arXiv:2405.04517",
)
