"""whisper-large-v3 — encoder-decoder backbone; conv frontend is a STUB
(input_specs() supplies precomputed frame embeddings). [arXiv:2212.04356]

The assigned 32L is the decoder depth; whisper-large has a matching 32-layer
audio encoder over a fixed 1500-frame (30 s) mel window. Encoder self-attn is
bidirectional; decoder is causal with cross-attention to the encoder memory —
PRISM compresses the encoder-memory exchange (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    norm_type="layernorm",
    act="gelu",
    rope_theta=10000.0,      # NB: whisper uses learned/sinusoidal absolute
                             # positions; we keep RoPE for the backbone per
                             # the "backbone only" brief (DESIGN.md §4).
    tie_embeddings=True,
    encoder_layers=32,
    encoder_seq=1500,
    source="arXiv:2212.04356",
)
