"""ViT-B/16 — the paper's own workload (224×224×3 CIFAR-10 inputs, N=197
tokens incl. CLS). Bidirectional encoder; the PRISM/Voltage tables in
EXPERIMENTS.md §Paper-validation run on this config. [arXiv:2010.11929]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="vit-base-16",
    family="vit",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=10,            # classifier head classes (CIFAR-10)
    causal=False,
    norm_type="layernorm",
    act="gelu",
    rope_theta=0.0,           # learned absolute positions, no RoPE
    tie_embeddings=False,
    source="arXiv:2010.11929",
)

N_TOKENS = 197                # 14×14 patches + CLS
