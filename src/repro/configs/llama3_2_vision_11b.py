"""llama-3.2-vision-11b — llama3 decoder with cross-attention image layers
every 5th layer; the vision tower is a STUB (input_specs() supplies projected
patch embeddings). [hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    tie_embeddings=False,
    cross_attn_every=5,      # layers 4, 9, 14, ... attend to image tokens
    image_tokens=1601,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
