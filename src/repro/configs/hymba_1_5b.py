"""hymba-1.5b — hybrid blocks with PARALLEL attention + mamba heads fused by
learned mean; [arXiv:2411.13676; hf]. ssm_state=16."""
from repro.configs.base import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    rope_theta=10000.0,
    tie_embeddings=True,
    ssm=SSMCfg(state_size=16, conv_width=4, expand=2, chunk=128),
    source="arXiv:2411.13676",
)
