"""qwen1.5-32b — dense GQA decoder with QKV bias. [hf:Qwen/Qwen1.5-*; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    kv_quant=True,   # decode_32k cache = 5.5 TB bf16 globally; int8 halves it

    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="hf:Qwen/Qwen1.5-32B",
)
