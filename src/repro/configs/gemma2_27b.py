"""gemma2-27b — local/global alternating attention + logit softcaps.

[arXiv:2408.00118; hf] — sliding window 4096 on local layers, attn softcap
50.0, final softcap 30.0, post-norms, GeGLU, query scale 1/sqrt(d/ n_heads).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    local_global=True,
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    act="gelu_tanh",
    embed_scale=True,
    tie_embeddings=True,
    query_scale=(4608 / 32) ** -0.5,   # query_pre_attn_scalar = d_model/n_heads
    kv_quant=True,   # decode_32k cache 1.5 TB bf16 globally; int8 halves it
    rope_theta=10000.0,
    source="arXiv:2408.00118",
)
