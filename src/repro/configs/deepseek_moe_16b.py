"""deepseek-moe-16b — fine-grained MoE: 64 routed top-6 + 2 shared experts.
[arXiv:2401.06066; hf]"""
from repro.configs.base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,               # expert hidden dim
    vocab_size=102400,
    tie_embeddings=False,
    rope_theta=10000.0,
    moe=MoECfg(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
               first_dense_layers=1, d_ff_dense=10944),
    source="arXiv:2401.06066",
)
