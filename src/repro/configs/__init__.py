"""Architecture config registry: ``get_config("<arch-id>")``."""
from __future__ import annotations

import importlib

from repro.configs.base import (ALL_SHAPES, LONG_CONTEXT_ARCHS, SHAPES_BY_NAME,
                                MLACfg, ModelConfig, MoECfg, ShapeSpec, SSMCfg,
                                shapes_for)

_MODULES = {
    "qwen1.5-32b": "qwen1_5_32b",
    "llama3.2-1b": "llama3_2_1b",
    "internlm2-1.8b": "internlm2_1_8b",
    "gemma2-27b": "gemma2_27b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-large-v3": "whisper_large_v3",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "hymba-1.5b": "hymba_1_5b",
    "xlstm-350m": "xlstm_350m",
    "vit-base-16": "vit_base",
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "vit-base-16")


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


__all__ = ["get_config", "ASSIGNED_ARCHS", "ALL_SHAPES", "SHAPES_BY_NAME",
           "ModelConfig", "MoECfg", "MLACfg", "SSMCfg", "ShapeSpec",
           "shapes_for", "LONG_CONTEXT_ARCHS"]
