"""Unified architecture config covering all assigned families.

Every assigned architecture is one ``ModelConfig``; the model registry
(`repro.models.registry`) turns a config into init/apply functions. Shapes
(`ShapeSpec`) are the assigned (seq_len × global_batch) input grids.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int                # routed experts
    top_k: int
    n_shared: int = 0             # always-on shared experts
    d_ff_expert: int = 0          # expert hidden dim
    first_dense_layers: int = 1   # leading layers that use a dense MLP
    d_ff_dense: int = 0           # hidden dim of those dense MLPs
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    state_size: int = 16
    conv_width: int = 4
    expand: int = 2               # d_inner = expand * d_model (mamba)
    chunk: int = 128              # chunked-scan block length
    slstm_every: int = 8          # xLSTM: one sLSTM per this many blocks
    mlstm_heads: int = 4
    proj_factor: float = 2.0      # xLSTM up-projection factor


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | audio | vlm | hybrid | ssm | vit
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 → d_model // n_heads
    # attention behaviour
    qkv_bias: bool = False
    causal: bool = True
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    window: Optional[int] = None            # sliding window (local layers)
    local_global: bool = False              # gemma2 alternation local,global,...
    rope_theta: float = 10000.0
    query_scale: Optional[float] = None     # override 1/sqrt(head_dim)
    # block structure
    norm_type: str = "rmsnorm"
    post_norms: bool = False                # gemma2 extra post-block norms
    act: str = "silu"
    tie_embeddings: bool = True
    embed_scale: bool = False               # multiply embeddings by sqrt(d)
    # family extensions
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    # enc-dec (whisper): decoder uses fields above; encoder below
    encoder_layers: int = 0
    encoder_seq: int = 1500                 # fixed 30 s mel window (stub frontend)
    # vlm: 1-in-k layers are cross-attention to image tokens
    cross_attn_every: int = 0
    image_tokens: int = 1601                # llama3.2-vision: 1 tile × (40² + 1)
    image_embed_dim: int = 0                # 0 → d_model (stub projects already)
    # serving
    kv_quant: bool = False        # int8 KV cache (per-token/head scales)
    # dtypes
    dtype: str = "bfloat16"
    # notes for DESIGN/docs
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def reduced(self, **over) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        mha = self.n_kv_heads == self.n_heads
        base = dict(
            n_layers=min(self.n_layers, 2 if not self.local_global else 2),
            d_model=64, n_heads=4, n_kv_heads=4 if mha else 2,
            head_dim=16, d_ff=128, vocab_size=512,
        )
        if self.local_global:
            base["window"] = 16
        if self.moe:
            base["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, n_shared=min(self.moe.n_shared, 1),
                d_ff_expert=32, d_ff_dense=128, first_dense_layers=1)
        if self.mla:
            base["mla"] = MLACfg(kv_lora_rank=32, q_lora_rank=48,
                                 qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
        if self.ssm:
            base["ssm"] = dataclasses.replace(self.ssm, state_size=8, chunk=8,
                                              slstm_every=2, mlstm_heads=2)
        if self.encoder_layers:
            base["encoder_layers"] = 2
            base["encoder_seq"] = 16
        if self.cross_attn_every:
            base["cross_attn_every"] = 2
            base["image_tokens"] = 8
        base.update(over)
        return dataclasses.replace(self, **base)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}

# long_500k requires a sub-quadratic sequence path. PRISM's segment-means
# attention bounds remote context to (P-1)·L keys, but the paper's technique
# keeps the LOCAL partition dense — at N=524288, P=16 a 32k dense local block
# per device stays quadratic-in-shard. Per the brief we therefore run
# long_500k only for the state-space / hybrid archs (O(1) state decode) and
# skip it for the 8 pure-attention archs (noted in DESIGN.md §4).
LONG_CONTEXT_ARCHS = ("hymba-1.5b", "xlstm-350m")


def shapes_for(arch: str) -> Tuple[ShapeSpec, ...]:
    if arch in LONG_CONTEXT_ARCHS:
        return ALL_SHAPES
    return (TRAIN_4K, PREFILL_32K, DECODE_32K)
