"""deepseek-v2-236b — MLA attention + fine-grained MoE (160 routed top-6,
2 shared). [arXiv:2405.04434; hf]"""
from repro.configs.base import MLACfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # MLA: latent cache, kv heads = q heads post-expand
    head_dim=192,            # qk_nope (128) + qk_rope (64)
    d_ff=1536,               # expert hidden dim (assigned spec)
    vocab_size=102400,
    tie_embeddings=False,
    rope_theta=10000.0,
    mla=MLACfg(kv_lora_rank=512, q_lora_rank=1536,
               qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoECfg(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536,
               first_dense_layers=1, d_ff_dense=12288),
    source="arXiv:2405.04434",
)
