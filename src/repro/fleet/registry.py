"""Device registry: named workers with pinned hardware + liveness.

The ROADMAP's "millions of users" axis makes the *worker* the unit of
scale: one :class:`DeviceRegistry` tracks a fleet of named workers, each
pinned to its own :class:`~repro.profiling.hardware.HardwareProfile` /
:class:`~repro.profiling.hardware.LinkProfile` and carrying its own
compiled :class:`~repro.profiling.table.PolicyTable` — per-device
capability differences dominate once more than one request shares a board
(PRISM, arXiv 2507.12145; the Jetson concurrent-workload profiling study),
so placement must query per-worker tables, not a fleet-wide average.

Two worker flavors share one interface (:class:`Worker`):

* :class:`WorkerHandle` — a *real* worker: an
  :class:`~repro.api.session.InferenceSession` + its
  :class:`~repro.serving.engine.ServingRuntime` (bounded EDF queue →
  adaptive scheduler → slot-pool decode).  Used by the token-exactness
  tests and ``launch/fleet.py --real``.
* :class:`SimWorker` — a *virtual-time* worker: the same bounded EDF queue
  and the same compiled policy table, but service is modeled (one profiled
  inference pass per generated token) so a single host can benchmark a
  heterogeneous fleet without serializing real decode.

Liveness reuses the existing :class:`~repro.runtime.fault.HeartbeatMonitor`
(deadline-based; ``fail()`` wins over ``beat()``); ``check_dead()`` follows
the :class:`~repro.serving.scheduler.FaultHook` consume pattern — a worker
is reported dead exactly once, and the router drains + re-routes it then.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, List, Optional

from repro.chaos.schedule import DispatchFault
from repro.obs import MetricsRegistry, StatsDict, request_trace_id
from repro.profiling.hardware import (JETSON_ORIN_NANO, WIFI_GLOO,
                                      HardwareProfile, LinkProfile)
from repro.runtime.fault import HeartbeatMonitor, RetryPolicy
from repro.serving.queue import Request, RequestQueue


def scaled_hardware(base: HardwareProfile, factor: float,
                    name: Optional[str] = None) -> HardwareProfile:
    """A heterogeneous-fleet variant of ``base``: effective-FLOP/s curve
    scaled by ``factor`` (a 0.5 board computes at half speed; overheads and
    power are board-level constants and stay put)."""
    if factor <= 0:
        raise ValueError(f"factor must be > 0, got {factor}")
    return dataclasses.replace(
        base, name=name or f"{base.name}-x{factor:g}",
        eff_inf=base.eff_inf * factor, eff_slope=base.eff_slope * factor)


class Worker:
    """One fleet member: a name, a hardware/link pin, a bounded EDF queue,
    and a compiled policy table the router scores placements with.

    Subclasses implement the service loop (``step``/``next_event_at``) and
    the drain path; everything the :class:`~repro.fleet.router.FleetRouter`
    touches is on this base interface.
    """

    name: str
    hardware: HardwareProfile
    link: LinkProfile
    queue: RequestQueue
    n_slots: int
    codec_bws: Dict[str, float] = {}       # per-device codec calibration
    # provenance: True when codec_bws was measured on the worker's own
    # process (RpcWorker Calibrate); False for eff_inf-scaled host estimates
    codec_bws_measured: bool = False
    # wire-health flag: in-process workers are always healthy; an RpcWorker
    # flips this when its socket/process is gone so the router stops
    # beating it and the heartbeat-death drain path takes over
    healthy: bool = True

    # -- placement inputs ----------------------------------------------------

    @property
    def bandwidth(self) -> float:
        """Estimated link bandwidth (Mbps) fed to the policy table."""
        raise NotImplementedError

    def table(self, objective=None):
        """This worker's compiled PolicyTable (its hardware, its sweep)."""
        raise NotImplementedError

    @property
    def in_flight(self) -> int:
        raise NotImplementedError

    @property
    def pending(self) -> int:
        """Requests this worker still owes: queued + in flight."""
        return len(self.queue) + self.in_flight

    @property
    def idle(self) -> bool:
        return self.in_flight == 0

    # -- intake / service ----------------------------------------------------

    def submit_request(self, req: Request, force: bool = False) -> Request:
        raise NotImplementedError

    def step(self, now: Optional[float] = None) -> List:
        """Advance service; returns the completions this step produced."""
        raise NotImplementedError

    def next_event_at(self, now: float) -> float:
        """Virtual-time drivers: when this worker next has work to do
        (``inf`` = nothing queued or in flight)."""
        raise NotImplementedError

    # -- failure / telemetry -------------------------------------------------

    def drain_requests(self) -> List[Request]:
        """Give up every queued and in-flight request (dead-worker path)."""
        raise NotImplementedError

    def pop_faults(self) -> List[DispatchFault]:
        """Dispatch failures since the last call (consume pattern — the
        router feeds these to the per-worker circuit breaker)."""
        return []

    def reprofile(self, codec_bws: Optional[Dict[str, float]] = None) -> None:
        """Re-run this worker's profiling sweep (re-admission path); when
        ``codec_bws`` is given the sweep sees those per-device measured
        codec decode throughputs."""
        raise NotImplementedError

    def stats_snapshot(self) -> Dict:
        raise NotImplementedError

    @property
    def served_tokens(self) -> int:
        raise NotImplementedError

    def __repr__(self):
        return (f"{type(self).__name__}({self.name!r}, "
                f"hw={self.hardware.name!r}, pending={self.pending})")


class WorkerHandle(Worker):
    """A real worker: an ``InferenceSession`` + ``ServingRuntime`` pinned to
    one hardware/link profile.

    The session must already be profiled (``session.profile(...)``) —
    typically with ``hardware=``/``link=`` matching the pin, so the
    worker's policy table predicts *this* device.  The runtime's bounded
    EDF queue doubles as the router's per-worker admission queue.
    """

    def __init__(self, name: str, session, *,
                 hardware: HardwareProfile = JETSON_ORIN_NANO,
                 link: LinkProfile = WIFI_GLOO,
                 runtime=None, n_slots: int = 4, chunk: int = 8,
                 max_len: int = 256, queue_size: int = 64, sweep=None,
                 metrics: Optional[MetricsRegistry] = None, tracer=None):
        from repro.serving.engine import ServingRuntime
        self.name = name
        self.session = session
        self.hardware = hardware
        self.link = link
        self.sweep = sweep
        self.codec_bws: Dict[str, float] = {}
        self.profiled_count = 1 if session.perfmap is not None else 0
        self.runtime = runtime or ServingRuntime(
            session, n_slots=n_slots, chunk=chunk, max_len=max_len,
            queue_size=queue_size, metrics=metrics, tracer=tracer,
            worker=name)
        self.queue = self.runtime.queue
        self.n_slots = self.runtime.n_slots
        self.runtime.chaos_name = name
        if runtime is not None:
            self.runtime.trace_worker = self.runtime.trace_worker or name

    @property
    def tracer(self):
        return self.runtime.tracer

    @tracer.setter
    def tracer(self, tr) -> None:
        self.runtime.tracer = tr

    @property
    def metrics(self):
        return self.runtime.metrics

    @property
    def bandwidth(self) -> float:
        return self.session.bandwidth

    def observe_bandwidth(self, mbps: float) -> None:
        self.session.observe_bandwidth(mbps)

    def reprofile(self, codec_bws: Optional[Dict[str, float]] = None) -> None:
        """Re-sweep this worker's session at its own hardware/link pin,
        with its per-device codec calibration installed for the sweep.
        Simulated backend: re-admission must not monopolize the device."""
        from repro.transport.codecs import codec_overrides
        bws = codec_bws if codec_bws is not None else self.codec_bws
        if codec_bws is not None:
            self.codec_bws = dict(codec_bws)
        with codec_overrides(bws or {}):
            self.session.profile(self.sweep, backend="simulated",
                                 hardware=self.hardware, link=self.link)
        self.profiled_count += 1

    def table(self, objective=None):
        return self.session.policy.table(objective or self.session.objective)

    @property
    def in_flight(self) -> int:
        return sum(p.n_active for p in self.runtime.pools.values())

    def submit_request(self, req: Request, force: bool = False) -> Request:
        if req.total_len > self.runtime.max_len:
            raise ValueError(
                f"request needs {req.total_len} positions but worker "
                f"{self.name!r} pools are sized for {self.runtime.max_len}")
        return self.queue.put(req, force=force)

    def step(self, now: Optional[float] = None) -> List:
        return self.runtime.step()

    def next_event_at(self, now: float) -> float:
        return now if (self.queue or not self.runtime.idle) else float("inf")

    def drain_requests(self) -> List[Request]:
        return self.runtime.drain_requests()

    def stats_snapshot(self) -> Dict:
        return self.runtime.stats_snapshot()

    @property
    def completions(self) -> List:
        return self.runtime.completions

    @property
    def served_tokens(self) -> int:
        return sum(len(c.tokens) for c in self.runtime.completions)


@dataclasses.dataclass
class SimCompletion:
    """One virtually-served request (no token payload — service is modeled,
    the *timing* is the artifact)."""
    request_id: int
    n_tokens: int
    worker: str
    arrival_ts: float
    admitted_ts: float
    finished_ts: float
    plan_key: str = "local"
    slo_ms: Optional[float] = None

    @property
    def latency_ms(self) -> float:
        return 1e3 * (self.finished_ts - self.arrival_ts)

    @property
    def queue_ms(self) -> float:
        return 1e3 * (self.admitted_ts - self.arrival_ts)


class SimWorker(Worker):
    """A virtual-time worker: real compiled policy table, modeled service.

    Placement and batch formation go through exactly the same
    ``PolicyTable.plan_batch`` query a real worker uses — over a perf map
    profiled at *this worker's* hardware/link — but serving one micro-batch
    is modeled as ``expected.total_ms`` per generated token (one profiled
    inference pass per decode step) instead of running decode.  That keeps
    a single benchmark host able to drive 3+ heterogeneous workers in
    virtual time, where real decode would serialize them.
    """

    def __init__(self, name: str, perfmap=None, *,
                 hardware: HardwareProfile = JETSON_ORIN_NANO,
                 link: LinkProfile = WIFI_GLOO,
                 bandwidth_mbps: float = 400.0, n_slots: int = 4,
                 queue_size: int = 64, objective="latency",
                 allow_modes=("local", "prism"), sweep=None,
                 adaptive: bool = True, shed_expired: bool = False,
                 dispatch_timeout_s: Optional[float] = None,
                 retry: Optional[RetryPolicy] = None,
                 metrics: Optional[MetricsRegistry] = None, tracer=None):
        from repro.core.policy import AdaptivePolicy, resolve_objective
        self.name = name
        self.hardware = hardware
        self.link = link
        self.n_slots = n_slots
        self.queue = RequestQueue(queue_size, shed_expired=shed_expired)
        self._bandwidth = float(bandwidth_mbps)
        # static baseline: plan at the bandwidth seen at construction and
        # never look again (what a non-adaptive deployment would run)
        self._plan_bandwidth = float(bandwidth_mbps)
        self.adaptive = adaptive
        self.objective = resolve_objective(objective)
        self._allow_modes = tuple(allow_modes)
        self.sweep = sweep
        self.codec_bws: Dict[str, float] = {}
        self.profiled_count = 0
        if perfmap is None:
            perfmap = self._sweep_perfmap()
            self.profiled_count = 1
        self.perfmap = perfmap
        self.policy = AdaptivePolicy(perfmap, allow_modes=self._allow_modes)
        # fault-injection / response state
        self.chaos = None                     # set by ChaosController.attach
        self.retry = retry or RetryPolicy()
        self.dispatch_timeout_s = dispatch_timeout_s
        self._stall_until = 0.0
        self._fail_kind: Optional[str] = None  # in-service dispatch doomed?
        self._faults: List[DispatchFault] = []
        self._attempts: Dict[int, int] = {}    # request id → failed tries
        self._consec_failures = 0
        # virtual service state
        self._in_service: List[Request] = []
        self._service_start = 0.0
        self._busy_until = 0.0
        self._service_key = "local"
        self.completions: List[SimCompletion] = []
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer              # spans get virtual timestamps
        self.stats = StatsDict(
            self.metrics, "fleet.worker",
            {"steps": 0, "admitted": 0, "served": 0, "tokens": 0,
             "max_concurrent": 0, "busy_s": 0.0, "retries": 0,
             "timeouts": 0, "transport_errors": 0, "straggled": 0,
             "gave_up": 0},
            labels={"worker": name})

    def _sweep_perfmap(self):
        from repro.profiling import ProfileContext, SweepSpec, get_backend
        from repro.transport.codecs import codec_overrides
        with codec_overrides(self.codec_bws or {}):
            return get_backend("simulated").profile(
                ProfileContext(hardware=self.hardware, link=self.link),
                self.sweep or SweepSpec())

    def reprofile(self, codec_bws: Optional[Dict[str, float]] = None) -> None:
        """Rebuild the perf map / policy table (re-admission path), sweeping
        under this device's measured codec decode throughputs if given."""
        from repro.core.policy import AdaptivePolicy
        if codec_bws is not None:
            self.codec_bws = dict(codec_bws)
        self.perfmap = self._sweep_perfmap()
        self.policy = AdaptivePolicy(self.perfmap,
                                     allow_modes=self._allow_modes)
        self.profiled_count += 1

    @property
    def bandwidth(self) -> float:
        return self._bandwidth

    def observe_bandwidth(self, mbps: float) -> None:
        self._bandwidth = float(mbps)

    def table(self, objective=None):
        return self.policy.table(objective or self.objective)

    @property
    def in_flight(self) -> int:
        return len(self._in_service)

    def submit_request(self, req: Request, force: bool = False) -> Request:
        return self.queue.put(req, force=force)

    # -- virtual service loop ------------------------------------------------

    def step(self, now: Optional[float] = None) -> List[SimCompletion]:
        """Advance to virtual time ``now``: finish the in-service batch if
        its modeled service time has elapsed, then (if idle and not in a
        stall/backoff window) admit the next table-formed micro-batch."""
        if now is None:
            raise ValueError("SimWorker.step needs the virtual time `now`")
        self.stats["steps"] += 1
        done: List[SimCompletion] = []
        if self._in_service and now >= self._busy_until - 1e-12:
            fin = self._busy_until
            if self._fail_kind is not None:
                self._finish_failed(fin)
            else:
                for req in self._in_service:
                    done.append(SimCompletion(
                        request_id=req.id, n_tokens=req.n_new,
                        worker=self.name, arrival_ts=req.arrival_ts,
                        admitted_ts=self._service_start, finished_ts=fin,
                        plan_key=self._service_key, slo_ms=req.slo_ms))
                    self.stats["served"] += 1
                    self.stats["tokens"] += req.n_new
                    self._attempts.pop(req.id, None)
                    if self.tracer is not None:
                        self._trace_served(req, fin)
                self.completions.extend(done)
                self._in_service = []
                self._consec_failures = 0
        if (not self._in_service and self.queue
                and now >= self._stall_until - 1e-12):
            self._admit(now)
        return done

    def _admit(self, now: float) -> None:
        table = self.table()
        plan_bw = self._bandwidth if self.adaptive else self._plan_bandwidth
        bp = table.plan_batch(len(self.queue), plan_bw,
                              max_batch=self.n_slots)
        reqs = self.queue.pop_many(bp.n_admit, now=now)
        if not reqs:                       # everything queued had expired
            return
        self._in_service = reqs
        self._service_start = now
        self._service_key = bp.decision.exec_key
        # one profiled pass per generated token; wall time is charged even
        # under the energy objective (the clock is not an objective), so
        # total_ms — not objective.cost — is the model.  A static planner
        # still pays the TRUE link: its chosen plan is re-costed at the
        # live bandwidth.
        service_s = 1e-3 * self._charged_ms(table, bp) * max(
            r.n_new for r in reqs)
        self._fail_kind = None
        fault = (self.chaos.dispatch_fault(self.name, now)
                 if self.chaos is not None else None)
        if fault is not None and fault.kind == "straggle":
            service_s *= max(fault.value, 1.0)
            self.stats["straggled"] += 1
        elif fault is not None and fault.kind == "error":
            # transport error surfaces after `value` seconds of wire time
            self._fail_kind = "error"
            service_s = min(service_s, max(fault.value, 1e-6))
        if (self._fail_kind is None and self.dispatch_timeout_s is not None
                and service_s > self.dispatch_timeout_s):
            self._fail_kind = "timeout"
            service_s = self.dispatch_timeout_s
        self._busy_until = now + service_s
        self.stats["admitted"] += len(reqs)
        self.stats["busy_s"] += service_s
        self.stats["max_concurrent"] = max(self.stats["max_concurrent"],
                                           len(reqs))

    def _trace_served(self, req: Request, fin: float) -> None:
        """Record one served request's tree with *virtual* timestamps:
        root ``request`` (arrival → fin), ``queue_wait`` (arrival or last
        requeue → service start) and ``decode`` (modeled service).  Spans
        are recorded only at completion, so a killed worker contributes
        nothing and the re-serving worker owns the request's tree."""
        if not req.trace_id:
            req.trace_id = request_trace_id(req.id)
        root = self.tracer.record(
            "request", start=req.arrival_ts, end=fin, kind="fleet",
            trace_id=req.trace_id, parent_id=req.parent_span or None,
            worker=self.name, n_new=req.n_new)
        qw0 = getattr(req, "requeued_at", req.arrival_ts)
        self.tracer.record("queue_wait", start=qw0,
                           end=self._service_start, kind="fleet",
                           trace_id=req.trace_id, parent_id=root.span_id,
                           worker=self.name)
        self.tracer.record("decode", start=self._service_start, end=fin,
                           kind="fleet", trace_id=req.trace_id,
                           parent_id=root.span_id, worker=self.name,
                           plan=self._service_key, tokens=req.n_new,
                           modeled=True)

    def _charged_ms(self, table, bp) -> float:
        """Modeled per-token service: the planned decision's cost at the
        TRUE bandwidth (identical to ``expected.total_ms`` for an adaptive
        worker, which planned at the true bandwidth already)."""
        d = bp.decision
        if self.adaptive:
            return d.expected.total_ms
        for key, exp in table.candidates(bp.batch, self._bandwidth):
            if (key.mode, key.cr, key.codec) == (d.mode, d.cr, d.codec):
                return exp.total_ms
        return d.expected.total_ms

    def _finish_failed(self, fin: float) -> None:
        """The in-service dispatch failed (transport error / timeout):
        requeue within the retry budget, give up past it, and back off
        exponentially before the next local dispatch."""
        kind = self._fail_kind or "error"
        self.stats["timeouts" if kind == "timeout"
                   else "transport_errors"] += 1
        retried, gave_up = [], []
        for req in self._in_service:
            n = self._attempts.get(req.id, 0) + 1
            self._attempts[req.id] = n
            if n > self.retry.max_retries:
                gave_up.append(req)
                self._attempts.pop(req.id, None)
                self.stats["gave_up"] += 1
            else:
                self.queue.put(req, force=True)
                req.requeued_at = fin
                retried.append(req.id)
                self.stats["retries"] += 1
            if self.tracer is not None:
                if not req.trace_id:
                    req.trace_id = request_trace_id(req.id)
                self.tracer.record(
                    "retry", start=fin, end=fin, kind="fleet",
                    trace_id=req.trace_id,
                    parent_id=req.parent_span or None, worker=self.name,
                    reason=kind, attempt=n,
                    gave_up=n > self.retry.max_retries)
        self._in_service = []
        self._fail_kind = None
        self._consec_failures += 1
        self._stall_until = max(
            self._stall_until,
            fin + self.retry.backoff_s(self._consec_failures - 1))
        self._faults.append(DispatchFault(
            worker=self.name, kind=kind, t=fin,
            retried=tuple(retried), gave_up=tuple(gave_up)))

    def apply_stall(self, t: float, duration: float) -> None:
        """Scripted stall: no admissions until ``t + duration``; an
        in-service batch finishes late by the stall length."""
        self._stall_until = max(self._stall_until, t + duration)
        if self._in_service:
            self._busy_until += duration
            self.stats["busy_s"] += duration

    def next_event_at(self, now: float) -> float:
        if self._in_service:
            return self._busy_until
        if self.queue:
            return max(now, self._stall_until)
        return float("inf")

    # -- failure / telemetry -------------------------------------------------

    def drain_requests(self) -> List[Request]:
        reqs = self.queue.drain()
        reqs.extend(self._in_service)
        self._in_service = []
        self._busy_until = 0.0
        self._fail_kind = None
        return reqs

    def pop_faults(self) -> List[DispatchFault]:
        out, self._faults = self._faults, []
        return out

    def stats_snapshot(self) -> Dict:
        snap = dict(self.stats)
        snap["queue_depth"] = len(self.queue)
        snap["in_flight"] = len(self._in_service)
        snap["completed"] = len(self.completions)
        snap["rejected"] = self.queue.rejected
        snap["rejections"] = dict(self.queue.rejections)
        snap["expired"] = self.queue.rejections.get("expired", 0)
        snap["profiled_count"] = self.profiled_count
        return snap

    @property
    def served_tokens(self) -> int:
        return self.stats["tokens"]


class DeviceRegistry:
    """Named workers + heartbeat liveness (the fleet's source of truth).

    ``add()`` registers a worker and starts its heartbeat deadline;
    ``beat()``/``fail()`` feed the monitor (``fail`` wins — an explicitly
    failed worker's beats are ignored, which is what lets the router
    auto-beat workers it successfully steps).  ``check_dead()`` is the
    consume edge: each dead worker is reported exactly once, at which point
    the router drains and re-routes it.

    ``calibrate_codecs=True`` runs the measured decode-throughput
    micro-benchmark (:func:`~repro.transport.codecs.calibrate_codec_bws`)
    at registry construction — once, on this host — and every worker
    added afterwards gets a *per-device* copy scaled to its own
    :class:`HardwareProfile` (``eff_inf`` ratio vs ``host_hardware``): a
    board that computes at 0.35× the host reconstructs codec payloads at
    0.35× the host's measured throughput.  The worker is then re-profiled
    under its own calibration, so its policy table prices codecs the way
    *that device* would pay for them.  ``readmit()`` repeats the scale +
    re-profile on revival.
    """

    def __init__(self, *, heartbeat_timeout_s: float = 10.0,
                 clock: Callable[[], float] = time.monotonic,
                 calibrate_codecs: bool = False,
                 host_hardware: HardwareProfile = JETSON_ORIN_NANO,
                 metrics: Optional[MetricsRegistry] = None):
        self.monitor = HeartbeatMonitor([], timeout_s=heartbeat_timeout_s,
                                        clock=clock)
        self.workers: Dict[str, Worker] = {}
        self._dead: set = set()
        self.host_hardware = host_hardware
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.codec_bws: Dict[str, float] = {}
        if calibrate_codecs:
            from repro.transport.codecs import calibrate_codec_bws
            self.codec_bws = calibrate_codec_bws()
            for cname, bw in self.codec_bws.items():
                self.metrics.observe_bandwidth(
                    "codec.decode_bw_bytes_per_s", bw, "measured",
                    codec=cname, worker="host")

    # -- membership ----------------------------------------------------------

    def add(self, worker: Worker) -> Worker:
        if worker.name in self.workers:
            raise ValueError(f"worker {worker.name!r} already registered")
        self.workers[worker.name] = worker
        self.monitor.beat(worker.name)       # starts the liveness deadline
        if self.codec_bws or hasattr(worker, "measure_codec_bws"):
            self.calibrate_worker(worker)
        return worker

    def device_codec_bws(self, worker: Worker) -> Dict[str, float]:
        """Host-measured codec decode throughputs scaled to this worker's
        compute (``eff_inf`` ratio) — the per-device calibration *estimate*,
        used only for workers that cannot measure on their own process."""
        scale = worker.hardware.eff_inf / max(self.host_hardware.eff_inf,
                                              1e-9)
        return {name: bw * scale for name, bw in self.codec_bws.items()}

    def _codec_bws_for(self, worker: Worker):
        """(bws, measured) for this worker: measured on the worker's own
        process when it can (``measure_codec_bws`` — the RPC boundary), the
        eff_inf-scaled host estimate otherwise."""
        measure = getattr(worker, "measure_codec_bws", None)
        if measure is not None:
            try:
                bws = measure()
            except Exception:          # wire hiccup: fall back to estimate
                bws = None
            if bws:
                return dict(bws), True
        return self.device_codec_bws(worker), False

    def _gauge_codec_bws(self, worker: Worker, bws: Dict[str, float],
                         measured: bool) -> None:
        """Per-device codec throughputs land in one provenance-labelled
        gauge — ``measured`` when the worker benchmarked its own process
        (RPC boundary), ``estimated`` for eff_inf-scaled host numbers."""
        prov = "measured" if measured else "estimated"
        for cname, bw in bws.items():
            self.metrics.observe_bandwidth(
                "codec.decode_bw_bytes_per_s", bw, prov,
                codec=cname, worker=worker.name)

    def calibrate_worker(self, worker: Worker) -> Dict[str, float]:
        """Install the per-device codec calibration and re-profile the
        worker under it (no-op dict if neither the worker nor the host can
        supply numbers).  Records ``codec_bws_measured`` provenance."""
        bws, measured = self._codec_bws_for(worker)
        worker.codec_bws_measured = measured
        if bws:
            self._gauge_codec_bws(worker, bws, measured)
            worker.reprofile(codec_bws=bws)
        return bws

    def get(self, name: str) -> Worker:
        try:
            return self.workers[name]
        except KeyError:
            raise KeyError(f"unknown worker {name!r}; registered: "
                           f"{sorted(self.workers)}") from None

    def remove(self, name: str) -> None:
        self.workers.pop(name, None)
        self._dead.discard(name)
        self.monitor.remove(name)

    @property
    def names(self) -> List[str]:
        return sorted(self.workers)

    def __len__(self) -> int:
        return len(self.workers)

    def __iter__(self) -> Iterator[Worker]:
        return iter(self.workers.values())

    # -- liveness ------------------------------------------------------------

    def beat(self, name: str) -> None:
        self.monitor.beat(name)

    def fail(self, name: str) -> None:
        """Mark a worker dead (kill switch; heartbeat misses also kill)."""
        if name not in self.workers:
            raise KeyError(f"unknown worker {name!r}")
        self.monitor.fail(name)

    def revive(self, name: str) -> None:
        self._dead.discard(name)
        self.monitor.revive(name)

    def readmit(self, name: str, *, recalibrate: bool = True,
                reprofile: bool = True) -> Worker:
        """Full re-admission: revive → re-calibrate codecs for this device
        → re-profile → the worker is placeable again.  A revived board may
        come back throttled or on a different link, so its policy table
        must be rebuilt before placement trusts it (the router's
        :meth:`~repro.fleet.router.FleetRouter.readmit` also resets the
        worker's circuit breaker)."""
        worker = self.get(name)
        # a process-backed worker whose process died must come back up
        # before it can recalibrate/reprofile (RpcWorker.respawn)
        respawn = getattr(worker, "respawn", None)
        if respawn is not None and not getattr(worker, "healthy", True):
            respawn()
        self.revive(name)
        if recalibrate and (self.codec_bws
                            or hasattr(worker, "measure_codec_bws")):
            worker.codec_bws, worker.codec_bws_measured = \
                self._codec_bws_for(worker)
            if worker.codec_bws:
                self._gauge_codec_bws(worker, worker.codec_bws,
                                      worker.codec_bws_measured)
        if reprofile:
            worker.reprofile(codec_bws=worker.codec_bws or None)
        return worker

    def is_alive(self, name: str) -> bool:
        return (name in self.workers and name not in self._dead
                and name not in self.monitor.dead_nodes())

    def alive(self) -> List[Worker]:
        dead = set(self.monitor.dead_nodes()) | self._dead
        return [w for n, w in sorted(self.workers.items()) if n not in dead]

    def dead(self) -> List[str]:
        return sorted((set(self.monitor.dead_nodes()) | self._dead)
                      & set(self.workers))

    def check_dead(self) -> List[str]:
        """Newly-dead workers (consume pattern: each reported once — the
        caller owns draining + re-routing them)."""
        newly = [n for n in self.monitor.dead_nodes()
                 if n in self.workers and n not in self._dead]
        for n in newly:
            self.monitor.remove(n)
            self._dead.add(n)
        return newly
