"""Fleet router: policy-table-scored placement over a device registry.

The paper's profiling doctrine, one level up: the same compiled
:class:`~repro.profiling.table.PolicyTable` that picks an execution mode
*within* a session here picks the *worker* — for each live worker the
router asks its table what serving one more request would cost at that
worker's hardware and current bandwidth, inflates the answer by queue
pressure, and admits the request to the cheapest worker's bounded EDF
queue.  Every decision is recorded as a :class:`PlacementRecord` whose
``explain()`` prints the full scored ranking — placement is auditable, not
a heuristic.

Failure semantics (same shape as the in-session fault path, PR 4): a
heartbeat miss surfaces through ``registry.check_dead()`` exactly once;
the router drains the dead worker's queued *and* in-flight requests and
re-routes them (``force=True`` — admitted work is never shed by the
bound).  A re-served request restarts from scratch on the new worker and
is token-exact with ``session.generate`` because ``seed``/``temperature``
pin the sampling chain; EDF order is recovered by the target queue's
deadline-ordered ``pop``.

Backpressure: when a pinned worker's queue is full, or every live
worker's queue is full, ``route`` raises :class:`FleetRejected` with a
machine-readable ``reason`` — and the shed is counted in the router stats
and in the per-worker queue's ``rejections`` (satellite: rejection is
telemetry, not a silent exception).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import resolve_objective
from repro.fleet.registry import DeviceRegistry, Worker
from repro.obs import MetricsRegistry, StatsDict, request_trace_id
from repro.runtime.fault import CircuitBreaker, RetryPolicy
from repro.serving.queue import QueueFull, Request
from repro.serving.scheduler import FailoverEvent

# FleetRejected reasons a placement retry can cure (queue pressure and
# breaker windows pass; a pinned-dead worker does not)
RETRYABLE_REASONS = ("all_full", "no_workers", "breaker_open")


class FleetRejected(RuntimeError):
    """The fleet shed a request.  ``reason``: ``"all_full"`` (every live
    worker's queue at capacity), ``"full"`` (the pinned worker's queue at
    capacity), ``"dead_worker"`` (pinned to a worker that missed its
    heartbeat), ``"no_workers"`` (nothing alive to route to),
    ``"breaker_open"`` (the only candidates are breaker-blocked)."""

    def __init__(self, msg: str, reason: str):
        super().__init__(msg)
        self.reason = reason


@dataclasses.dataclass
class ReadmissionEvent:
    """One revive → re-calibrate → re-profile → re-enter-placement cycle."""
    worker: str
    at: float
    recalibrated: bool = False
    reprofiled: bool = True


@dataclasses.dataclass
class WorkerScore:
    """One worker's placement bid for one request.

    ``score = per_request_cost × (1 + pending / n_slots)``: the policy
    table's objective cost for serving one more request at this worker's
    hardware and bandwidth, inflated by how much work the worker already
    owes relative to its concurrency budget.  ``mode``/``cr``/``codec``
    are the execution decision the table would make there — the placement
    is explainable down to the profiled cell that priced it.
    """
    worker: str
    score: float
    per_request_cost: float        # table objective cost per request
    pending: int                   # queued + in flight at scoring time
    n_slots: int
    queue_depth: int
    bandwidth_mbps: float
    mode: str
    cr: float
    codec: str

    def explain(self) -> str:
        plan = self.mode + (f"@{self.cr:g}" if self.cr else "") \
            + (f"+{self.codec}" if self.codec else "")
        return (f"{self.worker}: score {self.score:.3f} = "
                f"{self.per_request_cost:.3f} (table: {plan} @ "
                f"{self.bandwidth_mbps:g} Mbps) x "
                f"(1 + {self.pending}/{self.n_slots} pending)")


@dataclasses.dataclass
class PlacementRecord:
    """One routing decision: the chosen worker and the full scored field."""
    request_id: int
    worker: str
    scores: List[WorkerScore]              # ranked, cheapest first
    reason: str = "scored"                 # "scored"|"pinned"|"rerouted"

    def explain(self) -> str:
        lines = [f"request {self.request_id} -> {self.worker} "
                 f"({self.reason})"]
        for s in self.scores:
            mark = "->" if s.worker == self.worker else "  "
            lines.append(f"  {mark} {s.explain()}")
        return "\n".join(lines)


class FleetRouter:
    """Front door of the fleet: score, admit, step, fail over.

    ``submit``/``route`` place single requests; ``fanout`` maps a batch of
    prompts across the fleet (map–reduce: ``run``/``drive_virtual`` reduce
    the per-worker completions back into one result set).  ``step`` drives
    real workers on the real clock (auto-beating each worker it
    successfully steps — an explicit ``registry.fail`` still wins, the
    monitor ignores beats from failed nodes); ``drive_virtual`` is the
    event-driven loop for :class:`~repro.fleet.registry.SimWorker` fleets.
    """

    def __init__(self, registry: DeviceRegistry, *, objective=None,
                 retry: Optional[RetryPolicy] = None,
                 breaker_threshold: int = 3, breaker_reset_s: float = 1.0,
                 clock: Callable[[], float] = time.monotonic,
                 metrics: Optional[MetricsRegistry] = None, tracer=None):
        self.registry = registry
        self.objective = (resolve_objective(objective)
                          if objective is not None else None)
        self.clock = clock
        # retry=None keeps the pre-chaos semantics: one placement attempt,
        # shed on rejection.  With a RetryPolicy, drive_virtual re-offers
        # rejected arrivals after backoff, within the budget.
        self.retry = retry
        self._breaker_cfg = (breaker_threshold, breaker_reset_s)
        self.breakers: Dict[str, CircuitBreaker] = {}
        self.placements: List[PlacementRecord] = []
        self.events: List = []               # Failover + Readmission events
        # observability: the router shares the registry's metrics registry
        # by default, so one dump covers router + workers + codec gauges
        self.metrics = (metrics if metrics is not None
                        else registry.metrics)
        self.tracer = tracer
        self._trace_roots: Dict[int, object] = {}   # request id → route span
        # virtual drivers stash their clock here so spans recorded inside
        # _check_faults get virtual, deterministic timestamps
        self._now_hint: Optional[float] = None
        self.stats = StatsDict(
            self.metrics, "fleet.router",
            {"routed": 0, "rejected": 0, "rerouted": 0,
             "lost": 0, "fanout": 0, "retries": 0,
             "timeouts": 0, "transport_errors": 0, "gave_up": 0,
             "placement_retries": 0, "breaker_opened": 0,
             "readmitted": 0,
             "rejections": {}})      # shed counts by reason

    def breaker(self, name: str) -> CircuitBreaker:
        """This worker's circuit breaker (created closed on first use)."""
        br = self.breakers.get(name)
        if br is None:
            thresh, reset = self._breaker_cfg
            br = self.breakers[name] = CircuitBreaker(
                fail_threshold=thresh, reset_timeout_s=reset)
        return br

    def attach_tracer(self, tracer) -> None:
        """One tracer for the whole fleet: router placement spans plus
        every registered worker's serving spans land in the same buffer
        (RPC workers additionally merge their subprocess's spans into
        it)."""
        self.tracer = tracer
        for w in self.registry:
            w.tracer = tracer

    # -- scoring -------------------------------------------------------------

    def score_worker(self, w: Worker) -> WorkerScore:
        pending = w.pending
        bp = w.table(self.objective).plan_batch(
            pending + 1, w.bandwidth, max_batch=w.n_slots)
        d = bp.decision
        score = bp.per_request_cost * (1.0 + pending / max(w.n_slots, 1))
        return WorkerScore(worker=w.name, score=score,
                           per_request_cost=bp.per_request_cost,
                           pending=pending, n_slots=w.n_slots,
                           queue_depth=len(w.queue),
                           bandwidth_mbps=w.bandwidth,
                           mode=d.mode, cr=d.cr, codec=d.codec)

    def rank(self, exclude: Sequence[str] = (),
             now: Optional[float] = None) -> List[WorkerScore]:
        """Live, breaker-admitted workers' bids, cheapest first."""
        now = self.clock() if now is None else now
        scores = [self.score_worker(w) for w in self.registry.alive()
                  if w.name not in exclude and self.breaker(w.name).allows(now)]
        return sorted(scores, key=lambda s: (s.score, s.worker))

    # -- admission -----------------------------------------------------------

    def submit(self, prompt, n_new: int, *, pin: Optional[str] = None,
               slo_ms: Optional[float] = None, seed: int = 0,
               temperature: float = 0.0,
               arrival_ts: Optional[float] = None
               ) -> Tuple[Request, PlacementRecord]:
        req = Request(prompt=np.asarray(prompt), n_new=n_new, slo_ms=slo_ms,
                      seed=seed, temperature=temperature,
                      **({} if arrival_ts is None
                         else {"arrival_ts": arrival_ts}))
        return req, self.route(req, pin=pin)

    def route(self, req: Request, *, pin: Optional[str] = None,
              force: bool = False, exclude: Sequence[str] = (),
              reason: str = "scored",
              now: Optional[float] = None) -> PlacementRecord:
        """Admit ``req`` to a worker queue; raises :class:`FleetRejected`
        (with the shed counted) when it cannot.

        ``pin`` bypasses scoring (caller-chosen worker — affinity, tests);
        ``force`` bypasses the queue bound (reserved for re-routing work
        the fleet already admitted); ``exclude`` removes workers from the
        candidate set (e.g. the one that just died).  A worker whose
        circuit breaker is open receives no placements until its reset
        window elapses (the next placement after that is the probe).
        """
        now = self.clock() if now is None else now
        if pin is not None:
            w = self.registry.get(pin)
            if not self.registry.is_alive(pin):
                w.queue.reject("dead_worker")
                return self._shed("dead_worker",
                                  f"worker {pin!r} is dead")
            if not self.breaker(pin).allows(now):
                w.queue.reject("breaker_open")
                return self._shed("breaker_open",
                                  f"worker {pin!r} breaker is open")
            scores = [self.score_worker(w)]
            try:
                w.submit_request(req, force=force)
            except QueueFull as e:
                return self._shed(e.reason,
                                  f"worker {pin!r} queue is full")
            rec = PlacementRecord(req.id, pin, scores, reason="pinned")
        else:
            ranked = self.rank(exclude, now=now)
            if not ranked:
                if any(w.name not in exclude for w in self.registry.alive()):
                    return self._shed("breaker_open",
                                      "every live worker is breaker-blocked")
                return self._shed("no_workers", "no live workers")
            placed = None
            for s in ranked:
                try:
                    self.registry.get(s.worker).submit_request(req,
                                                               force=force)
                    placed = s.worker
                    break
                except QueueFull:
                    continue       # that queue counted its own "full"
            if placed is None:
                return self._shed("all_full",
                                  "every live worker queue is at capacity")
            rec = PlacementRecord(req.id, placed, ranked, reason=reason)
        self.placements.append(rec)
        self.stats["routed"] += 1
        if self.tracer is not None:
            self._trace_route(req, rec, now)
        return rec

    def _trace_route(self, req: Request, rec: PlacementRecord,
                     now: float) -> None:
        """First placement opens the request's ``route`` root span and
        hands its id to the worker via ``req.parent_span`` — every
        downstream span (worker-side ``request`` tree, RPC dispatch, a
        subprocess's shipped spans) parents under it, so kill → retry →
        re-serve stays ONE tree.  Re-routes add a ``retry`` leaf."""
        tr = self.tracer
        if not req.trace_id:
            req.trace_id = request_trace_id(req.id)
        root = self._trace_roots.get(req.id)
        if root is None:
            root = tr.start("route", kind="fleet", trace_id=req.trace_id,
                            parent_id=req.parent_span or None,
                            at=req.arrival_ts, worker=rec.worker,
                            reason=rec.reason)
            self._trace_roots[req.id] = root
            req.parent_span = root.span_id
        else:
            req.parent_span = root.span_id
            tr.record("retry", start=now, end=now, kind="fleet",
                      trace_id=req.trace_id, parent_id=root.span_id,
                      worker=rec.worker, reason=rec.reason)
            req.requeued_at = now

    def _shed(self, reason: str, msg: str):
        self.stats["rejected"] += 1
        rej = self.stats["rejections"]
        rej[reason] = rej.get(reason, 0) + 1
        raise FleetRejected(msg, reason=reason)

    def fanout(self, prompts: Sequence, n_new, *, seeds=None,
               slo_ms: Optional[float] = None,
               temperature: float = 0.0
               ) -> List[Tuple[Request, Optional[PlacementRecord]]]:
        """Map a batch of prompts across the fleet (one routing decision
        each; a shed prompt yields ``(req, None)`` instead of aborting the
        batch).  Reduce with ``run()``/``completion_for()``."""
        out = []
        for i, p in enumerate(prompts):
            req = Request(prompt=np.asarray(p),
                          n_new=n_new[i] if not isinstance(n_new, int)
                          else n_new,
                          slo_ms=slo_ms,
                          seed=seeds[i] if seeds is not None else i,
                          temperature=temperature)
            try:
                out.append((req, self.route(req)))
            except FleetRejected:
                out.append((req, None))
        self.stats["fanout"] += 1
        return out

    # -- serving loops -------------------------------------------------------

    def step(self) -> List:
        """One fleet round on the real clock: fault check, then one
        ``ServingRuntime.step`` per live worker (auto-beat on success)."""
        self._check_faults()
        now = self.clock()
        done: List = []
        for w in self.registry.alive():
            done.extend(self._step_worker(w, now))
            # beat on the worker's word: an in-process worker is healthy by
            # construction; an RpcWorker flips `healthy` off when its
            # socket/process is gone, and an explicit fail() routes it into
            # the heartbeat-death drain path the next _check_faults
            if getattr(w, "healthy", True):
                self.registry.beat(w.name)
            else:
                self.registry.fail(w.name)
        return done

    def _step_worker(self, w: Worker, now: float) -> List:
        """Step one worker and feed its dispatch-fault stream into the
        breaker/telemetry (completions are breaker successes)."""
        done = w.step(now)
        for fault in w.pop_faults():
            self._on_fault(w, fault, now)
        if done:
            self.breaker(w.name).record_success(now)
            if self.tracer is not None:
                for c in done:
                    root = self._trace_roots.pop(c.request_id, None)
                    if root is not None:
                        self.tracer.finish(
                            root, at=getattr(c, "finished_ts", now))
        return done

    def _on_fault(self, w: Worker, fault, now: float) -> None:
        """One dispatch failure: count it, trip the breaker if it's the
        threshold-th in a row, and re-place work the worker gave up on."""
        self.stats["retries"] += len(fault.retried)
        self.stats["timeouts" if fault.kind == "timeout"
                   else "transport_errors"] += 1
        if self.breaker(w.name).record_failure(now):
            self.stats["breaker_opened"] += 1
        for req in fault.gave_up:
            self.stats["gave_up"] += 1
            try:
                self.route(req, force=True, exclude=(w.name,),
                           reason="rerouted", now=now)
                self.stats["rerouted"] += 1
            except FleetRejected:
                self.stats["lost"] += 1

    def run(self, max_steps: int = 100_000) -> List:
        """Step until every live worker is drained; returns the completions
        produced (fleet-wide, arbitrary worker interleaving)."""
        done: List = []
        steps = 0
        while True:
            # drain newly-dead workers *before* the exit check: a fleet
            # whose only survivors are idle must still re-route a dead
            # worker's orphans rather than exit and lose them
            self._check_faults()
            if not any(w.queue or not w.idle
                       for w in self.registry.alive()):
                break
            done.extend(self.step())
            steps += 1
            if steps >= max_steps:
                raise RuntimeError(f"run() exceeded {max_steps} steps")
        return done

    def drive_virtual(self, requests: Sequence[Request], *,
                      events: Sequence[Tuple[float, Callable]] = (),
                      max_iters: int = 1_000_000) -> Dict:
        """Event-driven virtual-time loop for ``SimWorker`` fleets.

        ``requests`` carry virtual ``arrival_ts`` (seconds); each is routed
        when the virtual clock reaches it, with the fleet's queue state *at
        that instant* — so placement reflects load, exactly like the real
        loop.  ``events`` are ``(t, fn)`` callbacks (e.g. a
        :meth:`ChaosController.events` schedule, or ``lambda:
        registry.fail("w2")`` to kill a worker mid-run).  When the router
        was built with a :class:`RetryPolicy`, a retryably-rejected arrival
        (queues full, breakers open, fleet momentarily empty) is re-offered
        after exponential backoff instead of shed outright.  Returns the
        drive summary: served completions, shed requests, and the virtual
        makespan.
        """
        pending = sorted(requests, key=lambda r: (r.arrival_ts, r.id))
        evs = sorted(events, key=lambda e: e[0])
        retry_q: List[Tuple[float, int, Request]] = []   # (due, seq, req)
        attempts: Dict[int, int] = {}
        seq = itertools.count()
        shed: List[Request] = []
        done: List = []
        now, iters = 0.0, 0

        def offer(req: Request) -> None:
            try:
                self.route(req, now=now)
            except FleetRejected as e:
                n = attempts.get(req.id, 0)
                if (self.retry is not None
                        and e.reason in RETRYABLE_REASONS
                        and n < self.retry.max_retries):
                    attempts[req.id] = n + 1
                    self.stats["placement_retries"] += 1
                    heapq.heappush(
                        retry_q,
                        (now + self.retry.backoff_s(n), next(seq), req))
                else:
                    shed.append(req)

        while True:
            iters += 1
            if iters > max_iters:
                raise RuntimeError(f"drive_virtual exceeded {max_iters} "
                                   "events")
            next_service = min(
                (w.next_event_at(now) for w in self.registry.alive()),
                default=float("inf"))
            next_arrival = pending[0].arrival_ts if pending else float("inf")
            next_retry = retry_q[0][0] if retry_q else float("inf")
            next_inject = evs[0][0] if evs else float("inf")
            t = min(next_service, next_arrival, next_retry, next_inject)
            if t == float("inf"):
                break
            now = max(now, t)
            self._now_hint = now      # virtual stamps for failover spans
            while evs and evs[0][0] <= now:
                evs.pop(0)[1]()
            self._check_faults()
            while pending and pending[0].arrival_ts <= now:
                offer(pending.pop(0))
            while retry_q and retry_q[0][0] <= now:
                offer(heapq.heappop(retry_q)[2])
            for w in self.registry.alive():
                done.extend(self._step_worker(w, now))
        self._now_hint = None
        shed.extend(req for _, _, req in sorted(retry_q))
        return {"completions": done, "shed": shed, "makespan_s": now,
                "served_tokens": sum(c.n_tokens for c in done)}

    def drive_real(self, requests: Sequence[Request], *,
                   events: Sequence[Tuple[float, Callable]] = (),
                   timeout_s: float = 600.0, poll_s: float = 0.002) -> Dict:
        """Real-clock analog of :meth:`drive_virtual` for process-backed
        fleets (``RpcWorker``/``WorkerHandle``).

        ``requests`` carry *relative* ``arrival_ts`` offsets (seconds from
        drive start); each is rebased to the wall clock and routed when its
        offset elapses.  ``events`` are ``(offset_s, fn)`` callbacks — a
        :meth:`ChaosController.events` schedule realizes kills as actual
        ``SIGKILL`` and errors as actual socket sabotage here.  Rejected
        retryable arrivals re-offer after the router's ``RetryPolicy``
        backoff.  Returns the same summary shape as ``drive_virtual``
        (``served_tokens`` counts real token payloads).
        """
        t0 = self.clock()
        pending = sorted(requests, key=lambda r: (r.arrival_ts, r.id))
        evs = sorted(events, key=lambda e: e[0])
        retry_q: List[Tuple[float, int, Request]] = []   # (due, seq, req)
        attempts: Dict[int, int] = {}
        seq = itertools.count()
        shed: List[Request] = []
        done: List = []

        def offer(req: Request, now: float) -> None:
            try:
                self.route(req)
            except FleetRejected as e:
                n = attempts.get(req.id, 0)
                if (self.retry is not None
                        and e.reason in RETRYABLE_REASONS
                        and n < self.retry.max_retries):
                    attempts[req.id] = n + 1
                    self.stats["placement_retries"] += 1
                    heapq.heappush(
                        retry_q,
                        (now + self.retry.backoff_s(n), next(seq), req))
                else:
                    shed.append(req)

        while True:
            now = self.clock() - t0
            if now > timeout_s:
                raise RuntimeError(f"drive_real exceeded {timeout_s}s with "
                                   f"{len(pending)} arrivals pending")
            while evs and evs[0][0] <= now:
                evs.pop(0)[1]()
            while pending and pending[0].arrival_ts <= now:
                req = pending.pop(0)
                req.arrival_ts = self.clock()    # rebase to the wall clock
                offer(req, now)
            while retry_q and retry_q[0][0] <= now:
                offer(heapq.heappop(retry_q)[2], now)
            self._check_faults()
            done.extend(self.step())
            busy = any(w.queue or not w.idle
                       for w in self.registry.alive())
            if not pending and not evs and not retry_q and not busy \
                    and not self.registry.monitor.dead_nodes():
                break
            if not busy:
                time.sleep(poll_s)
        shed.extend(req for _, _, req in sorted(retry_q))
        return {"completions": done, "shed": shed,
                "makespan_s": self.clock() - t0,
                "served_tokens": sum(len(c.tokens) for c in done)}

    # -- failure semantics ---------------------------------------------------

    def _check_faults(self) -> List[str]:
        """Consume newly-dead workers: drain their queued + in-flight
        requests and re-route each to a surviving worker (``force=True`` —
        admitted work is never shed by the bound), tightest deadline
        first.  A request with nowhere to go is lost and counted."""
        newly = self.registry.check_dead()
        if not newly:
            return []
        now = self._now_hint if self._now_hint is not None else self.clock()
        orphans: List[Request] = []
        for name in newly:
            orphans.extend(self.registry.get(name).drain_requests())
        rerouted = 0
        for req in sorted(orphans, key=lambda r: (r.deadline(),
                                                  r.arrival_ts)):
            try:
                self.route(req, force=True, exclude=newly,
                           reason="rerouted", now=now)
                rerouted += 1
            except FleetRejected:
                self.stats["lost"] += 1
        self.stats["rerouted"] += rerouted
        self.events.append(FailoverEvent(
            dead=list(newly), survivors=len(self.registry.alive()),
            requeued=rerouted))
        if self.tracer is not None:
            self.tracer.record("failover", start=now, end=now, kind="fleet",
                               trace_id="runtime:router",
                               dead=",".join(sorted(newly)),
                               requeued=rerouted)
        return newly

    def readmit(self, name: str, *, now: Optional[float] = None) -> Worker:
        """Re-admit a revived worker: registry-level revive + re-calibrate
        + re-profile (:meth:`DeviceRegistry.readmit`), then reset its
        circuit breaker so placement trusts it again immediately."""
        now = self.clock() if now is None else now
        worker = self.registry.readmit(name)
        self.breaker(name).reset()
        self.stats["readmitted"] += 1
        self.events.append(ReadmissionEvent(
            worker=name, at=now, recalibrated=bool(worker.codec_bws)))
        return worker

    # -- reduce / telemetry --------------------------------------------------

    def completions(self) -> Dict[str, List]:
        """Per-worker completion lists (dead workers keep what they
        finished before dying)."""
        return {w.name: list(w.completions) for w in self.registry
                if hasattr(w, "completions")}

    def completion_for(self, request_id: int):
        """The completion that served ``request_id``, wherever it ran
        (None if still pending or shed)."""
        for comps in self.completions().values():
            for c in comps:
                if c.request_id == request_id:
                    return c
        return None

    def placement_for(self, request_id: int) -> List[PlacementRecord]:
        """Every routing decision made for ``request_id`` (>1 after a
        failover re-route)."""
        return [p for p in self.placements if p.request_id == request_id]

    def stats_snapshot(self) -> Dict:
        """Router counters + per-worker runtime snapshots, one consistent
        copy."""
        snap = dict(self.stats)
        snap["rejections"] = dict(self.stats["rejections"])
        snap["alive"] = [w.name for w in self.registry.alive()]
        snap["dead"] = self.registry.dead()
        snap["failovers"] = sum(isinstance(e, FailoverEvent)
                                for e in self.events)
        snap["readmissions"] = sum(isinstance(e, ReadmissionEvent)
                                   for e in self.events)
        snap["breakers"] = {name: br.snapshot()
                            for name, br in self.breakers.items()}
        snap["workers"] = {w.name: w.stats_snapshot()
                           for w in self.registry}
        return snap
