"""`repro.fleet` — policy-placed multi-worker serving over a device registry.

The unit of scale becomes the *worker*: a :class:`DeviceRegistry` of named
workers (real :class:`WorkerHandle` = session + serving runtime, or
virtual-time :class:`SimWorker` for fleet-scale benchmarking), each pinned
to its own hardware/link profile with its own compiled policy table, and a
:class:`FleetRouter` front door that scores placements with those tables,
admits into per-worker bounded EDF queues with explicit backpressure
(:class:`FleetRejected`), and re-routes a dead worker's in-flight requests
token-exactly on heartbeat miss.

    registry = DeviceRegistry()
    registry.add(SimWorker("fast", hardware=JETSON_ORIN_NANO))
    registry.add(SimWorker("slow",
                           hardware=scaled_hardware(JETSON_ORIN_NANO, 0.5)))
    router = FleetRouter(registry)
    req, rec = router.submit(prompt, n_new=16)
    print(rec.explain())                  # the full scored ranking

Robustness (PR 7): dispatch failures stream through ``Worker.pop_faults``
into a per-worker :class:`~repro.runtime.fault.CircuitBreaker`; a
:class:`~repro.runtime.fault.RetryPolicy` bounds local re-dispatch and
placement retries; ``FleetRouter.readmit`` runs the full revive →
re-calibrate → re-profile → re-place cycle.  Faults are injected — never
ad-hoc — through :mod:`repro.chaos`.
"""
from repro.fleet.registry import (DeviceRegistry, SimCompletion, SimWorker,
                                  Worker, WorkerHandle, scaled_hardware)
from repro.fleet.router import (FleetRejected, FleetRouter, PlacementRecord,
                                ReadmissionEvent, WorkerScore)

__all__ = [
    "DeviceRegistry", "Worker", "WorkerHandle", "SimWorker",
    "SimCompletion", "scaled_hardware",
    "FleetRouter", "FleetRejected", "PlacementRecord", "ReadmissionEvent",
    "WorkerScore",
]
