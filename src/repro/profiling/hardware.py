"""First-class hardware descriptions for the profiling subsystem.

The paper's profile-don't-estimate doctrine only works if a performance map
says *what it was profiled on*.  ``HardwareProfile`` (the compute device) and
``LinkProfile`` (the interconnect) carry exactly the constants the edge cost
model consumes, are serialized into the performance map (schema v2, see
``repro.core.perfmap``), and round-trip through ``to_dict``/``from_dict``
with strict validation so a corrupt map fails loudly instead of silently
profiling the wrong machine.

Presets:

* ``JETSON_ORIN_NANO`` + ``WIFI_GLOO`` — the paper's 2-board prototype
  (identical to the historic ``EdgeConstants`` defaults).
* ``TPU_V5E`` + ``TPU_ICI`` — a coarse roofline preset from the §Roofline
  constants (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI per link).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.costmodel import (TPU_HBM_BW, TPU_HBM_GB, TPU_ICI_BW,
                                  TPU_PEAK_FLOPS, EdgeConstants)

_STR_FIELDS = ("name", "description")


def _validated_kwargs(cls, d, kind: str) -> Dict:
    """Shared strict decoder for both profile dataclasses."""
    if not isinstance(d, dict):
        raise ValueError(f"{kind} must be a JSON object, got "
                         f"{type(d).__name__}")
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - names)
    if unknown:
        raise ValueError(f"{kind} has unknown fields {unknown}")
    if "name" not in d:
        raise ValueError(f"{kind} is missing the required 'name' field")
    for k, v in d.items():
        if k in _STR_FIELDS:
            if not isinstance(v, str):
                raise ValueError(f"{kind} field {k!r} must be a string, "
                                 f"got {v!r}")
        elif isinstance(v, bool) or not isinstance(v, (int, float)):
            raise ValueError(f"{kind} field {k!r} must be a number, "
                             f"got {v!r}")
    return d


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """One compute device: effective-FLOP/s curve, overheads, power draw.

    ``eff_inf``/``eff_slope`` parameterize the occupancy curve
    ``eff(B) = eff_inf - eff_slope/B`` the edge simulator uses; the memory
    fields (``mem_bw_bytes``/``mem_gb``) only matter for roofline-style
    presets and default to 0 (unknown).
    """
    name: str
    peak_flops: float = 1.28e12          # spec-sheet peak (documentation)
    eff_inf: float = 0.62e12             # saturated effective FLOP/s
    eff_slope: float = 0.19e12           # occupancy ramp
    launch_overhead_ms: float = 6.0      # per-inference fixed cost
    coord_overhead_ms: float = 30.0      # master-worker partition/assemble
    voltage_eff_penalty: float = 0.70    # staging copies pollute occupancy
    power_active_w: float = 5.8          # incremental board power, computing
    power_comm_w: float = 0.25           # incremental during staging/wire
    mem_bw_bytes: float = 0.0            # HBM/LPDDR bandwidth (roofline)
    mem_gb: float = 0.0
    description: str = ""

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d) -> "HardwareProfile":
        return HardwareProfile(
            **_validated_kwargs(HardwareProfile, d, "hardware profile"))


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """One interconnect: host-staging curve + wire RTT + sync overhead."""
    name: str
    staging_bw_base: float = 100e6       # pinned-copy floor, bytes/s
    staging_bw_extra: float = 410e6      # DMA amortization headroom
    staging_knee_bytes: float = 5e6
    staging_fixed_ms: float = 1.6        # per collective call
    wire_rtt_ms: float = 1.0             # per collective round
    sync_overhead_ms: float = 4.0        # barrier/straggler per block set
    description: str = ""

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d) -> "LinkProfile":
        return LinkProfile(**_validated_kwargs(LinkProfile, d,
                                               "link profile"))


def to_edge_constants(hw: HardwareProfile,
                      link: Optional[LinkProfile] = None) -> EdgeConstants:
    """Combine a device + link profile into the simulator's constant block."""
    link = link or WIFI_GLOO
    return EdgeConstants(
        eff_inf=hw.eff_inf, eff_slope=hw.eff_slope,
        launch_overhead_ms=hw.launch_overhead_ms,
        coord_overhead_ms=hw.coord_overhead_ms,
        voltage_eff_penalty=hw.voltage_eff_penalty,
        staging_bw_base=link.staging_bw_base,
        staging_bw_extra=link.staging_bw_extra,
        staging_knee_bytes=link.staging_knee_bytes,
        staging_fixed_ms=link.staging_fixed_ms,
        wire_rtt_ms=link.wire_rtt_ms,
        power_active_w=hw.power_active_w, power_comm_w=hw.power_comm_w,
        sync_overhead_ms=link.sync_overhead_ms)


# --- presets ---------------------------------------------------------------

JETSON_ORIN_NANO = HardwareProfile(
    name="jetson-orin-nano",
    description="Jetson Orin Nano 8 GB, 15 W mode (paper prototype; "
                "DESIGN.md §6 calibration)")

WIFI_GLOO = LinkProfile(
    name="wifi-gloo",
    description="GLOO over WiFi: GPU→CPU→GPU staging + 200-900 Mbps wire")

TPU_V5E = HardwareProfile(
    name="tpu-v5e",
    peak_flops=TPU_PEAK_FLOPS,
    # coarse roofline calibration: large-batch kernels reach ~55 % of peak,
    # small batches ramp like the edge curve scaled by the peak ratio
    eff_inf=0.55 * TPU_PEAK_FLOPS,
    eff_slope=0.15 * TPU_PEAK_FLOPS,
    launch_overhead_ms=0.05, coord_overhead_ms=0.5,
    voltage_eff_penalty=1.0,             # no host staging on ICI
    power_active_w=170.0, power_comm_w=40.0,
    mem_bw_bytes=TPU_HBM_BW, mem_gb=TPU_HBM_GB,
    description="TPU v5e roofline preset (197 TFLOP/s bf16, 819 GB/s HBM)")

TPU_ICI = LinkProfile(
    name="tpu-ici",
    staging_bw_base=TPU_ICI_BW, staging_bw_extra=0.0,
    staging_knee_bytes=1.0, staging_fixed_ms=0.005,
    wire_rtt_ms=0.001, sync_overhead_ms=0.05,
    description="2D-ring ICI, 50 GB/s per link; no host staging hop")

PRESET_HARDWARE = {p.name: p for p in (JETSON_ORIN_NANO, TPU_V5E)}
PRESET_LINKS = {p.name: p for p in (WIFI_GLOO, TPU_ICI)}
