"""Pluggable profiling backends (paper §3.3 made first-class).

A backend turns a :class:`ProfileContext` (what is deployed: config, params,
registered plan executables, hardware/link profiles) plus a
:class:`~repro.profiling.sweep.SweepSpec` (what to sweep) into a
:class:`~repro.core.perfmap.PerfMap` stamped with the hardware it describes.

Built-ins:

* ``simulated`` — the edge cost model; reproduces the paper's sweep
  instantly.  Defaults to the paper's ViT-base workload on the Jetson/WiFi
  preset (so the published crossovers reproduce), overridable with any
  ``HardwareProfile``/``LinkProfile``/``EdgeWorkload``.
* ``measured`` — times the **session's own registered plan executables** on
  this host (the seed's ``profile_measured`` hard-coded ``vit-base-16``),
  scales the compute curve to the target hardware profile, and composes it
  with the modeled staging/wire terms for each swept bandwidth.
* ``trace`` — replays a previously saved performance-map artifact
  (``path=``) or adopts an in-memory map (``perfmap=``) — the
  "profile once per fleet, ship the JSON" deployment story.

Register your own with ``@register_backend`` — anything with a ``name`` and
a ``profile(ctx, spec, **opts)`` returning a PerfMap plugs into
``InferenceSession.profile(backend=...)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple, Type

from repro.core.costmodel import EdgeCostModel, EdgeWorkload
from repro.core.perfmap import PerfEntry, PerfKey, PerfMap
from repro.profiling.hardware import (JETSON_ORIN_NANO, WIFI_GLOO,
                                      HardwareProfile, LinkProfile,
                                      to_edge_constants)
from repro.profiling.sweep import (SweepSpec, codec_entries,
                                   workload_from_config)


def _codec_row(model: EdgeCostModel, ctx: "ProfileContext", name: str,
               param: int, B: int, bw: float, P: int,
               link_kind: str) -> Tuple[Dict, Dict]:
    """One simulated (codec, batch, bandwidth) cell: per-device compute
    over the full reconstructed context + transport accounting from the
    codec × link pair (``repro.transport.exchange_cost``)."""
    from repro.core.costmodel import vit_flops_per_sample
    from repro.transport import exchange_cost
    w, c = model.w, model.c
    N = w.n_tokens
    Np = N // P + (N % P > 0)
    terms = exchange_cost(name, n_tokens=N, d_model=w.d_model,
                          bytes_per_el=w.bytes_per_el, batch=B, P=P,
                          n_layers=w.n_layers, bandwidth_mbps=bw,
                          profile=ctx.link, link=link_kind, param=param)
    # remote partitions are reconstructed per token, so attention runs over
    # the full context (vs PRISM's Np + (P-1)·L); decode is charged to the
    # compute stage of the receiving device
    flops = vit_flops_per_sample(w, Np, N)
    b_eff = B * Np / N
    compute_ms = (flops * B / c.eff(b_eff) * 1e3 + c.launch_overhead_ms
                  + c.coord_overhead_ms + terms["decode_ms"])
    row = model.pack(B, compute_ms, terms["staging_ms"], terms["comm_ms"],
                     boards=P)
    return row, terms


@dataclasses.dataclass
class ProfileContext:
    """Everything a backend may need about the deployed session.

    All fields optional: the simulated backend runs from an empty context;
    the measured backend requires ``cfg`` + ``execs`` (an
    ``InferenceSession`` provides them via ``session.profile_context()``).
    """
    cfg: Any = None
    params: Any = None
    plans: Dict[str, Any] = dataclasses.field(default_factory=dict)
    execs: Dict[str, Callable] = dataclasses.field(default_factory=dict)
    hardware: HardwareProfile = JETSON_ORIN_NANO
    link: LinkProfile = WIFI_GLOO
    workload: Optional[EdgeWorkload] = None   # analytic workload override
    cost_model: Optional[EdgeCostModel] = None  # full simulator override
    seq_len: int = 0                          # token-model profiling length

    def edge_model(self, workload: Optional[EdgeWorkload] = None
                   ) -> EdgeCostModel:
        if self.cost_model is not None:
            return self.cost_model
        w = workload or self.workload or EdgeWorkload()
        return EdgeCostModel(to_edge_constants(self.hardware, self.link), w)


class ProfileBackend:
    """Protocol: subclass, set ``name``, implement ``profile``."""

    name = ""

    def profile(self, ctx: ProfileContext, spec: SweepSpec = SweepSpec(),
                **opts) -> PerfMap:
        raise NotImplementedError


_REGISTRY: Dict[str, ProfileBackend] = {}


def register_backend(cls: Type[ProfileBackend]) -> Type[ProfileBackend]:
    """Class decorator: instantiate and register under ``cls.name``."""
    name = getattr(cls, "name", "")
    if not name:
        raise ValueError("profile backend must define a non-empty `name`")
    if name in _REGISTRY:
        raise ValueError(f"profile backend {name!r} already registered")
    _REGISTRY[name] = cls()
    return cls


def get_backend(name: str) -> ProfileBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown profile backend {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def list_backends():
    return sorted(_REGISTRY)


def _entry(r: Dict, meta: Optional[Dict] = None) -> PerfEntry:
    return PerfEntry(total_ms=r["total_ms"], per_sample_ms=r["per_sample_ms"],
                     per_sample_j=r["per_sample_j"],
                     compute_ms=r["compute_ms"], staging_ms=r["staging_ms"],
                     comm_ms=r["comm_ms"], meta=meta or {})


def _stamp(pm: PerfMap, ctx: ProfileContext,
           from_profiles: bool = True) -> PerfMap:
    """Embed provenance (schema v2) — only when the entries really came
    from the context's hardware/link profiles.  A caller-supplied
    ``EdgeCostModel`` has unknown provenance; stamping the preset names on
    its output would make the map lie about what it was profiled on."""
    if from_profiles:
        pm.hardware, pm.link = ctx.hardware, ctx.link
    return pm


# --------------------------------------------------------------------------
# simulated
# --------------------------------------------------------------------------

@register_backend
class SimulatedBackend(ProfileBackend):
    """Cost-model sweep — the paper's offline profiling pass, instant."""

    name = "simulated"

    def profile(self, ctx: Optional[ProfileContext] = None,
                spec: SweepSpec = SweepSpec(), *,
                model: Optional[EdgeCostModel] = None,
                link_kind: str = "staged") -> PerfMap:
        from repro.core.segment_means import cr_to_L
        from repro.transport import exchange_wire_bytes
        ctx = ctx or ProfileContext()
        custom_model = model is not None or ctx.cost_model is not None
        model = model or ctx.edge_model()
        pm = PerfMap()
        w = model.w
        N = w.n_tokens
        codecs = codec_entries(spec)
        for B in spec.batches:
            pm.put(PerfKey("local", B, 0.0, 0.0), _entry(model.local(B)))
            for bw in spec.bandwidths_mbps:
                rv = model.distributed(B, bw, spec.P, L=None)
                wb_v = exchange_wire_bytes(
                    "identity", n_tokens=N, d_model=w.d_model,
                    bytes_per_el=w.bytes_per_el, batch=B, P=spec.P,
                    n_layers=w.n_layers)
                pm.put(PerfKey("voltage", B, 0.0, bw),
                       _entry(rv, {"wire_bytes": wb_v}))
                for cr in spec.crs:
                    L = cr_to_L(N, spec.P, cr)
                    rp = model.distributed(B, bw, spec.P, L=L)
                    wb = exchange_wire_bytes(
                        "segment_means", n_tokens=N, d_model=w.d_model,
                        bytes_per_el=w.bytes_per_el, batch=B, P=spec.P,
                        n_layers=w.n_layers, L=L)
                    pm.put(PerfKey("prism", B, cr, bw),
                           _entry(rp, {"L": L, "wire_bytes": wb}))
                for name, param in codecs:
                    row, terms = _codec_row(model, ctx, name, param, B, bw,
                                            spec.P, link_kind)
                    pm.put(PerfKey("prism", B, round(terms["ratio"], 2),
                                   bw, name),
                           _entry(row, {"codec": name, "param": param,
                                        "wire_bytes": terms["wire_bytes"]}))
        return _stamp(pm, ctx, from_profiles=not custom_model)


# --------------------------------------------------------------------------
# measured
# --------------------------------------------------------------------------

@register_backend
class MeasuredBackend(ProfileBackend):
    """Times the session's registered plan executables on this host.

    The compute curve is **measured** per (plan × batch) and normalized so
    the anchor plan's first swept batch matches the hardware profile's
    prediction (host-shape-of-curve × target-absolute-level, as a real
    fleet would calibrate once); staging/wire are modeled from the link
    profile at each swept bandwidth.  Distributed plans charge each device
    ``1/P`` of the measured single-host compute plus the coordination
    overhead.
    """

    name = "measured"

    def profile(self, ctx: ProfileContext, spec: SweepSpec = SweepSpec(), *,
                iters: int = 3, warmup: int = 1) -> PerfMap:
        from repro.utils.timing import timeit_jax
        if ctx is None or ctx.cfg is None or not ctx.execs:
            raise ValueError(
                "measured backend profiles the session's own executables: "
                "build the context via InferenceSession.profile_context() "
                "(register plans first), or pass cfg= and execs=")
        workload = ctx.workload or workload_from_config(ctx.cfg, ctx.seq_len)
        model = ctx.edge_model(workload)
        pm = PerfMap()
        anchor = "local" if "local" in ctx.execs else next(iter(ctx.execs))
        arch = getattr(ctx.cfg, "name", "?")
        scale = None
        for B in spec.batches:
            inputs = _dummy_batch(ctx.cfg, B, workload.n_tokens)
            times = {key: timeit_jax(fn, inputs, iters=iters, warmup=warmup)
                     for key, fn in ctx.execs.items()}
            if scale is None:      # anchor: first swept batch of one plan
                scale = (model.local(B)["compute_ms"] / 1e3) / times[anchor]
            for key, t in times.items():
                plan = self._plan_for(ctx, key, workload.n_tokens)
                compute_ms = t * scale * 1e3
                meta = {"measured": True, "arch": arch}
                if not plan.distributed:
                    r = model.pack(B, compute_ms, 0.0, 0.0, boards=1)
                    pm.put(plan.to_perf_key(B), _entry(r, meta))
                    continue
                P = max(plan.seq_shards, 1)
                L = plan.L if plan.L > 0 else None
                per_dev_ms = compute_ms / P + model.c.coord_overhead_ms
                for bw in spec.bandwidths_mbps:
                    if plan.codec:     # non-default codec: transport terms
                        from repro.transport import exchange_cost
                        terms = exchange_cost(
                            plan.codec, n_tokens=workload.n_tokens,
                            d_model=workload.d_model,
                            bytes_per_el=workload.bytes_per_el, batch=B,
                            P=P, n_layers=workload.n_layers,
                            bandwidth_mbps=bw, profile=ctx.link,
                            link=plan.link or "staged", L=plan.L,
                            param=plan.codec_param)
                        r = model.pack(B, per_dev_ms + terms["decode_ms"],
                                       terms["staging_ms"],
                                       terms["comm_ms"], boards=P)
                        pm.put(plan.to_perf_key(B, bw),
                               _entry(r, dict(
                                   meta, codec=plan.codec,
                                   wire_bytes=terms["wire_bytes"])))
                        continue
                    rm = model.distributed(B, bw, P, L=L)
                    r = model.pack(B, per_dev_ms, rm["staging_ms"],
                                   rm["comm_ms"], boards=P)
                    pm.put(plan.to_perf_key(B, bw),
                           _entry(r, dict(meta, L=plan.L)))
        return _stamp(pm, ctx, from_profiles=ctx.cost_model is None)

    @staticmethod
    def _plan_for(ctx: ProfileContext, key: str, n_tokens: int):
        plan = ctx.plans.get(key)
        if plan is None:                        # hand-wired execs table
            from repro.api.plan import ExecutionPlan
            plan = ExecutionPlan.parse(key).resolve_L(n_tokens)
        return plan


def _dummy_batch(cfg, batch: int, seq_len: int) -> Dict[str, Any]:
    """Zero-filled inputs for the deployed config's family — tokens, images,
    audio frames, or image embeddings as the registry prescribes."""
    import jax.numpy as jnp
    from repro.configs.base import ShapeSpec
    from repro.models import registry
    shape = ShapeSpec("profiling", seq_len, batch, "prefill")
    return {k: jnp.zeros(s.shape, s.dtype)
            for k, s in registry.input_specs(cfg, shape).items()}


# --------------------------------------------------------------------------
# trace replay
# --------------------------------------------------------------------------

@register_backend
class TraceBackend(ProfileBackend):
    """Replay a saved performance-map artifact (no inference runs)."""

    name = "trace"

    def profile(self, ctx: Optional[ProfileContext] = None,
                spec: SweepSpec = SweepSpec(), *,
                path: Optional[str] = None,
                perfmap: Optional[PerfMap] = None) -> PerfMap:
        if perfmap is not None:
            return perfmap
        if path is None:
            raise ValueError("trace backend replays a recorded profile: "
                             "pass path=<saved perf-map JSON> or perfmap=")
        return PerfMap.load(path)
