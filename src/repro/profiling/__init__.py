"""`repro.profiling` — the pluggable profiling subsystem.

The paper's contribution is *profiling-driven* adaptation; this package makes
the profile→policy pipeline a first-class API surface:

* :class:`ProfileBackend` registry (``simulated`` / ``measured`` / ``trace``)
  — how a performance map gets filled.
* :class:`HardwareProfile` / :class:`LinkProfile` — what it was profiled on
  (serialized into the map, schema v2).
* :class:`Objective` hierarchy — what the policy optimizes (latency, energy,
  weighted tradeoff, SLO-constrained), with string back-compat.
* :class:`PolicyTable` — the compiled dense decision grid behind
  ``AdaptivePolicy``: O(1) ``decide()``, bandwidth interpolation,
  table-derived crossover artifacts.

``InferenceSession.profile(backend=...)`` and ``session.calibrate()`` are
the runtime entry points (see ``repro.api``).
"""
from repro.profiling.hardware import (JETSON_ORIN_NANO, PRESET_HARDWARE,
                                      PRESET_LINKS, TPU_ICI, TPU_V5E,
                                      WIFI_GLOO, HardwareProfile, LinkProfile,
                                      to_edge_constants)
from repro.profiling.objectives import (EnergyObjective, LatencyObjective,
                                        Objective, ObjectiveLike,
                                        SLOObjective, WeightedObjective,
                                        resolve_objective)
from repro.profiling.sweep import (PAPER_BATCHES, PAPER_BWS, PAPER_CRS,
                                   SweepSpec, sweep_cost,
                                   workload_from_config)
from repro.profiling.table import BatchPlan, Decision, PolicyTable
from repro.profiling.backends import (MeasuredBackend, ProfileBackend,
                                      ProfileContext, SimulatedBackend,
                                      TraceBackend, get_backend,
                                      list_backends, register_backend)

__all__ = [
    "ProfileBackend", "ProfileContext", "register_backend", "get_backend",
    "list_backends", "SimulatedBackend", "MeasuredBackend", "TraceBackend",
    "HardwareProfile", "LinkProfile", "to_edge_constants",
    "JETSON_ORIN_NANO", "WIFI_GLOO", "TPU_V5E", "TPU_ICI",
    "PRESET_HARDWARE", "PRESET_LINKS",
    "Objective", "ObjectiveLike", "LatencyObjective", "EnergyObjective",
    "WeightedObjective", "SLOObjective", "resolve_objective",
    "PolicyTable", "Decision", "BatchPlan",
    "SweepSpec", "sweep_cost", "workload_from_config",
    "PAPER_BATCHES", "PAPER_CRS", "PAPER_BWS",
]
