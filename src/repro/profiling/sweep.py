"""Sweep grids for the offline profiling pass (paper §3.3, Fig. 2).

``SweepSpec`` is shared by every backend; the ``PAPER_*`` grids reproduce
the paper's batch × compression × bandwidth sweep.  ``workload_from_config``
derives the analytic workload description (used for the modeled staging/wire
terms) from a deployed model config instead of the hard-coded ViT-base.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.costmodel import EdgeWorkload

PAPER_BATCHES = (1, 2, 4, 8, 16, 32)
PAPER_CRS = (3.3, 4.95, 9.9)
PAPER_BWS = (200, 300, 400, 500, 600, 700, 800, 900)

# token-model sequence length the measured backend profiles at when the
# session does not say otherwise (ViT's length is fixed by its patch grid)
DEFAULT_SEQ_LEN = 32
VIT_SEQ_LEN = 197


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    batches: Sequence[int] = PAPER_BATCHES
    crs: Sequence[float] = PAPER_CRS
    bandwidths_mbps: Sequence[float] = PAPER_BWS
    P: int = 2
    warmup_runs: int = 20          # T in the paper's cost estimate
    # extra exchange codecs to sweep alongside the segment-means CR grid:
    # each entry is a codec name ("int8") or a (name, param) pair
    # (("topk", 8)); "segment_means" itself is the `crs` axis above
    codecs: Sequence = ()


def codec_entries(spec: SweepSpec):
    """Normalized (name, param) pairs of the spec's extra codec axis
    (``segment_means`` is skipped — it is the classic ``crs`` grid)."""
    out = []
    for c in spec.codecs:
        name, param = c if isinstance(c, (tuple, list)) else (c, 0)
        if name == "segment_means":
            continue
        if param == 0:
            from repro.transport import get_codec
            param = get_codec(name).default_param
        out.append((name, int(param)))
    return out


def sweep_cost(spec: SweepSpec) -> int:
    """|B|·(|CR|+|codecs|)·|BW|·T inference passes (the paper's one-time
    profiling cost, extended by the codec axis)."""
    return (len(spec.batches)
            * (len(spec.crs) + len(codec_entries(spec)))
            * len(spec.bandwidths_mbps) * spec.warmup_runs)


def workload_from_config(cfg, seq_len: int = 0) -> EdgeWorkload:
    """Analytic per-sample workload of the *deployed* config — layer count,
    widths, and element size come from the model, not from ViT-base."""
    n_tokens = seq_len or (VIT_SEQ_LEN if cfg.family == "vit"
                           else DEFAULT_SEQ_LEN)
    return EdgeWorkload(n_layers=cfg.n_layers, d_model=cfg.d_model,
                        d_ff=cfg.d_ff, n_tokens=n_tokens,
                        bytes_per_el=cfg.jdtype.itemsize)
