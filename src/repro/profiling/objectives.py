"""Optimization objectives for the adaptive policy.

The seed encoded the objective as ``Literal["latency", "energy"]`` — enough
for the paper's two headline tables, but closed to the deployments PRISM-style
systems actually face (battery budgets, latency SLOs).  ``Objective`` is now a
tiny class hierarchy; every ``objective=`` parameter accepts either an
``Objective`` instance or the legacy strings (``"latency"``/``"energy"``),
and objectives compare equal to their string names so existing
``decision.objective == "energy"`` call sites keep working.

An objective maps a profiled :class:`~repro.core.perfmap.PerfEntry` to a
scalar cost; the policy table minimizes that cost per cell.
"""
from __future__ import annotations

from typing import Tuple, Union

# Candidates violating a hard constraint get pushed past every feasible cost
# but stay ordered among themselves (least-violating wins when nothing fits).
_INFEASIBLE = 1e12


class Objective:
    """Base: scalarize a PerfEntry; lower is better."""

    name = "objective"

    def cost(self, entry) -> float:
        raise NotImplementedError

    def feasible(self, entry) -> bool:
        """Whether the entry satisfies this objective's hard constraints."""
        return self.cost(entry) < _INFEASIBLE

    def _params(self) -> Tuple:
        return ()

    def cache_key(self) -> Tuple:
        return (type(self).__name__,) + self._params()

    # string back-compat: EnergyObjective() == "energy" etc.  Hashing by
    # name keeps dict/set lookups with string keys working too (equal
    # objects must hash equal; same-name objectives merely collide).
    def __eq__(self, other):
        if isinstance(other, str):
            return other == self.name
        return (type(other) is type(self)
                and other._params() == self._params())

    def __hash__(self):
        return hash(self.name)

    def __repr__(self):
        args = ", ".join(f"{v!r}" for v in self._params())
        return f"{type(self).__name__}({args})"


class LatencyObjective(Objective):
    """Minimize per-sample latency (the paper's default)."""
    name = "latency"

    def cost(self, entry) -> float:
        return entry.per_sample_ms


class EnergyObjective(Objective):
    """Minimize per-sample energy."""
    name = "energy"

    def cost(self, entry) -> float:
        return entry.per_sample_j


class WeightedObjective(Objective):
    """``latency_weight·ms/sample + energy_weight·J/sample`` — the weights
    absorb the unit conversion (e.g. J→ms-equivalents)."""
    name = "weighted"

    def __init__(self, latency_weight: float = 1.0,
                 energy_weight: float = 0.0):
        if latency_weight < 0 or energy_weight < 0:
            raise ValueError("objective weights must be non-negative")
        if latency_weight == 0 and energy_weight == 0:
            raise ValueError("at least one objective weight must be > 0")
        self.latency_weight = float(latency_weight)
        self.energy_weight = float(energy_weight)

    def cost(self, entry) -> float:
        return (self.latency_weight * entry.per_sample_ms
                + self.energy_weight * entry.per_sample_j)

    def _params(self) -> Tuple:
        return (self.latency_weight, self.energy_weight)


class SLOObjective(Objective):
    """Constrained objective: minimize ``base`` (default energy) subject to
    per-sample latency ≤ ``max_latency_ms``.  When no candidate meets the
    SLO the least-violating (fastest) one is chosen, and
    ``feasible(entry)`` reports False for it.
    """
    name = "slo"

    def __init__(self, max_latency_ms: float,
                 base: Union[str, Objective] = "energy"):
        if max_latency_ms <= 0:
            raise ValueError("max_latency_ms must be positive")
        self.max_latency_ms = float(max_latency_ms)
        self.base = resolve_objective(base)

    def cost(self, entry) -> float:
        if entry.per_sample_ms > self.max_latency_ms:
            return _INFEASIBLE + entry.per_sample_ms
        return self.base.cost(entry)

    def _params(self) -> Tuple:
        return (self.max_latency_ms, self.base.cache_key())

    def __repr__(self):
        return (f"SLOObjective(max_latency_ms={self.max_latency_ms:g}, "
                f"base={self.base!r})")


ObjectiveLike = Union[str, Objective]

_STRING_OBJECTIVES = {
    "latency": LatencyObjective,
    "energy": EnergyObjective,
}


def resolve_objective(obj: ObjectiveLike) -> Objective:
    """Accept an Objective instance or a legacy string spelling."""
    if isinstance(obj, Objective):
        return obj
    if isinstance(obj, str):
        try:
            return _STRING_OBJECTIVES[obj]()
        except KeyError:
            raise ValueError(
                f"unknown objective {obj!r}; string spellings are "
                f"{sorted(_STRING_OBJECTIVES)} — or pass an Objective "
                "instance (WeightedObjective, SLOObjective, ...)") from None
    raise TypeError(f"objective must be a string or Objective, "
                    f"got {type(obj).__name__}")
