"""Compiled dense policy table — O(1) runtime decisions.

The seed policy rescanned (and string-decoded) the whole performance map on
every ``decide()``.  ``PolicyTable.compile`` walks the map **once** and lays
the decisions out on a dense batch-grid × bandwidth-grid: each cell holds the
candidate set and the precomputed argmin under one objective.  A runtime
query then costs two bisections plus, between profiled bandwidths, a linear
interpolation over the (constant-size) candidate set — independent of the
map size.

Batches outside the profiled grid snap to the nearest profiled batch and the
resulting :class:`Decision` is flagged ``extrapolated`` (the seed snapped
silently — B=256 quietly became B=32).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.perfmap import PerfEntry, PerfKey, PerfMap
from repro.profiling.objectives import (Objective, ObjectiveLike,
                                        resolve_objective)

Candidate = Tuple[str, float, str]    # (mode, cr, codec)


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """One scheduler query: how to serve ``n_queued`` requests next.

    ``batch`` is the profiled grid point to form (pad with ``padded`` empty
    slots when the queue is shorter than the cheapest grid batch);
    ``n_admit`` requests actually ride it.  ``extrapolated`` mirrors
    :class:`Decision` — the queue depth fell outside the profiled grid.
    """
    batch: int                  # profiled grid batch to form
    n_admit: int                # requests admitted (≤ batch)
    padded: int                 # empty slots in the formed batch
    decision: "Decision"        # mode/CR chosen at that grid point
    per_request_cost: float     # objective cost per admitted request
    extrapolated: bool = False


@dataclasses.dataclass(frozen=True)
class Decision:
    mode: str                  # "local" | "prism" | "voltage"
    cr: float                  # 0.0 unless prism
    expected: PerfEntry
    objective: Objective
    extrapolated: bool = False  # batch outside the profiled grid, snapped
    codec: str = ""            # exchange codec ("" = the mode's default,
                               # i.e. segment_means for prism)

    @property
    def distributed(self) -> bool:
        return self.mode != "local"

    @property
    def exec_key(self) -> str:
        """Canonical executable id this decision routes to — the ONE home
        of the ``"local"`` / ``"mode@cr[+codec]"`` convention (matches
        ``ExecutionPlan.key``)."""
        base = self.mode if self.cr <= 0 else f"{self.mode}@{self.cr:g}"
        return f"{base}+{self.codec}" if self.codec else base

    @property
    def wire_bytes(self) -> int:
        """Profiled bytes-on-wire of the expected entry (0 if the sweep
        recorded none, e.g. a local decision)."""
        return int(self.expected.meta.get("wire_bytes", 0))


def _lerp_entry(a: PerfEntry, b: PerfEntry, t: float) -> PerfEntry:
    f = lambda x, y: x + (y - x) * t
    return PerfEntry(total_ms=f(a.total_ms, b.total_ms),
                     per_sample_ms=f(a.per_sample_ms, b.per_sample_ms),
                     per_sample_j=f(a.per_sample_j, b.per_sample_j),
                     compute_ms=f(a.compute_ms, b.compute_ms),
                     staging_ms=f(a.staging_ms, b.staging_ms),
                     comm_ms=f(a.comm_ms, b.comm_ms),
                     meta={**a.meta, "interpolated_bw": True})


class PolicyTable:
    """Dense (batch × bandwidth) decision grid for one objective."""

    def __init__(self, batches: Sequence[int], bandwidths: Sequence[float],
                 cells: List[List[Dict[Candidate, PerfEntry]]],
                 objective: Objective):
        self.batches: Tuple[int, ...] = tuple(batches)
        self.bandwidths: Tuple[float, ...] = tuple(bandwidths)
        self.objective = objective
        self._cells = cells
        # precomputed per-cell argmin: (mode, cr, entry)
        self._best = [[self._argmin(cell) for cell in row] for row in cells]

    # -- construction --------------------------------------------------------

    @classmethod
    def compile(cls, pm: PerfMap, allow_modes: Sequence[str],
                objective: ObjectiveLike = "latency") -> "PolicyTable":
        obj = resolve_objective(objective)
        allow = set(allow_modes)
        local: Dict[int, PerfEntry] = {}
        dist: Dict[Tuple[int, float], Dict[Candidate, PerfEntry]] = {}
        batches, bws = set(), set()
        for k, e in pm.entries():             # the ONLY full-map walk
            if k.mode not in allow:
                continue
            batches.add(k.batch)
            if k.mode == "local":
                local[k.batch] = e
            else:
                bws.add(k.bandwidth_mbps)
                dist.setdefault((k.batch, k.bandwidth_mbps),
                                {})[(k.mode, k.cr, k.codec)] = e
        if not batches:
            raise LookupError("empty performance map")
        batch_grid = sorted(batches)
        bw_grid = sorted(bws)
        cells: List[List[Dict[Candidate, PerfEntry]]] = []
        for b in batch_grid:
            row = []
            for w in (bw_grid or [0.0]):      # local-only map: one column
                cell: Dict[Candidate, PerfEntry] = {}
                if b in local:
                    cell[("local", 0.0, "")] = local[b]
                cell.update(dist.get((b, w), {}))
                row.append(cell)
            cells.append(row)
        return cls(batch_grid, bw_grid, cells, obj)

    def _argmin(self, cell: Dict[Candidate, PerfEntry]
                ) -> Optional[Tuple[str, float, str, PerfEntry]]:
        if not cell:
            return None
        (m, cr, cod), e = min(cell.items(),
                              key=lambda kv: (self.objective.cost(kv[1]),
                                              kv[0][0] != "local", kv[0][1],
                                              kv[0][2]))
        return (m, cr, cod, e)

    # -- grid lookups ---------------------------------------------------------

    def nearest_batch(self, batch: int) -> int:
        """Snap to the nearest profiled batch (ties toward the smaller)."""
        return min(self.batches, key=lambda b: (abs(b - batch), b))

    def nearest_bandwidth(self, bandwidth_mbps: float) -> Optional[float]:
        if not self.bandwidths:
            return None
        return min(self.bandwidths, key=lambda w: abs(w - bandwidth_mbps))

    def is_extrapolated(self, batch: int) -> bool:
        return batch < self.batches[0] or batch > self.batches[-1]

    # -- the O(1) query -------------------------------------------------------

    def decide(self, batch: int, bandwidth_mbps: float) -> Decision:
        bi = bisect.bisect_left(self.batches, self.nearest_batch(batch))
        extrap = self.is_extrapolated(batch)
        bws = self.bandwidths
        if not bws or bandwidth_mbps <= bws[0]:
            return self._from_cell(bi, 0, extrap)
        if bandwidth_mbps >= bws[-1]:
            return self._from_cell(bi, len(bws) - 1, extrap)
        j = bisect.bisect_left(bws, bandwidth_mbps)
        if bws[j] == bandwidth_mbps:          # exact grid hit
            return self._from_cell(bi, j, extrap)
        return self._interp(bi, j - 1, j, bandwidth_mbps, extrap)

    def _from_cell(self, bi: int, wi: int, extrapolated: bool) -> Decision:
        best = self._best[bi][wi]
        if best is None:
            raise LookupError(
                f"no profiled candidates at batch {self.batches[bi]}")
        m, cr, cod, e = best
        return Decision(mode=m, cr=cr, expected=e, objective=self.objective,
                        extrapolated=extrapolated, codec=cod)

    def _interp(self, bi: int, w0: int, w1: int, bw: float,
                extrapolated: bool) -> Decision:
        c0, c1 = self._cells[bi][w0], self._cells[bi][w1]
        t = ((bw - self.bandwidths[w0])
             / (self.bandwidths[w1] - self.bandwidths[w0]))
        shared = [c for c in c0 if c in c1]
        if not shared:
            return self._from_cell(bi, w0 if t < 0.5 else w1, extrapolated)
        best, best_cost = None, None
        for cand in shared:
            e = _lerp_entry(c0[cand], c1[cand], t)
            cost = (self.objective.cost(e), cand[0] != "local", cand[1],
                    cand[2])
            if best_cost is None or cost < best_cost:
                best, best_cost = (cand, e), cost
        (m, cr, cod), e = best
        return Decision(mode=m, cr=cr, expected=e, objective=self.objective,
                        extrapolated=extrapolated, codec=cod)

    def candidates(self, batch: int, bandwidth_mbps: float
                   ) -> List[Tuple[PerfKey, PerfEntry]]:
        """The candidate table ``decide()`` ranks at this operating point —
        interpolated between grid bandwidths exactly like ``decide()``, so
        an explanation never shows costs its decision did not compare."""
        b = self.nearest_batch(batch)
        bi = bisect.bisect_left(self.batches, b)
        bws = self.bandwidths
        if not bws or bandwidth_mbps <= bws[0]:
            cell, label = self._cells[bi][0], (bws[0] if bws else 0.0)
        elif bandwidth_mbps >= bws[-1]:
            cell, label = self._cells[bi][-1], bws[-1]
        else:
            j = bisect.bisect_left(bws, bandwidth_mbps)
            if bws[j] == bandwidth_mbps:
                cell, label = self._cells[bi][j], bws[j]
            else:
                c0, c1 = self._cells[bi][j - 1], self._cells[bi][j]
                t = (bandwidth_mbps - bws[j - 1]) / (bws[j] - bws[j - 1])
                cell = {c: _lerp_entry(c0[c], c1[c], t)
                        for c in c0 if c in c1}
                label = bandwidth_mbps
        return [(PerfKey(m, b, cr, 0.0 if m == "local" else label, cod), e)
                for (m, cr, cod), e in cell.items()]

    # -- batch formation (serving scheduler) ----------------------------------

    def plan_batch(self, n_queued: int, bandwidth_mbps: float,
                   max_batch: Optional[int] = None) -> BatchPlan:
        """Pick the profiled batch size (and its mode/CR decision) that
        minimizes this table's objective cost **per queued request**.

        Grid batches larger than the queue are still candidates — their
        padded slots are charged to the admitted requests
        (``cost·batch/n_admit``), so a nearly-full grid batch can win while
        a mostly-empty one cannot.  ``max_batch`` caps the candidate set
        (e.g. to the runtime's free slot count); queue depths outside the
        profiled grid mark the plan ``extrapolated``.
        """
        if n_queued <= 0:
            raise ValueError("plan_batch needs n_queued >= 1")
        if max_batch is not None and max_batch <= 0:
            raise ValueError("plan_batch needs max_batch >= 1 (or None)")
        cands = [b for b in self.batches
                 if max_batch is None or b <= max_batch]
        if not cands:
            # no grid batch fits under max_batch: form the smallest grid
            # point (executables exist only at grid shapes) but admit no
            # more than the caller's cap
            cands = [self.batches[0]]
        best: Optional[BatchPlan] = None
        for b in cands:
            d = self.decide(b, bandwidth_mbps)
            n_admit = min(b, n_queued,
                          max_batch if max_batch is not None else b)
            cost = self.objective.cost(d.expected) * b / n_admit
            if best is None or cost < best.per_request_cost:
                best = BatchPlan(batch=b, n_admit=n_admit,
                                 padded=b - n_admit, decision=d,
                                 per_request_cost=cost,
                                 extrapolated=self.is_extrapolated(n_queued))
        return best

    # -- table-derived crossover artifacts ------------------------------------

    def batch_crossover(self, bandwidth_mbps: float) -> Optional[int]:
        """Smallest profiled batch at which distributed wins (paper: 8)."""
        for b in self.batches:
            if self.decide(b, bandwidth_mbps).distributed:
                return b
        return None

    def bandwidth_crossover(self, batch: int) -> Optional[float]:
        """Smallest profiled bandwidth at which distributed wins at
        ``batch`` (paper: ≈340 Mbps at B=8)."""
        for w in self.bandwidths:
            if self.decide(batch, w).distributed:
                return w
        return None

    def artifacts(self) -> Dict:
        """Every crossover the table implies — the paper-reported artifacts
        derived in one pass, serializable for reports/benchmarks."""
        return {
            "objective": self.objective.name,
            "batch_crossover_by_bw": {w: self.batch_crossover(w)
                                      for w in self.bandwidths},
            "bandwidth_crossover_by_batch": {b: self.bandwidth_crossover(b)
                                             for b in self.batches},
        }

    def __len__(self) -> int:
        return len(self.batches) * max(len(self.bandwidths), 1)
