"""ChaosController — replays one :class:`FaultSchedule` against a fleet.

One controller is the single choke point through which every scripted
fault reaches the system, so the same schedule produces the same run in
tests, benchmarks, and ``launch/fleet.py --chaos``:

* **membership faults** (``kill`` / ``revive``) go through the
  :class:`~repro.fleet.registry.DeviceRegistry` (and the router's
  re-admission path, so a revived worker re-profiles and re-enters
  placement);
* **link faults** (``bandwidth`` / ``flap``) set the live bandwidth the
  worker's policy table queries — degradation flips plans toward
  local/compressed execution through the existing
  :class:`~repro.profiling.table.PolicyTable`, no special-case code;
* **dispatch faults** (``straggle`` / ``error``) are *armed* at their
  schedule time and consumed by the target worker's next dispatch
  (:meth:`dispatch_fault`), which is what exercises the retry/timeout/
  breaker machinery.

Every applied or consumed fault lands in ``controller.log`` — a plain
list of ``[t, kind, target, value]`` rows — and two runs of the same
seeded schedule must produce identical logs (asserted by
``benchmarks/scenarios.py``).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from repro.chaos.schedule import ChaosEvent, FaultSchedule


class ChaosController:
    """Bind a :class:`FaultSchedule` to a registry (+ optional router)."""

    def __init__(self, registry, schedule: FaultSchedule, *, router=None):
        self.registry = registry
        self.router = router
        self.schedule = schedule
        self.log: List[List] = []
        # armed per-dispatch faults, FIFO per worker
        self._armed: Dict[str, List[ChaosEvent]] = {}
        self._preflap: Dict[Tuple[str, float], float] = {}
        self.attach()

    # -- wiring ---------------------------------------------------------------

    def attach(self) -> None:
        """Point every chaos-capable worker at this controller (SimWorkers
        consume dispatch faults directly; WorkerHandles through their
        runtime's chaos hook)."""
        for w in self.registry:
            if hasattr(w, "chaos"):
                w.chaos = self
            elif hasattr(w, "runtime") and hasattr(w.runtime, "chaos"):
                w.runtime.chaos = self

    def events(self) -> List[Tuple[float, Callable]]:
        """``(t, fn)`` callbacks for ``FleetRouter.drive_virtual`` —
        flaps expand into a down event and a restore event."""
        out: List[Tuple[float, Callable]] = []
        for ev in self.schedule:
            if ev.kind == "flap":
                out.append((ev.t, lambda e=ev: self._flap_down(e)))
                out.append((ev.t + ev.duration,
                            lambda e=ev: self._flap_up(e)))
            else:
                out.append((ev.t, lambda e=ev: self.apply(e)))
        return sorted(out, key=lambda p: p[0])

    # -- applying scripted faults ---------------------------------------------

    def _log(self, t: float, kind: str, target: str, value: float) -> None:
        self.log.append([round(float(t), 9), kind, target,
                         round(float(value), 6)])

    def apply(self, ev: ChaosEvent) -> None:
        if ev.kind == "kill":
            # process-backed workers die for real: SIGKILL the subprocess
            # first so the wire goes down exactly like an actual crash,
            # then mark the membership change
            w = self.registry.workers.get(ev.target)
            killer = getattr(w, "kill_process", None)
            if killer is not None:
                killer()
                # the router stops stepping a dead member, so the client
                # would never discover the corpse on its own — record what
                # this controller just did, and readmission knows to respawn
                w.healthy = False
            if self.registry.is_alive(ev.target):
                self.registry.fail(ev.target)
            self._log(ev.t, "kill", ev.target, 0.0)
        elif ev.kind == "revive":
            if self.router is not None:
                self.router.readmit(ev.target, now=ev.t)
            else:
                self.registry.readmit(ev.target)
            self._log(ev.t, "revive", ev.target, 0.0)
        elif ev.kind == "bandwidth":
            self._set_bandwidth(ev.target, ev.value)
            self._log(ev.t, "bandwidth", ev.target, ev.value)
        elif ev.kind == "stall":
            w = self.registry.get(ev.target)
            w.apply_stall(ev.t, ev.duration)
            self._log(ev.t, "stall", ev.target, ev.duration)
        elif ev.kind in ("straggle", "error"):
            self._armed.setdefault(ev.target, []).append(ev)
            self._log(ev.t, f"arm_{ev.kind}", ev.target, ev.value)
        else:
            raise ValueError(f"controller cannot apply {ev.kind!r}")

    def _set_bandwidth(self, target: str, mbps: float) -> None:
        w = self.registry.get(target)
        if hasattr(w, "observe_bandwidth"):
            w.observe_bandwidth(mbps)
        elif hasattr(w, "session"):
            w.session.observe_bandwidth(mbps)
        else:
            raise TypeError(f"worker {target!r} exposes no bandwidth knob")

    def _flap_down(self, ev: ChaosEvent) -> None:
        w = self.registry.get(ev.target)
        self._preflap[(ev.target, ev.t)] = float(w.bandwidth)
        self._set_bandwidth(ev.target, ev.value)
        self._log(ev.t, "flap_down", ev.target, ev.value)

    def _flap_up(self, ev: ChaosEvent) -> None:
        restore = self._preflap.pop((ev.target, ev.t), None)
        if restore is None:                 # flap on an unknown pre-state
            return
        self._set_bandwidth(ev.target, restore)
        self._log(ev.t + ev.duration, "flap_up", ev.target, restore)

    # -- per-dispatch faults (consumed by workers) ----------------------------

    def dispatch_fault(self, worker: str,
                       now: float) -> Optional[ChaosEvent]:
        """The next armed dispatch fault for ``worker`` whose schedule time
        has passed, or None.  Each armed fault fires exactly once — a
        retried dispatch does not re-hit the same injection."""
        armed = self._armed.get(worker)
        if not armed or armed[0].t > now:
            return None
        ev = armed.pop(0)
        self._log(now, f"hit_{ev.kind}", worker, ev.value)
        return ev

    @property
    def pending_faults(self) -> int:
        return sum(len(v) for v in self._armed.values())
