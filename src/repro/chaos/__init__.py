"""`repro.chaos` — deterministic fault injection for the fleet tier.

The paper's claim is that profiling-driven *adaptation* makes edge
inference practical; this package is how the repo proves the adaptation
survives an unhealthy fleet.  A :class:`FaultSchedule` scripts bandwidth
drift, link flaps, worker death/stall/revive, and per-dispatch
stragglers/transport errors from an explicit seed; a
:class:`ChaosController` replays the schedule against a
:class:`~repro.fleet.registry.DeviceRegistry` /
:class:`~repro.fleet.router.FleetRouter` pair on the virtual clock — the
same schedule produces the same event log in tests, benchmarks, and
``python -m repro.launch.fleet --chaos <spec>``.

    schedule = (FaultSchedule.drift("edge-a", 0, 8, 600, 60, seed=7)
                .add(FaultSchedule.kill("edge-b", 2.0),
                     FaultSchedule.revive("edge-b", 5.0)))
    chaos = ChaosController(registry, schedule, router=router)
    out = router.drive_virtual(requests, events=chaos.events())
    chaos.log                      # [[t, kind, target, value], ...]

The *response* side — bounded retry with exponential backoff, per-dispatch
timeouts, a per-worker circuit breaker, and worker re-admission
(revive → re-calibrate → re-profile → re-enter placement) — lives in
``repro.fleet``; ``benchmarks/scenarios.py`` is the CI-gated proof.
"""
from repro.chaos.controller import ChaosController
from repro.chaos.schedule import (ChaosEvent, DispatchFault, FaultSchedule)

__all__ = ["ChaosController", "ChaosEvent", "DispatchFault",
           "FaultSchedule"]
