"""Deterministic fault schedules — the *script* of a chaos run.

A :class:`FaultSchedule` is a sorted list of :class:`ChaosEvent`s on the
virtual clock: link-bandwidth drift and flaps, worker death/stall/revive,
and per-dispatch stragglers/transport errors.  All randomness happens at
*build* time from an explicit seed (the drift walk uses
:class:`~repro.utils.bandwidth.BandwidthWalk`), so the same schedule —
replayed through a :class:`~repro.chaos.controller.ChaosController` — is
identical in tests, benchmarks, and ``launch/fleet.py --chaos <spec>``:
same seed, same event log.

Schedules compose (``a + b`` merges and re-sorts) and parse from a compact
spec string for the launcher::

    kill:edge-b@1.5; revive:edge-b@4; drift:edge-a@0:600->60:8;
    flap:edge-c@2:0.5; straggle:edge-b@1:4; error:edge-b@1; stall:edge-a@2:0.5
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.utils.bandwidth import BandwidthWalk

KINDS = ("bandwidth", "flap", "kill", "stall", "revive", "straggle",
         "error")


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scripted fault.

    ``t`` is virtual seconds; ``value`` is kind-specific (Mbps for
    ``bandwidth``, straggle factor for ``straggle``, modeled abort window
    in seconds for ``error``); ``duration`` applies to ``flap``/``stall``.
    """
    t: float
    kind: str
    target: str
    value: float = 0.0
    duration: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown chaos event kind {self.kind!r}; "
                             f"known: {KINDS}")
        if self.t < 0:
            raise ValueError(f"event time must be >= 0, got {self.t}")


@dataclasses.dataclass(frozen=True)
class DispatchFault:
    """One failed dispatch a worker reports to the router (the breaker /
    retry-telemetry feed).  ``retried`` are request ids the worker re-queued
    locally with backoff; ``gave_up`` are requests whose per-dispatch retry
    budget is exhausted — the router must re-place them elsewhere."""
    worker: str
    kind: str                       # "error" | "timeout"
    t: float
    retried: Tuple[int, ...] = ()
    gave_up: Tuple = ()             # Request objects, not ids


class FaultSchedule:
    """An ordered, seed-deterministic list of :class:`ChaosEvent`s."""

    def __init__(self, events: Iterable[ChaosEvent] = ()):
        self.events: List[ChaosEvent] = sorted(
            events, key=lambda e: (e.t, e.kind, e.target, e.value))

    # -- composition ---------------------------------------------------------

    def add(self, *events: ChaosEvent) -> "FaultSchedule":
        self.events = sorted(self.events + list(events),
                             key=lambda e: (e.t, e.kind, e.target, e.value))
        return self

    def __add__(self, other: "FaultSchedule") -> "FaultSchedule":
        return FaultSchedule(self.events + other.events)

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    # -- builders ------------------------------------------------------------

    @staticmethod
    def kill(target: str, t: float) -> ChaosEvent:
        return ChaosEvent(t, "kill", target)

    @staticmethod
    def revive(target: str, t: float) -> ChaosEvent:
        return ChaosEvent(t, "revive", target)

    @staticmethod
    def stall(target: str, t: float, duration: float) -> ChaosEvent:
        return ChaosEvent(t, "stall", target, duration=duration)

    @staticmethod
    def set_bandwidth(target: str, t: float, mbps: float) -> ChaosEvent:
        return ChaosEvent(t, "bandwidth", target, value=mbps)

    @staticmethod
    def flap(target: str, t: float, duration: float,
             floor_mbps: float = 1.0) -> ChaosEvent:
        """Link flap: bandwidth drops to ``floor_mbps`` at ``t`` and is
        restored (to its pre-flap value, captured at apply time) after
        ``duration`` seconds."""
        return ChaosEvent(t, "flap", target, value=floor_mbps,
                          duration=duration)

    @staticmethod
    def straggle(target: str, t: float, factor: float) -> ChaosEvent:
        """Arm ONE straggling dispatch: the target's next dispatch at or
        after ``t`` takes ``factor``× its modeled service time."""
        return ChaosEvent(t, "straggle", target, value=factor)

    @staticmethod
    def transport_error(target: str, t: float,
                        abort_s: float = 0.05) -> ChaosEvent:
        """Arm ONE failing dispatch: the target's next dispatch at or after
        ``t`` aborts with a :class:`~repro.transport.links.TransportError`
        after ``abort_s`` modeled seconds (its requests re-queue and
        retry with backoff)."""
        return ChaosEvent(t, "error", target, value=abort_s)

    @classmethod
    def drift(cls, target: str, t0: float, t1: float, from_mbps: float,
              to_mbps: float, *, steps: int = 16, seed: int = 0,
              jitter: float = 0.1) -> "FaultSchedule":
        """Seeded bandwidth drift: a :class:`BandwidthWalk` from
        ``from_mbps`` to ``to_mbps`` over [t0, t1], sampled at ``steps``
        evenly-spaced set-bandwidth events.  Same seed → same walk → same
        events."""
        if t1 <= t0:
            raise ValueError(f"drift needs t1 > t0, got [{t0}, {t1}]")
        walk = BandwidthWalk(from_mbps, to_mbps, seed=seed, jitter=jitter)
        dt = (t1 - t0) / max(steps, 1)
        evs = [cls.set_bandwidth(target, t0 + (i + 1) * dt,
                                 walk.at((i + 1) / max(steps, 1)))
               for i in range(steps)]
        return cls(evs)

    # -- the launcher spec string --------------------------------------------

    @classmethod
    def parse(cls, spec: str) -> "FaultSchedule":
        """Parse the compact ``--chaos`` spec (see module docstring).

        Each clause is ``kind:target@t[:args]``; clauses separated by
        ``;``.  ``drift`` takes ``from->to:duration``."""
        sched = cls()
        for clause in spec.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            try:
                kind, rest = clause.split(":", 1)
                target_t, *args = rest.split(":")
                target, t_s = target_t.split("@")
                t = float(t_s)
            except ValueError:
                raise ValueError(
                    f"bad chaos clause {clause!r} (want "
                    "kind:target@t[:args])") from None
            kind = kind.strip()
            if kind == "kill":
                sched.add(cls.kill(target, t))
            elif kind == "revive":
                sched.add(cls.revive(target, t))
            elif kind == "bw":
                sched.add(cls.set_bandwidth(target, t, float(args[0])))
            elif kind == "flap":
                sched.add(cls.flap(target, t, float(args[0]),
                                   *(float(a) for a in args[1:2])))
            elif kind == "stall":
                sched.add(cls.stall(target, t, float(args[0])))
            elif kind == "straggle":
                sched.add(cls.straggle(target, t, float(args[0])))
            elif kind == "error":
                sched.add(cls.transport_error(
                    target, t, *(float(a) for a in args[:1])))
            elif kind == "drift":
                span, dur = args[0], float(args[1]) if len(args) > 1 else 4.0
                lo, hi = span.split("->")
                sched += cls.drift(target, t, t + dur, float(lo), float(hi))
            else:
                raise ValueError(f"unknown chaos kind {kind!r} in "
                                 f"{clause!r}")
        return sched
