"""Serving launcher: policy-driven request traffic over `ServingRuntime`.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        [--mode prism|local|adaptive] [--requests 12] [--arrival-rate 50] \
        [--slo-ms 5000] [--slots 4] [--chunk 8] [--tokens 16] \
        [--bandwidth 400] [--objective latency|energy] \
        [--pages 64 --page-size 16]   # paged KV mode (prefix caching on)

The hand-rolled per-token decode loop is gone: requests flow through the
bounded queue → adaptive scheduler (micro-batches formed from the compiled
policy table at ``--bandwidth``/``--objective``) → continuous-batching
slot-pool decode (the compiled ``lax.scan`` fast path).  ``--mode local`` /
``--mode prism`` pin the executable family; ``--mode adaptive`` lets the
policy route.  Legacy flags (``--devices --batch --prompt-len --L``) keep
working: ``--batch`` sizes the slot pool and doubles as the default request
count.

NOTE: PRISM here runs in its single-host simulation form (``prism_sim`` —
same math, unpartitioned tensors); the serving slot pool is not
mesh-sharded yet.  Genuinely sequence-sharded decode over a device mesh is
exercised by ``scripts/sanity_e2e_distributed.py`` and ``launch/dryrun.py``.
"""
import argparse
import os

if __name__ == "__main__":
    _ap = argparse.ArgumentParser()
    _ap.add_argument("--devices", type=int, default=8)
    _args, _ = _ap.parse_known_args()
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={_args.devices} "
        "--xla_disable_hlo_passes=all-reduce-promotion")

import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--mode", default="prism",
                    choices=["prism", "local", "adaptive"])
    ap.add_argument("--devices", type=int, default=8)   # legacy (XLA flag)
    ap.add_argument("--batch", type=int, default=8,
                    help="slot-pool size (legacy: batch width)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--L", type=int, default=4)
    ap.add_argument("--codec", default="none",
                    choices=["none", "int8", "int4", "topk"],
                    help="register an extra compressed-exchange plan with "
                         "this repro.transport codec and add it to the "
                         "profiling sweep (the policy may then select it)")
    ap.add_argument("--bandwidth", type=float, default=400.0,
                    help="observed link bandwidth (Mbps) for the policy")
    ap.add_argument("--objective", default="latency",
                    choices=["latency", "energy"])
    ap.add_argument("--requests", type=int, default=0,
                    help="number of requests to simulate (default: --batch)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrival rate, req/s (0 = burst at t=0)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="per-request latency SLO (0 = best effort)")
    ap.add_argument("--slots", type=int, default=0,
                    help="slot-pool size (default: --batch); with --pages/"
                         "--page-size it aliases the page BUDGET instead "
                         "(slots x max_len positions worth of pages)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps per continuous-batching chunk")
    ap.add_argument("--pages", type=int, default=0,
                    help="paged KV mode: shared pool of this many pages "
                         "(admission bounded by free pages, prefix caching "
                         "on).  0 with --page-size set = --slots' budget")
    ap.add_argument("--page-size", type=int, default=0,
                    help="positions per KV page (paged mode; default 16 "
                         "when only --pages is given)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="paged mode: disable prompt prefix sharing")
    ap.add_argument("--cold-horizon", type=int, default=0,
                    help="paged mode: quantize prefix-cache pages idle for "
                         "this many admissions (LOSSY; 0 = never)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="write the request span trace as JSONL to PATH "
                         "and print a per-stage breakdown at exit")
    ap.add_argument("--metrics", action="store_true",
                    help="dump the unified metrics registry "
                         "(Prometheus text format) at exit")
    args = ap.parse_args()

    from repro.api import ExecutionPlan, InferenceSession
    from repro.serving import ServingRuntime

    tracer = None
    if args.trace or args.metrics:
        from repro.obs import Tracer
        tracer = Tracer(name="serve")

    allow = {"local": ("local",), "prism": ("prism",),
             "adaptive": None}[args.mode]
    plans = [ExecutionPlan.local(), ExecutionPlan.prism_sim(L=args.L, cr=9.9)]
    codecs = ()
    if args.codec != "none":
        from repro.transport import get_codec
        plans.append(ExecutionPlan("prism_sim", seq_axis="seq",
                                   seq_shards=2, codec=args.codec,
                                   codec_param=get_codec(
                                       args.codec).default_param))
        codecs = (args.codec,)
    session = InferenceSession.from_config(
        args.arch, reduced={"vocab_size": 512}, plans=plans,
        objective=args.objective, allow_modes=allow,
        initial_bandwidth_mbps=args.bandwidth)
    from repro.profiling import SweepSpec
    session.profile(SweepSpec(codecs=codecs),
                    backend="simulated")        # paper's offline sweep
    d = session.decide(args.batch)
    print(f"policy: B={args.batch} BW={args.bandwidth:g} Mbps "
          f"[{args.objective}] → {d.mode}"
          + (f" CR={d.cr:g}" if d.cr else "")
          + (f" codec={d.codec}" if d.codec else "")
          + f" ({d.expected.per_sample_ms:.1f} ms/sample expected"
          + (", EXTRAPOLATED batch" if d.extrapolated else "") + ")")

    n_req = args.requests or args.batch
    n_slots = args.slots or args.batch
    rng = np.random.RandomState(args.seed)
    # three prompt-length buckets, not a continuum: prime_slot compiles one
    # prefill per distinct (length, pool) shape, and mid-traffic compiles
    # would swamp the reported latencies
    buckets = sorted({max(args.prompt_len // 2, 1), args.prompt_len,
                      args.prompt_len + args.prompt_len // 2})
    lens = [buckets[rng.randint(len(buckets))] for _ in range(n_req)]
    gaps = (rng.exponential(1.0 / args.arrival_rate, n_req)
            if args.arrival_rate > 0 else np.zeros(n_req))
    arrivals = np.cumsum(gaps)
    prompts = [rng.randint(0, session.cfg.vocab_size, t) for t in lens]
    max_len = max(buckets) + args.tokens
    paged = bool(args.pages or args.page_size)
    if paged:
        # --slots stays an alias for the memory budget: n_slots dense rows
        # of max_len positions = the same positions' worth of pages
        rt = ServingRuntime(session, n_slots=n_slots, chunk=args.chunk,
                            max_len=max_len,
                            page_size=args.page_size or None,
                            n_pages=args.pages or None,
                            prefix_cache=not args.no_prefix_cache,
                            cold_horizon=args.cold_horizon or None,
                            tracer=tracer)
        print(f"paged KV pool: {rt.n_pages} pages x {rt.page_size} "
              f"positions ({rt.n_slots} rows, prefix cache "
              f"{'off' if args.no_prefix_cache else 'on'})")
    else:
        rt = ServingRuntime(session, n_slots=n_slots, chunk=args.chunk,
                            max_len=max_len, tracer=tracer)
    session.tracer = tracer

    t_start = time.monotonic()
    comps = rt.drive(prompts, arrivals, args.tokens,
                     slo_ms=args.slo_ms or None, poll_s=0.01)
    dt = time.monotonic() - t_start

    lats = [c.latency_ms for c in comps]
    total_toks = sum(len(c.tokens) for c in comps)
    by_plan = {}
    for c in comps:
        by_plan[c.plan_key] = by_plan.get(c.plan_key, 0) + 1
    print(f"served {len(comps)} requests ({total_toks} tokens) in {dt:.2f}s "
          f"→ {total_toks / dt:.1f} tok/s host wall")
    by_codec = {}
    for c in comps:
        name = c.codec or "-"
        by_codec[name] = by_codec.get(name, 0) + 1
    stats = rt.stats_snapshot()
    print(f"latency p50 {np.percentile(lats, 50):.0f} ms  "
          f"p99 {np.percentile(lats, 99):.0f} ms  "
          f"plans {by_plan}  max concurrent {stats['max_concurrent']}")
    print(f"transport: codecs {by_codec}  "
          f"{stats['wire_bytes'] / 1e6:.2f} MB on wire (modeled)")
    if stats["rejected"]:
        print(f"backpressure: {stats['rejected']} puts shed "
              f"{stats['rejections']}")
    if paged:
        print(f"pages: occupancy {stats['page_occupancy']:.0%} peak-free "
              f"{stats['pages_free']}/{stats['pages_total']}  prefix "
              f"hit-rate {stats['prefix_hit_rate']:.0%} "
              f"({stats['full_hits']} full / {stats['partial_hits']} "
              f"partial, {stats['cow_splits']} COW splits)")
    if args.slo_ms:
        met = sum(1 for c in comps if c.slo_met)
        print(f"SLO {args.slo_ms:g} ms: {met}/{len(comps)} met")
    if tracer is not None:
        from repro.obs.export import (format_breakdown, prometheus_text,
                                      write_spans_jsonl)
        spans = tracer.spans
        if args.trace:
            write_spans_jsonl(spans, args.trace)
            print(f"trace: {len(spans)} spans -> {args.trace}")
        # reconcile against summed per-request wall (requests overlap, so
        # the host makespan is not the right denominator); request trees
        # only — runtime-level traces (decode_chunk) overlap decode
        # residency and would double-count
        req_spans = [s for s in spans if s.trace_id.startswith("req:")]
        print(format_breakdown(req_spans, wall_ms=sum(lats)))
        if args.metrics:
            print(prometheus_text(rt.metrics, session.metrics), end="")
    print(np.stack([c.tokens for c in comps[:2]]))
    print("SERVE OK")


if __name__ == "__main__":
    main()
