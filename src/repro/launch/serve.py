"""Sharded serving launcher: prefill + adaptive batched decode.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        [--mode prism|local|adaptive] [--devices 8] [--tokens 16] \
        [--bandwidth 400] [--objective latency|energy]

``--mode adaptive`` profiles through the ``simulated`` backend
(`repro.profiling`) and routes local-vs-PRISM from the compiled policy
table at the given ``--bandwidth`` and ``--objective``.
"""
import argparse
import os

if __name__ == "__main__":
    _ap = argparse.ArgumentParser()
    _ap.add_argument("--devices", type=int, default=8)
    _args, _ = _ap.parse_known_args()
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={_args.devices} "
        "--xla_disable_hlo_passes=all-reduce-promotion")

import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--mode", default="prism",
                    choices=["prism", "local", "adaptive"])
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--L", type=int, default=4)
    ap.add_argument("--bandwidth", type=float, default=400.0,
                    help="observed link bandwidth (Mbps) for --mode adaptive")
    ap.add_argument("--objective", default="latency",
                    choices=["latency", "energy"])
    args = ap.parse_args()

    from repro.api import AdaptivePolicy, ExecutionPlan
    from repro.configs import get_config
    from repro.models import registry, transformer as tfm
    from repro.sharding.specs import (batch_shardings, cache_shardings,
                                      param_shardings)

    mode = args.mode
    if mode == "adaptive":
        from repro.profiling import ProfileContext, SweepSpec, get_backend
        pm = get_backend("simulated").profile(ProfileContext(), SweepSpec())
        d = AdaptivePolicy(pm).decide(args.batch, args.bandwidth,
                                      args.objective)
        mode = "prism" if d.distributed else "local"
        print(f"adaptive: B={args.batch} BW={args.bandwidth:g} Mbps "
              f"[{args.objective}] → {d.mode}"
              + (f" CR={d.cr:g}" if d.cr else "")
              + f" ({d.expected.per_sample_ms:.1f} ms/sample expected"
              + (", EXTRAPOLATED batch" if d.extrapolated else "") + ")")

    n_model = 2 if args.devices >= 4 else 1
    from repro.utils.compat import make_auto_mesh
    mesh = make_auto_mesh((args.devices // n_model, n_model),
                          ("data", "model"))
    cfg = get_config(args.arch).reduced(vocab_size=512)
    eplan = (ExecutionPlan.local() if mode == "local" else
             ExecutionPlan.prism(L=args.L, seq_axis="model",
                                 seq_shards=n_model))
    plan = eplan.sharding_plan(mesh, cfg, decode=True)
    S = args.prompt_len + args.tokens
    rng = np.random.RandomState(0)

    from repro.utils.compat import set_mesh as _set_mesh
    with _set_mesh(mesh):
        params = registry.init_params(cfg, seed=0)
        params = jax.device_put(params, param_shardings(plan, cfg, params))
        cache = tfm.init_decode_cache(cfg, args.batch, S)
        cache = jax.device_put(cache, cache_shardings(plan, cfg, cache))
        dec = jax.jit(lambda p, b, c, i: tfm.decode_step(p, b, c, i, cfg,
                                                         plan.xcfg),
                      donate_argnums=(2,))
        prompt = jnp.asarray(rng.randint(0, cfg.vocab_size,
                                         (args.batch, args.prompt_len)))
        tok = prompt[:, :1]
        out = []
        t0 = time.perf_counter()
        for t in range(S - 1):
            logits, cache = dec(params, {"tokens": tok}, cache, t)
            if t + 1 < args.prompt_len:
                tok = prompt[:, t + 1:t + 2]
            else:
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                out.append(tok)
            if len(out) >= args.tokens:
                break
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        toks = np.concatenate([np.asarray(t) for t in out], 1)
        print(f"mesh {dict(mesh.shape)} mode={mode}: generated "
              f"{toks.shape} in {dt:.2f}s "
              f"({args.batch * args.tokens / dt:.1f} tok/s host wall)")
        print(toks[:2])
        print("SERVE OK")


if __name__ == "__main__":
    main()
