"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init)."""
from __future__ import annotations

import jax

from repro.utils.compat import make_auto_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips).

    Axes: ``pod`` (DCN, slow — the Jetson-WiFi analogue), ``data`` (batch /
    FSDP), ``model`` (TP in LOCAL mode; the paper's P=16 position-wise
    sequence partitions in PRISM/VOLTAGE modes).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_debug_mesh(n_data: int = 4, n_model: int = 2):
    """Small host-device mesh for tests (requires
    --xla_force_host_platform_device_count ≥ n_data·n_model)."""
    return make_auto_mesh((n_data, n_model), ("data", "model"))
