"""Fleet launcher: policy-placed routing over a heterogeneous worker fleet.

    PYTHONPATH=src python -m repro.launch.fleet \
        [--workers 3] [--requests 24] [--arrival-rate 40] [--tokens 16] \
        [--kill edge-b] [--chaos "kill:edge-b@1;revive:edge-b@3"] \
        [--objective latency|energy] [--explain 3] [--real]

Default mode drives virtual-time workers (:class:`repro.fleet.SimWorker`):
three boards with effective-FLOP/s scaled 1.0 / 0.6 / 0.35 of the Jetson
Orin Nano profile, each placing through its own compiled policy table.
``--kill NAME`` fails a worker mid-run to demonstrate drain + re-route;
``--chaos SPEC`` replays a full :class:`repro.chaos.FaultSchedule`
(``kill``/``revive``/``bw``/``drift``/``flap``/``stall``/``straggle``/
``error`` clauses — see :meth:`FaultSchedule.parse`) through the same
:class:`~repro.chaos.ChaosController` the tests and benchmarks use.

``--real`` builds two *real* workers (``InferenceSession`` +
``ServingRuntime`` sharing identical params), serves a small burst, kills
one mid-decode, and verifies the re-routed requests are token-exact
against ``session.generate`` — the fleet-level failover acceptance check.

``--rpc N`` spawns N *subprocess* workers (:mod:`repro.rpc`) and drives
them over real sockets: it prints each worker's measured-vs-modeled codec
decode-throughput table (calibration runs on the worker's own process),
``--chaos`` faults are realized on the wire (kill = SIGKILL, error =
truncated frame + hard close), and the fleet shuts down cleanly on
SIGINT.
"""
import argparse


def _make_tracer(args):
    if not (args.trace or args.metrics):
        return None
    from repro.obs import Tracer
    return Tracer(name="fleet")


def _dump_obs(args, tracer, registries, wall_ms=None):
    """Exit-time observability dump shared by all three fleet modes:
    JSONL span file (--trace), per-stage breakdown line, Prometheus text
    (--metrics)."""
    if tracer is None:
        return
    from repro.obs.export import (format_breakdown, prometheus_text,
                                  write_spans_jsonl)
    spans = tracer.spans
    if args.trace:
        write_spans_jsonl(spans, args.trace)
        print(f"trace: {len(spans)} spans "
              f"({len(tracer.trace_ids())} traces) -> {args.trace}")
    # breakdown over request trees only: runtime-level traces
    # (decode_chunk, failover) overlap decode residency and would
    # double-count against the summed request wall
    req_spans = [s for s in spans if s.trace_id.startswith("req:")]
    print(format_breakdown(req_spans, wall_ms=wall_ms))
    if args.metrics:
        uniq = []
        for r in registries:
            if r is not None and all(r is not u for u in uniq):
                uniq.append(r)
        print(prometheus_text(*uniq), end="")


def _sim_main(args):
    import numpy as np

    from repro.fleet import (DeviceRegistry, FleetRejected, FleetRouter,
                             SimWorker, scaled_hardware)
    from repro.profiling.hardware import JETSON_ORIN_NANO
    from repro.serving.queue import Request

    factors = [1.0, 0.6, 0.35, 0.2, 0.1][:max(args.workers, 1)]
    reg = DeviceRegistry(heartbeat_timeout_s=1e9, calibrate_codecs=True)
    if reg.codec_bws:
        bws = ", ".join(f"{n} {bw / 1e9:.2f} GB/s"
                        for n, bw in sorted(reg.codec_bws.items()))
        print(f"measured codec decode throughput: {bws}")
    for i, f in enumerate(factors):
        name = f"edge-{chr(ord('a') + i)}"
        w = reg.add(SimWorker(
            name,
            hardware=scaled_hardware(JETSON_ORIN_NANO, f,
                                     name=f"jetson-{name}"),
            n_slots=args.slots, queue_size=args.queue_size,
            objective=args.objective,
            dispatch_timeout_s=(args.timeout or None)))
        extra = (f", codecs x{f:g}" if w.codec_bws else "")
        print(f"registered {name}: eff x{f:g}{extra}")

    rng = np.random.RandomState(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                         args.requests))
    reqs = [Request(prompt=rng.randint(0, 64, args.prompt_len),
                    n_new=args.tokens, seed=i, arrival_ts=float(arrivals[i]))
            for i in range(args.requests)]

    from repro.runtime.fault import RetryPolicy
    router = FleetRouter(reg, objective=args.objective,
                         retry=RetryPolicy(max_retries=args.retries),
                         clock=lambda: 0.0)
    tracer = _make_tracer(args)
    if tracer is not None:
        router.attach_tracer(tracer)
    events = []
    chaos = None
    if args.chaos:
        from repro.chaos import ChaosController, FaultSchedule
        schedule = FaultSchedule.parse(args.chaos)
        chaos = ChaosController(reg, schedule, router=router)
        events.extend(chaos.events())
        print(f"chaos schedule: {len(schedule)} scripted events")
    if args.kill:
        kill_at = float(arrivals[len(arrivals) // 3])
        events.append((kill_at, lambda: reg.fail(args.kill)))
        print(f"will kill {args.kill} at t={kill_at:.2f}s (virtual)")
    out = router.drive_virtual(reqs, events=events)

    for rec in router.placements[:args.explain]:
        print(rec.explain())
    comps = out["completions"]
    lats = [c.latency_ms for c in comps]
    tok_s = out["served_tokens"] / max(out["makespan_s"], 1e-9)
    by_worker = {}
    for c in comps:
        by_worker[c.worker] = by_worker.get(c.worker, 0) + 1
    print(f"served {len(comps)}/{args.requests} requests "
          f"({out['served_tokens']} tokens) in {out['makespan_s']:.2f}s "
          f"virtual -> {tok_s:.1f} tok/s aggregate")
    if lats:
        print(f"latency p50 {np.percentile(lats, 50):.0f} ms  "
              f"p99 {np.percentile(lats, 99):.0f} ms  "
              f"by worker {by_worker}  shed {len(out['shed'])}")
    snap = router.stats_snapshot()
    print(f"router: routed {snap['routed']}  rerouted {snap['rerouted']}  "
          f"rejections {snap['rejections']}  dead {snap['dead']}")
    open_breakers = sorted(n for n, b in snap["breakers"].items()
                           if b["state"] != "closed")
    print(f"resilience: retries {snap['retries']}  "
          f"timeouts {snap['timeouts']}  "
          f"transport errors {snap['transport_errors']}  "
          f"placement retries {snap['placement_retries']}  "
          f"breaker opened {snap['breaker_opened']}x"
          f" (now open: {open_breakers or 'none'})  "
          f"failovers {snap['failovers']}  "
          f"readmissions {snap['readmissions']}  lost {snap['lost']}")
    if chaos is not None:
        print(f"chaos log: {len(chaos.log)} applied events, "
              f"{chaos.pending_faults} never consumed")
    _dump_obs(args, tracer,
              [router.metrics] + [w.metrics for w in reg],
              wall_ms=sum(lats))
    print("FLEET OK")


def _real_main(args):
    import numpy as np

    from repro.api import ExecutionPlan, InferenceSession
    from repro.fleet import DeviceRegistry, FleetRouter, WorkerHandle

    def make_session():
        s = InferenceSession.from_config(
            args.arch, reduced={"vocab_size": 64},
            plans=[ExecutionPlan.local(),
                   ExecutionPlan.prism_sim(L=4, cr=9.9)])
        s.profile(backend="simulated")
        return s

    # identical params (same config, same seed) — a re-routed request is
    # token-exact on the surviving worker
    s1, s2 = make_session(), make_session()
    reg = DeviceRegistry(heartbeat_timeout_s=1e9)
    reg.add(WorkerHandle("w1", s1, n_slots=4, max_len=64))
    reg.add(WorkerHandle("w2", s2, n_slots=4, max_len=64))
    router = FleetRouter(reg)
    tracer = _make_tracer(args)
    if tracer is not None:
        router.attach_tracer(tracer)

    rng = np.random.RandomState(args.seed)
    prompts = [rng.randint(0, 64, args.prompt_len) for _ in range(6)]
    placed = router.fanout(prompts, args.tokens)
    for req, rec in placed:
        print(rec.explain() if rec else f"request {req.id} SHED")

    router.step()                     # everyone gets some work in flight
    reg.fail("w1")
    print("killed w1 mid-decode; re-routing its in-flight requests...")
    router.run()

    import jax.numpy as jnp
    ok = 0
    for req, _ in placed:
        comp = router.completion_for(req.id)
        ref = s2.generate(jnp.asarray(req.prompt)[None], req.n_new,
                          seed=req.seed)
        exact = bool(np.array_equal(comp.tokens, np.asarray(ref)[0]))
        ok += exact
        print(f"request {req.id}: served by a surviving worker, "
              f"token-exact={exact}")
    snap = router.stats_snapshot()
    print(f"router: routed {snap['routed']}  rerouted {snap['rerouted']}  "
          f"dead {snap['dead']}")
    if ok != len(placed):
        raise SystemExit("FAIL: failover was not token-exact")
    _dump_obs(args, tracer,
              [router.metrics] + [w.metrics for w in reg])
    print("FLEET OK (real workers, token-exact failover)")


def _rpc_main(args):
    """--rpc N: spawn N real subprocess workers (``repro.rpc``), print the
    measured-vs-modeled codec decode-throughput table, drive a short
    real-clock Poisson load (``--chaos`` faults are realized on the wire:
    kills are SIGKILLs, errors are sabotaged sockets), and shut the fleet
    down cleanly — including on Ctrl-C."""
    import signal

    import numpy as np

    from repro.fleet import DeviceRegistry, FleetRouter
    from repro.rpc import RpcWorker
    from repro.runtime.fault import RetryPolicy
    from repro.serving.queue import Request
    from repro.transport.codecs import get_codec

    n = max(args.rpc, 1)
    reg = DeviceRegistry(heartbeat_timeout_s=60.0)
    workers = []
    interrupted = []

    def on_sigint(signum, frame):
        # first Ctrl-C: finish the loop and shut down cleanly; the drive
        # checks the flag through the chaos-free event path below
        interrupted.append(True)
        print("\nSIGINT: draining and shutting the fleet down...")

    old_handler = signal.signal(signal.SIGINT, on_sigint)
    try:
        for i in range(n):
            name = f"rpc-{chr(ord('a') + i)}"
            w = RpcWorker(name, vocab=64, seed=args.seed, n_slots=args.slots,
                          chunk=4, max_len=max(args.prompt_len + args.tokens,
                                               32),
                          queue_size=args.queue_size,
                          hw_scale=[1.0, 0.8, 0.6, 0.5, 0.4][i % 5],
                          arch=args.arch,
                          retry=RetryPolicy(max_retries=args.retries,
                                            backoff_base_s=0.05))
            workers.append(w)
            reg.add(w)
            print(f"spawned {name}: pid {w.proc.pid}, "
                  f"port {w.address[1]}, calibration "
                  f"{'measured' if w.codec_bws_measured else 'estimated'}")
        print(f"{'worker':8s} {'codec':14s} {'measured MB/s':>14s} "
              f"{'modeled MB/s':>13s}")
        for w in workers:
            for cname in sorted(w.codec_bws):
                modeled = type(get_codec(cname)).decode_bw
                print(f"{w.name:8s} {cname:14s} "
                      f"{w.codec_bws[cname] / 1e6:14.1f} "
                      f"{modeled / 1e6:13.1f}")

        router = FleetRouter(reg, objective=args.objective,
                             retry=RetryPolicy(max_retries=args.retries))
        tracer = _make_tracer(args)
        if tracer is not None:
            router.attach_tracer(tracer)
        rng = np.random.RandomState(args.seed)
        n_req = min(args.requests, 24)
        arrivals = np.cumsum(rng.exponential(1.0 / min(args.arrival_rate,
                                                       8.0), n_req))
        reqs = [Request(prompt=rng.randint(0, 64, args.prompt_len),
                        n_new=args.tokens, seed=i,
                        arrival_ts=float(arrivals[i]))
                for i in range(n_req)]
        events = []
        chaos = None
        if args.chaos:
            from repro.chaos import ChaosController, FaultSchedule
            schedule = FaultSchedule.parse(args.chaos)
            chaos = ChaosController(reg, schedule, router=router)
            events.extend(chaos.events())
            print(f"chaos schedule: {len(schedule)} scripted events "
                  "(realized on the wire: kill=SIGKILL, "
                  "error=truncated frame)")
        if interrupted:
            return
        out = router.drive_real(reqs, events=events, timeout_s=600.0)
        comps = out["completions"]
        lats = [c.latency_ms for c in comps]
        by_worker = {}
        for c in comps:
            by_worker[c.worker] = by_worker.get(c.worker, 0) + 1
        tok_s = out["served_tokens"] / max(out["makespan_s"], 1e-9)
        print(f"served {len(comps)}/{n_req} requests "
              f"({out['served_tokens']} tokens) in "
              f"{out['makespan_s']:.2f}s -> {tok_s:.1f} tok/s aggregate")
        if lats:
            print(f"latency p50 {np.percentile(lats, 50):.0f} ms  "
                  f"p99 {np.percentile(lats, 99):.0f} ms  "
                  f"by worker {by_worker}  shed {len(out['shed'])}")
        snap = router.stats_snapshot()
        print(f"router: routed {snap['routed']}  "
              f"rerouted {snap['rerouted']}  lost {snap['lost']}  "
              f"breaker opened {snap['breaker_opened']}x")
        if chaos is not None:
            print(f"chaos log: {len(chaos.log)} applied events, "
                  f"{chaos.pending_faults} never consumed")
        _dump_obs(args, tracer,
                  [router.metrics] + [w.metrics for w in workers],
                  wall_ms=sum(lats))
        print("RPC FLEET OK")
    finally:
        signal.signal(signal.SIGINT, old_handler)
        for w in workers:
            try:
                w.close()
            except Exception:
                w.kill_process()
        live = [w.name for w in workers
                if w.proc is not None and w.proc.poll() is None]
        print(f"shutdown: {len(workers)} workers closed"
              + (f" (still alive: {live})" if live else ""))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=3,
                    help="fleet size (sim mode; eff 1.0/0.6/0.35/...)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--arrival-rate", type=float, default=40.0,
                    help="Poisson arrival rate, req/s (virtual)")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--queue-size", type=int, default=8)
    ap.add_argument("--objective", default="latency",
                    choices=["latency", "energy"])
    ap.add_argument("--kill", default="",
                    help="worker name to fail mid-run (e.g. edge-b)")
    ap.add_argument("--chaos", default="",
                    help="fault-schedule spec, e.g. "
                         "'kill:edge-b@1;revive:edge-b@3;"
                         "drift:edge-a@0:600->60:4'")
    ap.add_argument("--retries", type=int, default=3,
                    help="placement retry budget (exponential backoff)")
    ap.add_argument("--timeout", type=float, default=0.0,
                    help="per-dispatch timeout in virtual seconds "
                         "(0 = none)")
    ap.add_argument("--explain", type=int, default=3,
                    help="print the scored ranking of the first N "
                         "placements")
    ap.add_argument("--real", action="store_true",
                    help="two real workers + token-exact failover demo")
    ap.add_argument("--rpc", type=int, default=0, metavar="N",
                    help="spawn N subprocess workers (repro.rpc) and "
                         "drive them over real sockets; --chaos faults "
                         "are realized on the wire")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="write the request span trace as JSONL to PATH "
                         "and print a per-stage breakdown at exit "
                         "(works in sim, --real and --rpc modes)")
    ap.add_argument("--metrics", action="store_true",
                    help="dump the unified metrics registries "
                         "(Prometheus text format) at exit")
    args = ap.parse_args()
    if args.rpc:
        _rpc_main(args)
    elif args.real:
        _real_main(args)
    else:
        _sim_main(args)


if __name__ == "__main__":
    main()
