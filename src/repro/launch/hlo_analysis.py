"""Post-optimization HLO analyzer: loop-aware FLOPs / HBM-bytes / collective
bytes for the roofline (EXPERIMENTS.md §Roofline).

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits every
while-loop body ONCE — all our layer stacks are ``lax.scan``s, so its flops
undercount by the layer count. This analyzer parses ``compiled.as_text()``
(per-device, post-SPMD shapes), walks the call graph, and multiplies while
bodies by their ``known_trip_count`` backend config.

Cost model per op (documented assumptions):
* dot: 2 · prod(output) · prod(contracted dims) FLOPs.
* elementwise arith/transcendental: 1 FLOP / output element.
* HBM bytes: operands + outputs per top-level op; fusions count their
  *parameters'* effective reads — a parameter whose only users inside the
  fusion are (dynamic-)slice/gather is charged the slice bytes, not the full
  buffer (this is exactly the scan weight-slicing pattern).
* collectives: operand bytes recorded per kind with ring-transfer factors —
  all-gather (P-1)·in, reduce-scatter (P-1)/P·in, all-reduce 2(P-1)/P·in,
  all-to-all (P-1)/P·in, collective-permute 1·in — giving per-device wire
  bytes; both raw operand sums (the brief's definition) and wire bytes are
  reported.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1,
                "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5,
                "u4": 0.5, "c128": 16, "token": 0, "opaque": 0}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "sign", "floor", "ceil", "compare",
    "select", "and", "or", "not", "xor", "convert", "sine", "cosine",
    "logistic", "erf", "atan2", "remainder", "round-nearest-afz",
    "round-nearest-even", "clamp", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "cbrt", "is-finite", "reduce", "exp",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPND_RE = re.compile(r"%[\w.\-]+")


def _shape_bytes_elems(type_str: str) -> Tuple[float, float]:
    """Total (bytes, elements) over all arrays in a (possibly tuple) type."""
    total_b = total_e = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1.0
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_b, total_e


def _split_top_type(line: str) -> Optional[str]:
    """Return the result type of '%name = TYPE op(...)' lines."""
    m = re.match(r"\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*(.*?)\s+([\w\-]+)\(", line)
    if not m:
        return None
    return m.group(1)


@dataclasses.dataclass
class OpInfo:
    name: str
    kind: str
    out_type: str
    operands: List[str]
    attrs: str
    line: str


@dataclasses.dataclass
class CollectiveRecord:
    kind: str
    operand_bytes: float        # per-device operand size × executions
    wire_bytes: float           # ring-transfer bytes per device × executions
    group_size: int
    count: float                # number of executions (× trip counts)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: List[CollectiveRecord] = dataclasses.field(
        default_factory=list)

    def scaled(self, k: float) -> "HloCost":
        return HloCost(self.flops * k, self.bytes * k,
                       [CollectiveRecord(c.kind, c.operand_bytes * k,
                                         c.wire_bytes * k, c.group_size,
                                         c.count * k)
                        for c in self.collectives])

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        self.collectives.extend(other.collectives)

    @property
    def collective_operand_bytes(self) -> float:
        return sum(c.operand_bytes for c in self.collectives)

    @property
    def collective_wire_bytes(self) -> float:
        return sum(c.wire_bytes for c in self.collectives)

    def collective_summary(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = defaultdict(
            lambda: {"operand_bytes": 0.0, "wire_bytes": 0.0, "count": 0.0})
        for c in self.collectives:
            out[c.kind]["operand_bytes"] += c.operand_bytes
            out[c.kind]["wire_bytes"] += c.wire_bytes
            out[c.kind]["count"] += c.count
        return dict(out)


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[OpInfo]] = {}
        self.op_types: Dict[Tuple[str, str], str] = {}   # (comp, %name) → type
        self._parse(text)

    def _parse(self, text: str) -> None:
        comp = None
        for raw in text.splitlines():
            line = raw.rstrip()
            header = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{",
                              line)
            if header and "=" not in line.split("(")[0]:
                comp = header.group(1)
                self.computations[comp] = []
                continue
            if comp is None:
                continue
            m = re.match(
                r"\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)",
                line)
            if not m:
                continue
            name, out_type, kind, rest = m.groups()
            args_part = rest.split("),", 1)[0] if ")," in rest else rest
            operands = _OPND_RE.findall(args_part)
            op = OpInfo(name=name, kind=kind, out_type=out_type,
                        operands=operands, attrs=rest, line=line)
            self.computations[comp].append(op)
            self.op_types[(comp, name)] = out_type

    # ------------------------------------------------------------------

    def _operand_type(self, comp: str, name: str) -> str:
        return self.op_types.get((comp, name), "")

    def _group_size(self, attrs: str) -> int:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
        if m:
            return len(m.group(1).split(","))
        return 2

    def _trip_count(self, attrs: str) -> float:
        m = re.search(r'known_trip_count[":{ ]+n["\s:]+\"?(\d+)', attrs)
        return float(m.group(1)) if m else 1.0

    def _called(self, attrs: str, key: str) -> Optional[str]:
        m = re.search(key + r"=%([\w.\-]+)", attrs)
        return m.group(1) if m else None

    def _fusion_param_bytes(self, called: str, operands: List[str],
                            comp: str) -> float:
        """Effective read bytes of a fusion's parameters (slice-aware)."""
        ops = self.computations.get(called, [])
        params: Dict[int, str] = {}
        for o in ops:
            if o.kind == "parameter":
                m = re.search(r"parameter\((\d+)", o.line)
                if m:
                    params[int(m.group(1))] = o.name
        total = 0.0
        for idx, opnd in enumerate(operands):
            full_b, _ = _shape_bytes_elems(self._operand_type(comp, opnd))
            pname = params.get(idx)
            if pname is None:
                total += full_b
                continue
            users = [o for o in ops if pname in o.operands]
            if users and all(u.kind in ("dynamic-slice", "gather", "bitcast",
                                        "reshape", "slice", "copy",
                                        "dynamic-update-slice")
                             for u in users):
                eff = 0.0
                for u in users:
                    if u.kind == "dynamic-update-slice":
                        # reads+writes only the update region
                        upd = u.operands[1] if len(u.operands) > 1 else None
                        t = (self._operand_type(called, upd) if upd else
                             u.out_type)
                        eff += _shape_bytes_elems(t)[0]
                    else:
                        eff += _shape_bytes_elems(u.out_type)[0]
                total += min(eff, full_b)
            else:
                total += full_b
        return total

    def cost_of(self, comp: str, memo: Optional[Dict[str, HloCost]] = None
                ) -> HloCost:
        memo = memo if memo is not None else {}
        if comp in memo:
            return memo[comp]
        memo[comp] = HloCost()          # break cycles defensively
        total = HloCost()
        for op in self.computations.get(comp, []):
            k = op.kind
            if k in ("parameter", "constant", "get-tuple-element", "tuple",
                     "bitcast", "after-all", "partition-id", "replica-id",
                     "iota"):
                continue
            out_b, out_e = _shape_bytes_elems(op.out_type)

            if k == "while":
                trip = self._trip_count(op.attrs)
                body = self._called(op.attrs, "body")
                cond = self._called(op.attrs, "condition")
                if body:
                    total.add(self.cost_of(body, memo).scaled(trip))
                if cond:
                    total.add(self.cost_of(cond, memo).scaled(trip))
                continue
            if k == "conditional":
                branches = re.findall(r"%([\w.\-]+)", op.attrs)
                subcosts = [self.cost_of(b, memo) for b in branches
                            if b in self.computations]
                if subcosts:
                    biggest = max(subcosts, key=lambda c: c.flops + c.bytes)
                    total.add(biggest)
                total.bytes += out_b
                continue
            if k in ("call", "async-start"):
                called = self._called(op.attrs, "to_apply") or \
                    self._called(op.attrs, "calls")
                if called:
                    total.add(self.cost_of(called, memo))
                continue

            if k in _COLLECTIVES or any(op.kind.startswith(c)
                                        for c in _COLLECTIVES):
                in_b = sum(_shape_bytes_elems(
                    self._operand_type(comp, o))[0] for o in op.operands)
                g = self._group_size(op.attrs)
                base = max(g - 1, 0) / max(g, 1)
                kind = next(c for c in _COLLECTIVES if op.kind.startswith(c))
                if kind == "all-gather":
                    wire = in_b * max(g - 1, 0)
                elif kind == "all-reduce":
                    wire = 2 * in_b * base
                elif kind in ("reduce-scatter", "all-to-all"):
                    wire = in_b * base
                else:                      # collective-permute
                    wire = in_b
                total.collectives.append(
                    CollectiveRecord(kind, in_b, wire, g, 1.0))
                total.bytes += in_b + out_b
                continue

            if k == "fusion":
                called = self._called(op.attrs, "calls")
                if called:
                    sub = self.cost_of(called, memo)
                    total.flops += sub.flops
                    total.collectives.extend(sub.collectives)
                    total.bytes += (self._fusion_param_bytes(
                        called, op.operands, comp) + out_b)
                continue

            if k == "dot":
                lhs_t = self._operand_type(comp, op.operands[0]) \
                    if op.operands else ""
                contract = 1.0
                m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
                if m and lhs_t:
                    dims_m = _SHAPE_RE.search(lhs_t)
                    if dims_m:
                        lshape = [int(x) for x in dims_m.group(2).split(",")
                                  if x]
                        for d in m.group(1).split(","):
                            if d:
                                contract *= lshape[int(d)]
                total.flops += 2.0 * out_e * contract
                in_b = sum(_shape_bytes_elems(
                    self._operand_type(comp, o))[0] for o in op.operands)
                total.bytes += in_b + out_b
                continue

            if k == "convolution":
                m = re.search(r"dim_labels=\S+", op.attrs)
                total.flops += 2.0 * out_e * 128        # coarse; convs only
                total.bytes += out_b * 3                # in stub frontends
                continue

            if k in ("dynamic-slice", "slice", "gather"):
                total.bytes += 2 * out_b
                continue
            if k in ("dynamic-update-slice", "scatter"):
                upd = op.operands[1] if len(op.operands) > 1 else None
                ub = _shape_bytes_elems(
                    self._operand_type(comp, upd))[0] if upd else out_b
                total.bytes += 2 * ub
                continue
            if k in ("copy", "copy-start", "transpose", "reshape",
                     "broadcast", "concatenate", "pad", "reverse",
                     "reduce-window", "sort", "rng", "rng-bit-generator",
                     "cholesky", "triangular-solve", "custom-call",
                     "dynamic-reshape", "select-and-scatter"):
                in_b = sum(_shape_bytes_elems(
                    self._operand_type(comp, o))[0] for o in op.operands)
                total.bytes += in_b + out_b
                if k == "sort":
                    total.flops += out_e * 10           # ~n log n compares
                continue

            if k in _ELEMENTWISE:
                total.flops += out_e
                in_b = sum(_shape_bytes_elems(
                    self._operand_type(comp, o))[0] for o in op.operands)
                total.bytes += in_b + out_b
                continue

            # unknown op: count bytes conservatively
            in_b = sum(_shape_bytes_elems(
                self._operand_type(comp, o))[0] for o in op.operands)
            total.bytes += in_b + out_b
        memo[comp] = total
        return total

    def entry_cost(self) -> HloCost:
        entry = None
        for name, ops in self.computations.items():
            if name.startswith("main") or ".main" in name or entry is None:
                if any(o.kind not in ("parameter",) for o in ops):
                    if entry is None or "main" in name:
                        entry = name
        # prefer a computation literally containing 'main'
        mains = [n for n in self.computations if "main" in n]
        if mains:
            entry = mains[0]
        return self.cost_of(entry)


def analyze_hlo_text(text: str) -> HloCost:
    return HloModule(text).entry_cost()


def analysis_dict(cost: HloCost, n_chips: int) -> Dict:
    """Roofline terms per EXPERIMENTS.md §Roofline (per-chip quantities —
    post-SPMD HLO shapes are already per-device)."""
    from repro.core.costmodel import TPU_HBM_BW, TPU_ICI_BW, TPU_PEAK_FLOPS
    return {
        "per_device_flops": cost.flops,
        "per_device_hbm_bytes": cost.bytes,
        "per_device_collective_operand_bytes": cost.collective_operand_bytes,
        "per_device_collective_wire_bytes": cost.collective_wire_bytes,
        "collectives": cost.collective_summary(),
        "n_chips": n_chips,
        "compute_s": cost.flops / TPU_PEAK_FLOPS,
        "memory_s": cost.bytes / TPU_HBM_BW,
        "collective_s": cost.collective_wire_bytes / TPU_ICI_BW,
    }
