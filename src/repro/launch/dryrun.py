import os
# 512 placeholder devices for the production mesh; the disabled pass is an
# XLA-CPU-only crasher (bf16 collective reducers carrying layout copies —
# "Invalid binary instruction opcode copy"); it never runs on TPU.
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=all-reduce-promotion")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent on the
production mesh — 16×16 single-pod and 2×16×16 multi-pod — and extracts the
roofline terms from the compiled artifact:

  * ``compiled.memory_analysis()``  → fits-in-HBM proof (per device)
  * ``compiled.cost_analysis()``    → XLA's flops/bytes (loop bodies ×1)
  * ``repro.launch.hlo_analysis``   → loop-aware flops / HBM bytes /
                                      collective bytes (§Roofline source)

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--mode prism]
Artifacts: artifacts/dryrun/<mesh>/<arch>__<shape>__<mode>.json
"""
import argparse
import gc
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, SHAPES_BY_NAME, get_config, shapes_for
from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.costmodel import TPU_HBM_GB
from repro.core.exchange import ExchangeMode
from repro.launch.hlo_analysis import analysis_dict, analyze_hlo_text
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.sharding.specs import (batch_shardings, cache_shardings, make_plan,
                                  opt_state_shardings, param_shardings)
from repro.train.optimizer import adamw_init
from repro.train.train_step import build_train_step

DEFAULT_L = 16


def default_mode(cfg: ModelConfig, shape_kind: str = "prefill"
                 ) -> ExchangeMode:
    """The adaptive policy's static projection onto the baseline table:

    * xLSTM has no attention → LOCAL always (DESIGN.md §4).
    * Inference (prefill/decode) → PRISM — the paper's domain.
    * Training: PRISM while weights are replicable (small archs — the
      paper-faithful layout with zero FFN comm); above the FSDP threshold
      the position-wise layout loses to classic TP×FSDP because weight
      gather/grad-reduce traffic swamps the activation traffic PRISM saves
      (measured — EXPERIMENTS.md §Perf), so big-arch train cells run LOCAL.
    """
    if cfg.family == "ssm":
        return ExchangeMode.LOCAL
    if shape_kind == "train":
        from repro.sharding.specs import _param_gb
        if _param_gb(cfg) > 20:
            return ExchangeMode.LOCAL
    return ExchangeMode.PRISM


def model_flops(cfg: ModelConfig, shape: ShapeSpec, n_params: int) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D (train) / 2·N_active·D (fwd)."""
    active = active_params(cfg, n_params)
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill") else shape.global_batch)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active * tokens


def active_params(cfg: ModelConfig, n_params: int) -> float:
    if not cfg.moe:
        return float(n_params)
    m = cfg.moe
    routed_per_layer = 3 * cfg.d_model * m.d_ff_expert * m.n_experts
    inactive = (3 * cfg.d_model * m.d_ff_expert * (m.n_experts - m.top_k)
                * (cfg.n_layers - m.first_dense_layers))
    return float(n_params) - inactive


def grad_accum_for(cfg: ModelConfig) -> int:
    """Microbatching keeps big-arch train cells inside 16 GB HBM: the
    per-layer residual stack scales with tokens/device ÷ accumulation."""
    from repro.sharding.specs import _param_gb
    gb = _param_gb(cfg)
    if gb > 100:
        return 16
    if gb > 20:
        return 4
    return 1


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               mode: ExchangeMode, L: int = DEFAULT_L, compile_only=True,
               grad_accum: Optional[int] = None):
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    kind = SHAPES_BY_NAME[shape_name].kind
    plan = make_plan(mesh, cfg, mode, L=L, train=kind == "train",
                     decode=kind == "decode")
    xcfg = plan.xcfg

    aparams = registry.abstract_params(cfg)
    pshard = param_shardings(plan, cfg, aparams)
    from repro.utils.tree import param_bytes, param_count
    n_params = param_count(aparams)

    from repro.utils.compat import set_mesh as _set_mesh
    with _set_mesh(mesh):
        if shape.kind == "train":
            from repro.sharding.specs import _param_gb
            mdt = jnp.bfloat16 if _param_gb(cfg) > 100 else jnp.float32
            aopt = jax.eval_shape(lambda p: adamw_init(p, moment_dtype=mdt),
                                  aparams)
            oshard = opt_state_shardings(plan, cfg, aopt)
            inspecs = registry.input_specs(cfg, shape)
            bshard = batch_shardings(plan, cfg, inspecs, shape.kind)
            ga = grad_accum_for(cfg) if grad_accum is None else grad_accum
            # each microbatch must still cover the batch shards
            bshards = int(np.prod([mesh.shape[a] for a in plan.batch_axes]))
            ga = max(min(ga, shape.global_batch // max(bshards, 1)), 1)
            from repro.sharding.specs import _param_gb
            import jax.numpy as _jnp
            acc_dtype = (_jnp.bfloat16 if _param_gb(cfg) > 100
                         else _jnp.float32)
            step = build_train_step(cfg, xcfg, grad_accum=ga,
                                    acc_shardings=oshard.m,
                                    acc_dtype=acc_dtype)
            fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(aparams, aopt, inspecs)
        elif shape.kind == "prefill":
            inspecs = registry.input_specs(cfg, shape)
            bshard = batch_shardings(plan, cfg, inspecs, shape.kind)
            fwd = registry.prefill_fn(cfg)

            def prefill(params, batch):
                logits, aux = fwd(params, batch, xcfg)
                return logits[:, -1:], aux
            fn = jax.jit(prefill, in_shardings=(pshard, bshard))
            lowered = fn.lower(aparams, inspecs)
        else:  # decode
            inspecs = registry.input_specs(cfg, shape)
            bshard = batch_shardings(plan, cfg, inspecs, shape.kind)
            acache = registry.abstract_cache(cfg, shape, xcfg)
            cshard = cache_shardings(plan, cfg, acache)
            dec = registry.decode_fn(cfg)

            def serve_step(params, batch, cache, idx):
                return dec(params, batch, cache, idx, xcfg)
            fn = jax.jit(serve_step,
                         in_shardings=(pshard, bshard, cshard, None),
                         out_shardings=None, donate_argnums=(2,))
            lowered = fn.lower(aparams, inspecs, acache,
                               jax.ShapeDtypeStruct((), jnp.int32))
    return lowered, dict(cfg=cfg, shape=shape, n_chips=n_chips,
                         n_params=n_params,
                         param_bytes=param_bytes(aparams), plan=plan)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             mode: ExchangeMode, L: int = DEFAULT_L, out_dir="artifacts/dryrun",
             verbose=True):
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, multi_pod=multi_pod,
                               mode=mode, L=L)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo_cost = analyze_hlo_text(compiled.as_text())
    roof = analysis_dict(hlo_cost, meta["n_chips"])
    mf = model_flops(meta["cfg"], meta["shape"], meta["n_params"])

    per_dev_hbm = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                   - mem.alias_size_in_bytes + mem.temp_size_in_bytes)
    record = {
        "arch": arch, "shape": shape_name, "mode": mode.value, "L": L,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": meta["n_chips"],
        "n_params": meta["n_params"],
        "param_bytes": meta["param_bytes"],
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            "per_device_total_bytes": per_dev_hbm,
            "fits_16gb": per_dev_hbm < TPU_HBM_GB * 1e9,
        },
        "xla_cost_analysis": {k: cost.get(k) for k in
                              ("flops", "bytes accessed")},
        "roofline": roof,
        "model_flops_global": mf,
        "model_flops_per_device": mf / meta["n_chips"],
        "useful_flops_ratio": (mf / meta["n_chips"]) / max(roof["per_device_flops"], 1.0),
    }
    if verbose:
        print(f"[{record['mesh']}] {arch} × {shape_name} × {mode.value}: "
              f"compile {t_compile:.0f}s, "
              f"mem/dev {per_dev_hbm/1e9:.2f} GB "
              f"(fits={record['memory']['fits_16gb']}), "
              f"flops/dev {roof['per_device_flops']:.3e}, "
              f"coll wire {roof['per_device_collective_wire_bytes']:.3e} B")
        print(f"    terms: compute {roof['compute_s']*1e3:.2f} ms | memory "
              f"{roof['memory_s']*1e3:.2f} ms | collective "
              f"{roof['collective_s']*1e3:.2f} ms")
    d = os.path.join(out_dir, record["mesh"])
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, f"{arch}__{shape_name}__{mode.value}.json"),
              "w") as f:
        json.dump(record, f, indent=1)
    return record


def all_cells():
    for arch in ASSIGNED_ARCHS:
        for shape in shapes_for(arch):
            yield arch, shape.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mode", default=None,
                    choices=["prism", "voltage", "local"])
    ap.add_argument("--L", type=int, default=DEFAULT_L)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    cells = (list(all_cells()) if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        cfg = get_config(arch)
        mode = (ExchangeMode(args.mode) if args.mode
                else default_mode(cfg, SHAPES_BY_NAME[shape].kind))
        for mp in meshes:
            try:
                run_cell(arch, shape, multi_pod=mp, mode=mode, L=args.L,
                         out_dir=args.out)
            except Exception as e:
                failures.append((arch, shape, mp, repr(e)))
                print(f"FAILED [{'2x16x16' if mp else '16x16'}] {arch} × "
                      f"{shape}: {e}")
                traceback.print_exc()
            gc.collect()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUN CELLS PASSED")


if __name__ == "__main__":
    main()
