"""Sharded training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 100 [--mode prism|local] [--devices 8] [--reduced]

On this host, ``--devices N`` builds an N-device debug mesh (host platform
devices); on a real fleet the same code runs under jax.distributed with the
production mesh from mesh.py.
"""
import argparse
import os

if __name__ == "__main__":                     # set before jax init
    _ap = argparse.ArgumentParser()
    _ap.add_argument("--devices", type=int, default=8)
    _args, _rest = _ap.parse_known_args()
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={_args.devices} "
        "--xla_disable_hlo_passes=all-reduce-promotion")

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--mode", default="prism", choices=["prism", "voltage",
                                                        "local"])
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--L", type=int, default=4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core.exchange import ExchangeMode
    from repro.data.pipeline import SyntheticLMDataset
    from repro.models import registry
    from repro.sharding.specs import (batch_shardings, make_plan,
                                      opt_state_shardings, param_shardings)
    from repro.checkpoint.manager import CheckpointManager
    from repro.train.optimizer import adamw_init
    from repro.train.train_step import build_train_step

    n_model = 2 if args.devices >= 4 else 1
    from repro.utils.compat import make_auto_mesh
    mesh = make_auto_mesh((args.devices // n_model, n_model),
                          ("data", "model"))
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(vocab_size=512)
    plan = make_plan(mesh, cfg, ExchangeMode(args.mode), L=args.L, train=True)

    from repro.utils.compat import set_mesh as _set_mesh
    with _set_mesh(mesh):
        params = registry.init_params(cfg, seed=0)
        pshard = param_shardings(plan, cfg, params)
        params = jax.device_put(params, pshard)
        opt = jax.device_put(adamw_init(params),
                             opt_state_shardings(plan, cfg, params))
        step_fn = jax.jit(build_train_step(cfg, plan.xcfg),
                          in_shardings=(pshard, None, None),
                          donate_argnums=(0, 1))
        ds = SyntheticLMDataset(cfg.vocab_size, args.seq, args.batch)
        ckpt = CheckpointManager(args.ckpt_dir, keep=2)
        losses = []
        for step in range(args.steps):
            b = ds.sample(np.random.RandomState(1000 + step))
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt, m = step_fn(params, opt, batch)
            losses.append(float(m["loss"]))
            if step % 10 == 0:
                print(f"step {step:4d} loss {losses[-1]:.4f} "
                      f"gnorm {float(m['grad_norm']):.3f}")
            if (step + 1) % 50 == 0:
                ckpt.save_async((params, opt), step + 1)
        ckpt.wait()
        print(f"done: loss {np.mean(losses[:5]):.3f} → "
              f"{np.mean(losses[-5:]):.3f} on mesh {dict(mesh.shape)} "
              f"mode={args.mode}")


if __name__ == "__main__":
    main()
