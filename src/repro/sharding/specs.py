"""Sharding plans: param / activation / cache PartitionSpecs per execution
mode (DESIGN.md §5).

Mesh axes: ``("data", "model")`` single-pod (16×16) or
``("pod", "data", "model")`` multi-pod (2×16×16).

Execution modes map the paper's deployment choices onto the mesh:

* **LOCAL** (paper's single-device inference, generalized): batch shards
  over (pod, data); the model axis does tensor parallelism — attention
  head-sharded where head counts divide, FFN column/row sharded, vocab
  sharded. No sequence partitioning.
* **VOLTAGE / PRISM** (paper's distributed execution): the *sequence*
  shards over the model axis — the paper's position-wise partitions P=16 —
  and attention communicates via full-tensor or Segment-Means all-gather
  inside shard_map. Attention projections are replicated over `model`
  (heads live unsharded inside the manual region); FFN stays
  column/row-sharded over `model`, which under a sequence-sharded
  activation becomes the standard all-gather → FFN → reduce-scatter
  sequence-parallel TP schedule chosen by GSPMD.

FSDP: architectures whose parameters exceed ``FSDP_THRESHOLD_GB`` are
additionally sharded over the batch axes (ZeRO-3; XLA inserts just-in-time
all-gathers). Optimizer state is always sharded over the batch axes where
divisible (ZeRO-1) regardless of size.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.exchange import ExchangeConfig, ExchangeMode

FSDP_THRESHOLD_GB = 4.0

# [in, out] column-parallel mats (output dim is the TP dim in LOCAL mode)
_COL = {"wq", "wk", "wv", "w_up", "w_gate", "w_uq", "patch_embed", "head",
        "w_in", "w_x", "w_bc", "w_dt", "w_if", "w_q", "w_k", "w_v"}
_ROW = {"wo", "w_down", "w_out"}
_ATTN = {"wq", "wk", "wv", "wo"}          # replicated over model when the
                                          # sequence occupies the model axis
_EMBED = {"table"}


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    mesh: Mesh
    mode: ExchangeMode
    batch_axes: Tuple[str, ...]          # axes sharding the batch dim
    tp_axis: str                          # "model"
    seq_axis: Optional[str]               # "model" in distributed modes
    fsdp: bool                            # ZeRO-3 params over batch axes
    L: int = 0                            # PRISM segment means per partition
    decode: bool = False                  # one-token steps: no seq/TP conflict
    train: bool = False

    @property
    def xcfg(self) -> ExchangeConfig:
        n = self.mesh.shape[self.seq_axis] if self.seq_axis else 1
        return ExchangeConfig(self.mode, self.seq_axis, n, L=self.L,
                              batch_axes=tuple(self.batch_axes))

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def make_plan(mesh: Mesh, cfg: ModelConfig, mode: ExchangeMode,
              L: int = 0, train: bool = False,
              decode: bool = False) -> ShardingPlan:
    axes = list(mesh.axis_names)
    tp = "model"
    batch_axes = tuple(a for a in axes if a != tp)
    seq_axis = tp if mode in (ExchangeMode.PRISM, ExchangeMode.VOLTAGE) else None
    nbytes = _param_gb(cfg)
    # Training always shards params (ZeRO-3 over the batch axes): replicated
    # params replicate the f32 optimizer math and its temporaries too.
    # Inference replicates small archs (zero weight comm — paper layout).
    return ShardingPlan(mesh=mesh, mode=mode, batch_axes=batch_axes,
                        tp_axis=tp, seq_axis=seq_axis,
                        fsdp=train or nbytes > FSDP_THRESHOLD_GB, L=L,
                        decode=decode, train=train)


def _param_gb(cfg: ModelConfig) -> float:
    """Analytic parameter-byte estimate (for the FSDP threshold only)."""
    d, f, V, nl = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    per_layer = 4 * d * d + 3 * d * f
    if cfg.moe:
        m = cfg.moe
        per_layer = 4 * d * d + 3 * d * m.d_ff_expert * (m.n_experts + m.n_shared)
    total = nl * per_layer + 2 * V * d
    return total * 2 / 1e9


def _divides(n: int, mesh: Mesh, axes) -> bool:
    if not axes:
        return False
    size = int(np.prod([mesh.shape[a] for a in (
        axes if isinstance(axes, tuple) else (axes,))]))
    return n % size == 0


def _fsdp_axes(plan: ShardingPlan, dim: int) -> Any:
    """Batch-axes (pod+data) sharding for a dim if enabled & divisible."""
    if not plan.fsdp:
        return None
    ax = plan.batch_axes if len(plan.batch_axes) > 1 else plan.batch_axes[0]
    return ax if _divides(dim, plan.mesh, ax) else None


def _leaf_spec(path: str, leaf, plan: ShardingPlan, cfg: ModelConfig,
               for_opt: bool = False) -> P:
    """Spec for one (possibly scan-stacked) parameter leaf.

    Layer params carry 1–2 leading *stack* dims (lax.scan layout); rules
    apply to the logical trailing dims and FSDP prefers the stack dim
    (per-layer just-in-time gather — ZeRO-3 granularity) falling back to the
    logical in-dim when the stack size doesn't divide the batch axes.
    """
    shape = leaf.shape
    mesh = plan.mesh
    tp = plan.tp_axis
    name = path.rsplit("/", 1)[-1]
    distributed = plan.seq_axis is not None

    if len(shape) <= 1:
        return P()

    def fsdp_ax(dim_size: int):
        return _fsdp_axes(plan, dim_size)

    # --- embeddings / unembedding (top-level, unstacked [V, D]) ------------
    # Feature dim stays replicated: sharding it leaks a feature-sharded
    # layout into the activations (embedding gather output), which destroys
    # the batch sharding downstream. The vocab dim shards over `model` in
    # LOCAL mode but over `data` in distributed modes — there the sequence
    # owns the model axis, and an unsharded vocab makes the unembed-gradient
    # partials materialize as full [D, V] f32 per device.
    if name in _EMBED:
        v_ax = None
        if not distributed and _divides(shape[0], mesh, tp):
            v_ax = tp
        elif distributed:
            for cand in plan.batch_axes[::-1]:
                if _divides(shape[0], mesh, cand):
                    v_ax = cand
                    break
        d_ax = None
        if for_opt:
            cands = [a for a in (plan.batch_axes + (tp,)) if a != v_ax]
            d_ax = next((a for a in cands if _divides(shape[1], mesh, a)),
                        None)
        elif distributed and not plan.train and _divides(shape[1], mesh, tp):
            # inference: 2-D shard the table — GSPMD lowers a vocab-sharded
            # gather via a table-sized f32 select, so shrink the table shard
            # both ways; pin_activations re-gathers D right after the lookup
            # (one small AG instead of a 3 GB f32 select).
            d_ax = tp
        return P(v_ax, d_ax)

    # --- MoE expert weights: [..., E, D, F] / [..., E, F, D] ----------------
    if "moe/" in path and name in ("w_gate", "w_up", "w_down"):
        stack = len(shape) - 3
        e_ax = tp if _divides(shape[stack], mesh, tp) else None
        inner = fsdp_ax(shape[stack + 1])
        spec = [None] * stack + [e_ax, inner, None]
        return P(*spec)

    # --- MLA up-projections [..., r, H, dh] ---------------------------------
    if name in ("w_uk", "w_uv"):
        stack = len(shape) - 3
        head_ax = (tp if (not distributed
                          and _divides(shape[stack + 1], mesh, tp)) else None)
        r_ax = fsdp_ax(shape[stack]) if (plan.fsdp or for_opt) else None
        return P(*([None] * stack), r_ax, head_ax, None)

    # --- xLSTM sLSTM recurrent [4, H, dh, dh] (unstackable, small) ----------
    if name == "r":
        return P()

    def dense_spec(kind: str):
        """kind: 'col' (out dim TP) | 'row' (in dim TP)."""
        stack = len(shape) - 2
        d_in, d_out = shape[-2], shape[-1]
        tp_ok_out = _divides(d_out, mesh, tp)
        tp_ok_in = _divides(d_in, mesh, tp)
        # TP uses the model axis only in LOCAL mode. In distributed modes the
        # model axis carries the sequence: sharding an activation-adjacent
        # weight dim over it makes GSPMD un-shard the sequence (full-N
        # activations per device) — weights there shard over data only.
        # Decode is the exception: activations are [B, 1, D], so MLP TP over
        # model is conflict-free (the cache owns the seq axis, weights can
        # still use model for their own dims). Attention projections stay
        # off-model (head reshape).
        use_tp = (not distributed) or (plan.decode and name not in _ATTN)
        col_ax = tp if (use_tp and kind == "col" and tp_ok_out) else None
        row_ax = tp if (use_tp and kind == "row" and tp_ok_in) else None
        # FSDP: shard a LOGICAL dim (never the stack dim — lax.scan's
        # dynamic-slice over a sharded stack dim makes GSPMD replicate the
        # whole stacked tensor every iteration).
        spec = [None] * len(shape)
        if kind == "col" and col_ax is not None:
            spec[-1] = col_ax
        if kind == "row" and row_ax is not None:
            spec[-2] = row_ax
        if plan.fsdp or for_opt:
            if kind == "col" and fsdp_ax(d_in) is not None:
                spec[-2] = fsdp_ax(d_in)
            elif kind == "row" and spec[-1] is None and fsdp_ax(d_out) is not None:
                spec[-1] = fsdp_ax(d_out)
            elif spec[-1] is None and fsdp_ax(d_out) is not None:
                spec[-1] = fsdp_ax(d_out)
        if for_opt:
            # optimizer state additionally shards the other logical dim over
            # the model axis (ZeRO-1): the update is elementwise, so the
            # head-reshape / sequence-axis constraints that stop the PARAM
            # from using `model` don't apply to m/v.
            if kind == "col" and spec[-1] is None and tp_ok_out:
                spec[-1] = tp
            elif kind == "row" and spec[-2] is None and tp_ok_in:
                spec[-2] = tp
        return P(*spec)

    if name in _ROW:
        return dense_spec("row")
    if name in _COL or name in _ATTN or len(shape) >= 2:
        return dense_spec("col")
    return P()


def _opt_force_data(spec: P, leaf, plan: ShardingPlan) -> P:
    """ZeRO-1: ensure optimizer state is sharded over the batch axes on some
    dim even when the param itself is replicated."""
    if any(s is not None for s in spec):
        return spec
    shape = leaf.shape
    if len(shape) == 0:
        return P()
    ax = plan.batch_axes if len(plan.batch_axes) > 1 else (
        plan.batch_axes[0] if plan.batch_axes else None)
    if ax is None:
        return spec
    for i, dim in enumerate(shape):
        if _divides(dim, plan.mesh, ax):
            return P(*([None] * i), ax)
    return spec


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def param_shardings(plan: ShardingPlan, cfg: ModelConfig, params):
    """NamedSharding tree matching an (abstract) param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: plan.named(_leaf_spec(_path_str(p), l, plan, cfg)),
        params)


def opt_state_shardings(plan: ShardingPlan, cfg: ModelConfig, params):
    def spec(p, l):
        s = _leaf_spec(_path_str(p), l, plan, cfg, for_opt=True)
        return plan.named(_opt_force_data(s, l, plan))
    return jax.tree_util.tree_map_with_path(spec, params)


def _batch_ax(plan: ShardingPlan, dim: int):
    """Largest batch-axes group that divides ``dim`` (None if none does)."""
    cands = []
    if len(plan.batch_axes) > 1:
        cands.append(plan.batch_axes)
    cands.extend(plan.batch_axes)
    for c in cands:
        if _divides(dim, plan.mesh, c):
            return c
    return None


def _seq_ax(plan: ShardingPlan, dim: int):
    if plan.seq_axis and _divides(dim, plan.mesh, plan.seq_axis):
        return plan.seq_axis
    return None


def batch_shardings(plan: ShardingPlan, cfg: ModelConfig, specs,
                    kind: str):
    """Shardings for the input batch dict (tokens / labels / frames / ...)."""

    def one(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        bax = _batch_ax(plan, leaf.shape[0])
        if "tokens" in name or "labels" in name:
            if kind == "decode" or nd < 2 or leaf.shape[1] == 1:
                return plan.named(P(bax, None))
            return plan.named(P(bax, _seq_ax(plan, leaf.shape[1])))
        if "frames" in name or "image_embeds" in name:
            # memory: batch over data; memory length stays unsharded here —
            # the forward pads it, then partitions it (pad_len is known only
            # inside the model), so the raw stub input is replicated on seq.
            return plan.named(P(bax, None, None))
        if "images" in name:
            return plan.named(P(bax, None, None, None))
        return plan.named(P(*([bax] + [None] * (nd - 1))))

    return jax.tree_util.tree_map_with_path(one, specs)


def cache_shardings(plan: ShardingPlan, cfg: ModelConfig, cache):
    """Decode-cache shardings: [layers, B, S, ...] — batch over (pod, data),
    sequence over the model axis (flash-decoding merge), SSM states batch-
    sharded only."""

    def one(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if "mem_mask" in name:                     # [B, M]
            return plan.named(P(_batch_ax(plan, leaf.shape[0]),
                                _seq_ax(plan, leaf.shape[1])))
        if "mem_kv" in name:                       # [layers, B, M, Hk, dh]
            return plan.named(P(None, _batch_ax(plan, leaf.shape[1]),
                                _seq_ax(plan, leaf.shape[2]), None, None))
        if any(k in name for k in ("/k", "/v", "c_kv", "k_pe")) and nd >= 3:
            # [layers(, inner), B, S, ...] — S right after batch
            lead = 2 if nd > 5 else 1
            spec = [None] * nd
            spec[lead] = _batch_ax(plan, leaf.shape[lead])
            spec[lead + 1] = _seq_ax(plan, leaf.shape[lead + 1])
            return plan.named(P(*spec))
        # recurrent states: xlstm mLSTM stacks are [groups, n_m, B, ...]
        bdim = 2 if name.startswith("m/") else 1
        spec = [None] * nd
        if nd > bdim:
            spec[bdim] = _batch_ax(plan, leaf.shape[bdim])
        return plan.named(P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache)
