from repro.sharding.specs import (ShardingPlan, make_plan, param_shardings,
                                  batch_shardings, cache_shardings,
                                  opt_state_shardings)

__all__ = ["ShardingPlan", "make_plan", "param_shardings", "batch_shardings",
           "cache_shardings", "opt_state_shardings"]
