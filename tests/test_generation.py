"""Compiled generation fast path: parity with the legacy per-token loop,
single-pass prefill correctness, and the O(1)-dispatch regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExecutionPlan, InferenceSession
from repro.api import generation as gen
from repro.configs import get_config
from repro.models import registry
from repro.models import transformer as tfm


def _cfg(arch="llama3.2-1b", **over):
    return get_config(arch).reduced(vocab_size=64, **over)


@pytest.fixture(scope="module")
def dense():
    cfg = _cfg()
    return cfg, registry.init_params(cfg, seed=0)


def legacy_generate(params, prompt, n_new, cfg, xcfg, seed=0, T=0.0,
                    extras=None):
    """The seed implementation: one jitted decode dispatch per prompt token
    and per new token, host-side key splits — the parity oracle."""
    dec = jax.jit(lambda p, b, c, i: tfm.decode_step(p, b, c, i, cfg, xcfg))
    B, T0 = prompt.shape
    cache = tfm.init_decode_cache(cfg, B, T0 + n_new)
    if cfg.family in ("audio", "vlm"):
        cache = tfm.prefill_memory(params, {"tokens": prompt,
                                            **(extras or {})}, cfg, xcfg,
                                   cache)
    key = jax.random.key(seed)
    tok = prompt[:, :1]
    out = []
    for t in range(T0 + n_new - 1):
        logits, cache = dec(params, {"tokens": tok}, cache, t)
        if t + 1 < T0:
            tok = prompt[:, t + 1:t + 2]
        else:
            key, sub = jax.random.split(key)
            tok = gen.sample_token(logits, sub, T)[:, 0:1]
            out.append(tok)
        if len(out) >= n_new:
            break
    return jnp.concatenate(out, axis=1)


def _prompt(B=2, T0=5, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randint(0, 64, (B, T0)))


# --- parity: compiled engine == legacy loop --------------------------------

@pytest.mark.parametrize("prefill_mode", ["single_pass", "scan"])
def test_generate_parity_local(dense, prefill_mode):
    cfg, params = dense
    xcfg = ExecutionPlan.local().to_exchange_config()
    prompt = _prompt()
    ref = legacy_generate(params, prompt, 6, cfg, xcfg)
    fn = gen.build_generate_fn(cfg, xcfg, n_new=6, prefill_mode=prefill_mode)
    got = fn(params, prompt, {}, jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_generate_parity_prism_sim(dense):
    cfg, params = dense
    xcfg = ExecutionPlan.prism_sim(L=4).to_exchange_config()
    prompt = _prompt(B=1, T0=4)
    ref = legacy_generate(params, prompt, 5, cfg, xcfg)
    fn = gen.build_generate_fn(cfg, xcfg, n_new=5)
    assert fn.prefill_mode == "scan"    # compressed prefill is opt-in
    got = fn(params, prompt, {}, jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_generate_parity_temperature(dense):
    """Sampled decode threads the PRNG key exactly like the legacy loop."""
    cfg, params = dense
    xcfg = ExecutionPlan.local().to_exchange_config()
    prompt = _prompt()
    ref = legacy_generate(params, prompt, 6, cfg, xcfg, seed=3, T=1.0)
    fn = gen.build_generate_fn(cfg, xcfg, n_new=6, temperature=1.0)
    got = fn(params, prompt, {}, jax.random.key(3))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("arch", ["gemma2-27b", "deepseek-v2-236b",
                                  "hymba-1.5b", "xlstm-350m"])
def test_generate_parity_families(arch):
    """Windowed local/global dense, MLA MoE, hybrid and recurrent families
    all route through the engine (single-pass or scanned fallback)."""
    cfg = _cfg(arch)
    params = registry.init_params(cfg, seed=0)
    xcfg = ExecutionPlan.local().to_exchange_config()
    prompt = _prompt(B=1, T0=4, seed=len(arch))
    ref = legacy_generate(params, prompt, 4, cfg, xcfg)
    fn = gen.build_generate_fn(cfg, xcfg, n_new=4)
    # MoE capacity routing is seq-len dependent → auto keeps it scanned
    want = ("single_pass"
            if tfm.supports_prefill(cfg) and cfg.moe is None else "scan")
    assert fn.prefill_mode == want
    got = fn(params, prompt, {}, jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# --- single-pass prefill vs the full forward -------------------------------

@pytest.mark.parametrize("plan", [ExecutionPlan.local(),
                                  ExecutionPlan.prism_sim(L=4)])
def test_prefill_matches_forward_last_logits(dense, plan):
    """prefill() is forward_lm run once + bulk cache write: its logits must
    equal the full forward's last position under the SAME exchange (for
    prism_sim that is the compressed PRISM math, by design)."""
    cfg, params = dense
    xcfg = plan.to_exchange_config()
    T0 = 8                              # divisible into shards*L segments
    prompt = _prompt(B=1, T0=T0, seed=2)
    cache = tfm.init_decode_cache(cfg, 1, T0 + 2)
    logits, cache = tfm.prefill(params, {"tokens": prompt}, cache, cfg, xcfg)
    full, _ = tfm.forward_lm(params, {"tokens": prompt}, cfg, xcfg)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-5, rtol=1e-5)


def test_moe_single_pass_prefill_matches_forward():
    """MoE single-pass prefill is opt-in (capacity routing is seq-len
    dependent) and must reproduce the full forward's routing semantics."""
    cfg = _cfg("deepseek-v2-236b")
    params = registry.init_params(cfg, seed=0)
    xcfg = ExecutionPlan.local().to_exchange_config()
    prompt = _prompt(B=1, T0=6, seed=7)
    cache = tfm.init_decode_cache(cfg, 1, 8)
    logits, _ = tfm.prefill(params, {"tokens": prompt}, cache, cfg, xcfg)
    full, _ = tfm.forward_lm(params, {"tokens": prompt}, cfg, xcfg)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-5, rtol=1e-5)


def test_prefill_cache_matches_teacher_forced(dense):
    """Bulk-written prompt K/V == the cache T0 sequential decode steps
    build (decode continues identically from either)."""
    cfg, params = dense
    xcfg = ExecutionPlan.local().to_exchange_config()
    T0, S = 5, 8
    prompt = _prompt(B=1, T0=T0, seed=4)
    c_bulk = tfm.init_decode_cache(cfg, 1, S)
    _, c_bulk = tfm.prefill(params, {"tokens": prompt}, c_bulk, cfg, xcfg)
    c_seq = tfm.init_decode_cache(cfg, 1, S)
    for t in range(T0):
        _, c_seq = tfm.decode_step(params, {"tokens": prompt[:, t:t + 1]},
                                   c_seq, t, cfg, xcfg)
    for a, b in zip(jax.tree_util.tree_leaves(c_bulk),
                    jax.tree_util.tree_leaves(c_seq)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32)[:, :, :T0],
            np.asarray(b, np.float32)[:, :, :T0], atol=2e-2)


def test_prefill_rejects_recurrent_families():
    cfg = _cfg("xlstm-350m")
    assert not tfm.supports_prefill(cfg)
    with pytest.raises(ValueError, match="single-pass"):
        tfm.prefill({}, {"tokens": jnp.ones((1, 4), jnp.int32)}, {}, cfg,
                    ExecutionPlan.local().to_exchange_config())
    with pytest.raises(ValueError, match="single-pass"):
        gen.resolve_prefill_mode(cfg,
                                 ExecutionPlan.local().to_exchange_config(),
                                 "single_pass")


# --- O(1) dispatch regression ----------------------------------------------

def test_generation_dispatch_count_constant(dense):
    """The whole generation must execute a CONSTANT number of jitted
    callables (here: exactly one) regardless of prompt length and n_new —
    the seed implementation issued T0 + n_new - 1 of them."""
    cfg, params = dense
    sess = InferenceSession(cfg, params, [ExecutionPlan.local()])
    counts = []
    for T0, n_new in ((3, 4), (9, 4), (3, 24), (9, 24)):
        before = gen.dispatch_count()
        out = sess.generate(_prompt(B=1, T0=T0), n_new=n_new)
        counts.append(gen.dispatch_count() - before)
        assert out.shape == (1, n_new)
    assert counts == [1, 1, 1, 1], counts


def test_generation_executables_cached(dense):
    """Repeat shapes reuse the compiled executable; new shapes add one."""
    cfg, params = dense
    sess = InferenceSession(cfg, params, [ExecutionPlan.local()])
    before = gen.build_count()
    sess.generate(_prompt(), n_new=4)
    sess.generate(_prompt(seed=9), n_new=4)      # same shape, new data
    assert gen.build_count() - before == 1
    sess.generate(_prompt(), n_new=5)            # new shape
    assert gen.build_count() - before == 2


def test_generate_n_new_zero(dense):
    cfg, params = dense
    sess = InferenceSession(cfg, params, [ExecutionPlan.local()])
    assert sess.generate(_prompt(), n_new=0).shape == (2, 0)


def test_codec_default_generation_token_exact(dense):
    """The codec refactor must not perturb generation: a prism_sim plan
    (implicit segment_means codec) and the same plan spelled with the
    codec explicit share one identity and produce identical tokens."""
    cfg, params = dense
    implicit = ExecutionPlan.prism_sim(L=2, cr=4.0)
    explicit = ExecutionPlan("prism_sim", 4.0, 2, "seq", 2,
                             codec="segment_means")
    assert explicit == implicit and explicit.key == implicit.key
    sess = InferenceSession(cfg, params, [implicit])
    prompt = _prompt()
    out = sess.generate(prompt, n_new=4, plan=implicit)
    ref = legacy_generate(params, prompt, 4, cfg,
                          implicit.to_exchange_config())
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
