"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + no NaNs; prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.exchange import ExchangeConfig, ExchangeMode
from repro.models import registry
from repro.models import transformer as tfm

XLOC = ExchangeConfig(ExchangeMode.LOCAL)
B, N = 2, 32


def _batch(cfg):
    batch = {"tokens": jnp.asarray(
        np.random.RandomState(0).randint(0, cfg.vocab_size, (B, N)))}
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model),
                                   cfg.jdtype)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.ones((B, cfg.image_tokens, cfg.d_model),
                                         cfg.jdtype)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_forward(arch):
    cfg = get_config(arch).reduced()
    params = registry.init_params(cfg, seed=0)
    logits, aux = registry.forward_fn(cfg)(params, _batch(cfg), XLOC)
    assert logits.shape == (B, N, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_decode(arch):
    cfg = get_config(arch).reduced()
    params = registry.init_params(cfg, seed=0)
    cache = tfm.init_decode_cache(cfg, B, N)
    cache = tfm.prefill_memory(params, _batch(cfg), cfg, XLOC, cache)
    logits, cache2 = tfm.decode_step(
        params, {"tokens": jnp.ones((B, 1), jnp.int32)}, cache, 0, cfg, XLOC)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_train_step(arch):
    """One real gradient step on the reduced config; finite loss & grads."""
    from repro.train.optimizer import adamw_init
    from repro.train.train_step import build_train_step
    cfg = get_config(arch).reduced()
    params = registry.init_params(cfg, seed=0)
    opt = adamw_init(params)
    batch = _batch(cfg)
    batch["labels"] = batch["tokens"]
    step = build_train_step(cfg, XLOC)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert jnp.isfinite(metrics["grad_norm"]), arch
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l.astype(jnp.float32)))),
        jax.tree_util.tree_map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            params2, params), 0.0)
    assert moved > 0.0, arch


@pytest.mark.parametrize("arch", ["llama3.2-1b", "hymba-1.5b", "xlstm-350m"])
def test_prefill_decode_consistency(arch):
    """Teacher-forced decode reproduces the forward logits step by step —
    validates cache correctness for attention, hybrid and recurrent paths."""
    cfg = get_config(arch).reduced()
    params = registry.init_params(cfg, seed=0)
    toks = jnp.asarray(np.random.RandomState(1).randint(
        0, cfg.vocab_size, (1, 8)))
    logits_full, _ = registry.forward_fn(cfg)(params, {"tokens": toks}, XLOC)
    cache = tfm.init_decode_cache(cfg, 1, 8)
    outs = []
    for t in range(8):
        lg, cache = tfm.decode_step(params, {"tokens": toks[:, t:t + 1]},
                                    cache, t, cfg, XLOC)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               atol=2e-2, rtol=2e-2)


def test_vit_forward():
    cfg = get_config("vit-base-16").reduced()
    params = registry.init_params(cfg, seed=0)
    imgs = jnp.asarray(np.random.RandomState(0).rand(2, 224, 224, 3),
                       jnp.float32)
    logits, _ = registry.forward_fn(cfg)(params, {"images": imgs}, XLOC)
    assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_vit_prism_sim_close_to_local():
    """PRISM_SIM (P=2, generous L) approximates full attention on ViT —
    the paper's accuracy-preservation mechanism at low CR."""
    cfg = get_config("vit-base-16").reduced(n_layers=2)
    params = registry.init_params(cfg, seed=0)
    imgs = jnp.asarray(np.random.RandomState(0).rand(2, 224, 224, 3),
                       jnp.float32)
    lg_full, _ = registry.forward_fn(cfg)(params, {"images": imgs}, XLOC)
    xp = ExchangeConfig(ExchangeMode.PRISM_SIM, "seq", 2, L=50)
    lg_prism, _ = registry.forward_fn(cfg)(params, {"images": imgs}, xp)
    # agreement in prediction, not bitwise
    assert jnp.array_equal(jnp.argmax(lg_full, -1), jnp.argmax(lg_prism, -1))


def test_gemma_window_masking():
    """Local layers must not attend beyond the sliding window."""
    from repro.core.prism_attention import reference_attention
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(1, 16, 2, 8), jnp.float32)
    k = jnp.asarray(rng.randn(1, 16, 2, 8), jnp.float32)
    v = jnp.asarray(rng.randn(1, 16, 2, 8), jnp.float32)
    out_w = reference_attention(q, k, v, causal=True, window=4)
    # perturbing keys outside the window of the last query changes nothing
    k2 = k.at[:, :8].set(rng.randn(1, 8, 2, 8))
    out_w2 = reference_attention(q, k2, v, causal=True, window=4)
    np.testing.assert_allclose(np.asarray(out_w[:, -1]),
                               np.asarray(out_w2[:, -1]), atol=1e-6)
