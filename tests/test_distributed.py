"""Distributed correctness via subprocesses (8 host devices per process, so
the XLA device-count flag never leaks into this pytest process — smoke
tests here see 1 device, per the dry-run contract)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run([sys.executable, os.path.join(ROOT, script)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)


@pytest.mark.slow
def test_exchange_shard_map_equivalences():
    """shard_map PRISM/Voltage/decode == single-host oracles (8 devices)."""
    r = _run("scripts/sanity_exchange.py")
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "ALL SANITY PASSED" in r.stdout


@pytest.mark.slow
def test_e2e_distributed_train_and_decode():
    """PRISM/Voltage train steps + sharded decode on a (4×2) mesh."""
    r = _run("scripts/sanity_e2e_distributed.py")
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "E2E DISTRIBUTED SANITY PASSED" in r.stdout
