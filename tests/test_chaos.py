"""Chaos tier: seeded fault schedules, the controller choke point, and the
retry/timeout/breaker/readmission machinery they exercise.

Injection is deterministic by construction (all randomness at schedule
build time), so every test here asserts exact state transitions — armed
faults fire exactly once, flaps restore the pre-flap bandwidth, a death is
consumed exactly once, a revived worker re-profiles before placement
trusts it again.
"""
import numpy as np
import pytest

from repro.api import ExecutionPlan, InferenceSession
from repro.chaos import (ChaosController, ChaosEvent, DispatchFault,
                         FaultSchedule)
from repro.fleet import (DeviceRegistry, FleetRejected, FleetRouter,
                         ReadmissionEvent, SimWorker, WorkerHandle,
                         scaled_hardware)
from repro.profiling import ProfileContext, SweepSpec, get_backend
from repro.profiling.hardware import JETSON_ORIN_NANO
from repro.runtime.fault import (CircuitBreaker, HeartbeatMonitor,
                                 RetryPolicy)
from repro.serving.queue import Request, RequestQueue
from repro.transport.codecs import codec_overrides, get_codec, list_codecs
from repro.utils.bandwidth import BandwidthWalk


def _prompt(T0, seed=0):
    return np.random.RandomState(seed).randint(0, 64, T0)


# one simulated sweep per hardware speed grade, shared across tests
_PM_CACHE = {}


def _sim_worker(name, factor=1.0, **kw):
    if factor not in _PM_CACHE:
        hw = scaled_hardware(JETSON_ORIN_NANO, factor)
        pm = get_backend("simulated").profile(ProfileContext(hardware=hw),
                                              SweepSpec())
        _PM_CACHE[factor] = (hw, pm)
    hw, pm = _PM_CACHE[factor]
    return SimWorker(name, perfmap=pm, hardware=hw, **kw)


def _fleet(names, **kw):
    reg = DeviceRegistry(heartbeat_timeout_s=1e9)
    for n in names:
        reg.add(_sim_worker(n, **kw))
    return reg


def _req(n_new=2, arrival_ts=0.0, **kw):
    return Request(prompt=_prompt(8), n_new=n_new, arrival_ts=arrival_ts,
                   **kw)


# --- schedules ---------------------------------------------------------------

def test_schedule_sorts_and_composes():
    sched = FaultSchedule().add(FaultSchedule.revive("a", 3.0),
                                FaultSchedule.kill("a", 1.0))
    assert [e.kind for e in sched] == ["kill", "revive"]
    merged = sched + FaultSchedule([FaultSchedule.stall("b", 2.0, 0.5)])
    assert [(e.t, e.kind) for e in merged] == [
        (1.0, "kill"), (2.0, "stall"), (3.0, "revive")]
    assert len(merged) == 3


def test_chaos_event_validation():
    with pytest.raises(ValueError, match="unknown chaos event kind"):
        ChaosEvent(0.0, "explode", "a")
    with pytest.raises(ValueError, match="must be >= 0"):
        ChaosEvent(-1.0, "kill", "a")


def test_schedule_parse_all_kinds():
    sched = FaultSchedule.parse(
        "kill:b@1; revive:b@3; bw:a@0.5:250; flap:c@2:0.5:5;"
        " stall:a@2:0.25; straggle:c@1:3; error:c@1.5:0.1;"
        " drift:a@4:600->60:2")
    assert len(sched) == 7 + 16          # drift expands to 16 bw events
    times = [e.t for e in sched]
    assert times == sorted(times)
    by_kind = {}
    for e in sched:
        by_kind.setdefault(e.kind, []).append(e)
    assert by_kind["kill"][0].target == "b"
    assert by_kind["flap"][0].value == 5.0
    assert by_kind["flap"][0].duration == 0.5
    assert by_kind["straggle"][0].value == 3.0
    assert by_kind["error"][0].value == 0.1
    assert len(by_kind["bandwidth"]) == 17    # 1 explicit + 16 drift
    with pytest.raises(ValueError, match="bad chaos clause"):
        FaultSchedule.parse("bogus")
    with pytest.raises(ValueError, match="unknown chaos kind"):
        FaultSchedule.parse("wibble:a@1")


def test_drift_is_seed_deterministic():
    a = FaultSchedule.drift("a", 0.0, 8.0, 600.0, 60.0, seed=3)
    b = FaultSchedule.drift("a", 0.0, 8.0, 600.0, 60.0, seed=3)
    c = FaultSchedule.drift("a", 0.0, 8.0, 600.0, 60.0, seed=4)
    assert [(e.t, e.value) for e in a] == [(e.t, e.value) for e in b]
    assert [e.value for e in a] != [e.value for e in c]
    assert all(e.kind == "bandwidth" for e in a)
    with pytest.raises(ValueError, match="t1 > t0"):
        FaultSchedule.drift("a", 2.0, 2.0, 600.0, 60.0)


def test_bandwidth_walk():
    w = BandwidthWalk(600.0, 60.0, seed=5, jitter=0.1)
    assert w.at(0.0) == pytest.approx(600.0, rel=0.1)
    assert w.at(1.0) == pytest.approx(60.0, rel=0.1)
    assert w.at(-3.0) == w.at(0.0) and w.at(9.0) == w.at(1.0)
    assert w.sample(8) == BandwidthWalk(600.0, 60.0, seed=5,
                                        jitter=0.1).sample(8)
    with pytest.raises(ValueError, match="jitter"):
        BandwidthWalk(600.0, 60.0, jitter=1.0)
    with pytest.raises(ValueError, match="endpoints"):
        BandwidthWalk(0.0, 60.0)


# --- retry policy + circuit breaker ------------------------------------------

def test_retry_policy_backoff_and_cap():
    p = RetryPolicy(max_retries=3, backoff_base_s=0.05, backoff_mult=2.0)
    assert [p.backoff_s(k) for k in range(3)] == [0.05, 0.1, 0.2]
    assert p.backoff_s(-4) == 0.05           # clamped to attempt 0
    # uncapped doubling would be 0.05 * 2^100 seconds — the cap holds
    assert p.backoff_s(100) == p.backoff_cap_s == 30.0
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_mult=0.5)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_cap_s=0.0)


def test_circuit_breaker_state_machine():
    br = CircuitBreaker(fail_threshold=2, reset_timeout_s=1.0)
    assert br.state == "closed" and br.allows(0.0)
    assert not br.record_failure(0.0)           # 1/2 — still closed
    assert br.record_failure(0.1)               # 2/2 — newly opened
    assert br.state == "open" and br.opened_total == 1
    assert not br.allows(0.5)                   # inside the reset window
    br.record_success(0.5)                      # draining old work: ignored
    assert br.state == "open"
    assert br.allows(1.2)                       # window elapsed → probe
    assert br.state == "half_open"
    assert br.record_failure(1.3)               # failed probe re-opens
    assert br.state == "open" and br.opened_total == 2
    assert br.allows(2.5) and br.state == "half_open"
    br.record_success(2.5)                      # probe succeeded
    assert br.state == "closed" and br.failures == 0
    br.record_failure(3.0)
    br.record_success(3.1)                      # closed success resets count
    assert br.failures == 0
    br.record_failure(4.0)
    br.reset()
    assert br.state == "closed" and br.failures == 0
    assert br.snapshot() == {"state": "closed", "failures": 0,
                             "opened_total": 2}
    with pytest.raises(ValueError):
        CircuitBreaker(fail_threshold=0)


# --- controller --------------------------------------------------------------

def test_controller_attaches_and_replays_kill_revive():
    reg = _fleet(["a", "b"])
    sched = FaultSchedule().add(FaultSchedule.kill("a", 1.0),
                                FaultSchedule.revive("a", 2.0))
    chaos = ChaosController(reg, sched)
    assert reg.get("a").chaos is chaos          # attach wired the worker
    before = reg.get("a").profiled_count
    for _, fn in chaos.events():
        fn()
    assert reg.is_alive("a")
    # registry-level revive goes through full readmission → re-profile
    assert reg.get("a").profiled_count == before + 1
    assert chaos.log == [[1.0, "kill", "a", 0.0], [2.0, "revive", "a", 0.0]]


def test_controller_flap_restores_preflap_bandwidth():
    reg = _fleet(["a"])
    w = reg.get("a")
    w.observe_bandwidth(500.0)
    chaos = ChaosController(
        reg, FaultSchedule([FaultSchedule.flap("a", 1.0, 0.5,
                                               floor_mbps=2.0)]))
    evs = chaos.events()
    assert [t for t, _ in evs] == [1.0, 1.5]    # down + restore
    evs[0][1]()
    assert w.bandwidth == 2.0
    evs[1][1]()
    assert w.bandwidth == 500.0
    assert [row[1] for row in chaos.log] == ["flap_down", "flap_up"]


def test_dispatch_fault_armed_fires_exactly_once():
    reg = _fleet(["a"])
    chaos = ChaosController(reg, FaultSchedule())
    chaos.apply(FaultSchedule.straggle("a", 1.0, 4.0))
    assert chaos.pending_faults == 1
    assert chaos.dispatch_fault("a", 0.5) is None     # not due yet
    assert chaos.dispatch_fault("b", 2.0) is None     # wrong worker
    ev = chaos.dispatch_fault("a", 1.2)
    assert ev is not None and ev.kind == "straggle" and ev.value == 4.0
    assert chaos.dispatch_fault("a", 2.0) is None     # consumed
    assert chaos.pending_faults == 0
    assert [row[1] for row in chaos.log] == ["arm_straggle",
                                             "hit_straggle"]


# --- SimWorker fault paths ---------------------------------------------------

def test_simworker_transport_error_requeues_with_backoff():
    retry = RetryPolicy(max_retries=2, backoff_base_s=0.5)
    reg = DeviceRegistry(heartbeat_timeout_s=1e9)
    w = reg.add(_sim_worker("a", retry=retry))
    chaos = ChaosController(reg, FaultSchedule())
    chaos.apply(FaultSchedule.transport_error("a", 0.0, abort_s=0.01))
    req = _req()
    w.submit_request(req)
    w.step(0.0)                        # admit → armed error dooms dispatch
    assert w.in_flight == 1
    assert w.step(0.02) == []          # aborts, no completion
    faults = w.pop_faults()
    assert len(faults) == 1
    assert faults[0].kind == "error" and faults[0].retried == (req.id,)
    assert faults[0].gave_up == ()
    assert w.pop_faults() == []        # consume pattern
    assert len(w.queue) == 1           # requeued locally
    # exponential backoff: no admission until the backoff window passes
    assert w.next_event_at(0.02) == pytest.approx(0.01 + 0.5)
    w.step(0.1)
    assert w.in_flight == 0
    w.step(0.6)                        # backoff elapsed, fault consumed
    assert w.in_flight == 1
    snap = w.stats_snapshot()
    assert snap["transport_errors"] == 1 and snap["retries"] == 1


def test_simworker_gives_up_past_retry_budget():
    reg = DeviceRegistry(heartbeat_timeout_s=1e9)
    w = reg.add(_sim_worker("a", retry=RetryPolicy(max_retries=0)))
    chaos = ChaosController(reg, FaultSchedule())
    chaos.apply(FaultSchedule.transport_error("a", 0.0, abort_s=0.01))
    req = _req()
    w.submit_request(req)
    w.step(0.0)
    w.step(0.02)
    faults = w.pop_faults()
    assert faults[0].gave_up == (req,) and faults[0].retried == ()
    assert len(w.queue) == 0           # handed back, not requeued
    assert w.stats_snapshot()["gave_up"] == 1


def test_simworker_dispatch_timeout():
    w = _sim_worker("a", dispatch_timeout_s=1e-4)
    w.submit_request(_req())
    w.step(0.0)                        # any real service exceeds 0.1 ms
    assert w._busy_until == pytest.approx(1e-4)
    assert w.step(1.0) == []
    faults = w.pop_faults()
    assert faults[0].kind == "timeout"
    assert w.stats_snapshot()["timeouts"] == 1


def test_simworker_straggle_inflates_service():
    reg = DeviceRegistry(heartbeat_timeout_s=1e9)
    w = reg.add(_sim_worker("a"))
    w.submit_request(_req())
    w.step(0.0)
    base = w._busy_until
    w.drain_requests()
    chaos = ChaosController(reg, FaultSchedule())
    chaos.apply(FaultSchedule.straggle("a", 0.0, 3.0))
    w.submit_request(_req(arrival_ts=10.0))
    w.step(10.0)
    assert w._busy_until - 10.0 == pytest.approx(3.0 * base)
    assert w.stats_snapshot()["straggled"] == 1


def test_simworker_stall_defers_admission_and_extends_service():
    w = _sim_worker("a")
    w.submit_request(_req())
    w.apply_stall(0.0, 1.0)
    w.step(0.5)
    assert w.in_flight == 0            # stalled: nothing admitted
    assert w.next_event_at(0.5) == 1.0
    w.step(1.0)
    assert w.in_flight == 1
    busy = w._busy_until
    w.apply_stall(1.1, 0.5)            # mid-service stall finishes late
    assert w._busy_until == pytest.approx(busy + 0.5)


def test_static_worker_plans_frozen_but_pays_true_bandwidth():
    w = _sim_worker("a", adaptive=False, bandwidth_mbps=600.0)
    w.observe_bandwidth(30.0)          # link degraded after planning froze
    table = w.table()
    bp = table.plan_batch(1, 600.0, max_batch=4)   # the frozen plan
    d = bp.decision
    true_ms = next(exp.total_ms
                   for key, exp in table.candidates(bp.batch, 30.0)
                   if (key.mode, key.cr, key.codec)
                   == (d.mode, d.cr, d.codec))
    req = _req(n_new=4)
    w.submit_request(req)
    w.step(0.0)
    assert w._service_key == d.exec_key            # planned at 600 Mbps
    assert w._busy_until == pytest.approx(1e-3 * true_ms * 4)
    # an adaptive twin re-plans at the live link instead
    wa = _sim_worker("b", adaptive=True, bandwidth_mbps=600.0)
    wa.observe_bandwidth(30.0)
    bpa = wa.table().plan_batch(1, 30.0, max_batch=4)
    wa.submit_request(_req(n_new=4))
    wa.step(0.0)
    assert wa._busy_until == pytest.approx(
        1e-3 * bpa.decision.expected.total_ms * 4)


# --- router: breakers, placement retries, re-placement, readmission ----------

def test_router_skips_breaker_open_workers():
    reg = _fleet(["a", "b"])
    router = FleetRouter(reg, clock=lambda: 0.0, breaker_threshold=1,
                         breaker_reset_s=5.0)
    router.breaker("a").record_failure(0.0)        # threshold 1 → open
    assert [s.worker for s in router.rank(now=0.0)] == ["b"]
    req, rec = router.submit(_prompt(8), 2)
    assert rec.worker == "b"
    # pinned to a breaker-open worker: shed with the machine reason
    with pytest.raises(FleetRejected) as ei:
        router.route(_req(), pin="a", now=0.0)
    assert ei.value.reason == "breaker_open"
    assert reg.get("a").queue.rejections["breaker_open"] == 1
    # every live worker blocked → breaker_open, not no_workers
    router.breaker("b").record_failure(0.0)
    with pytest.raises(FleetRejected) as ei:
        router.route(_req(), now=0.0)
    assert ei.value.reason == "breaker_open"
    # past the reset window both half-open and placement resumes
    assert {s.worker for s in router.rank(now=10.0)} == {"a", "b"}


def test_drive_virtual_retries_rejected_placements():
    reg = DeviceRegistry(heartbeat_timeout_s=1e9)
    reg.add(_sim_worker("a", n_slots=1, queue_size=1))
    router = FleetRouter(
        reg, retry=RetryPolicy(max_retries=10, backoff_base_s=0.2),
        clock=lambda: 0.0)
    reqs = [_req(n_new=1, arrival_ts=0.0) for _ in range(4)]
    out = router.drive_virtual(reqs)
    assert len(out["completions"]) == 4 and out["shed"] == []
    assert router.stats["placement_retries"] >= 3


def test_drive_virtual_without_retry_sheds_immediately():
    reg = DeviceRegistry(heartbeat_timeout_s=1e9)
    reg.add(_sim_worker("a", n_slots=1, queue_size=1))
    router = FleetRouter(reg, clock=lambda: 0.0)    # retry=None: one shot
    reqs = [_req(n_new=1, arrival_ts=0.0) for _ in range(4)]
    out = router.drive_virtual(reqs)
    assert len(out["shed"]) > 0
    assert router.stats["placement_retries"] == 0


def test_router_replaces_gave_up_requests_on_survivor():
    reg = _fleet(["a", "b"])
    reg.get("a").retry = RetryPolicy(max_retries=0)
    router = FleetRouter(reg, clock=lambda: 0.0, breaker_threshold=1)
    chaos = ChaosController(reg, FaultSchedule(), router=router)
    chaos.apply(FaultSchedule.transport_error("a", 0.0, abort_s=0.01))
    out = router.drive_virtual([_req(n_new=1, arrival_ts=0.0)])
    assert len(out["completions"]) == 1
    assert out["completions"][0].worker == "b"      # re-placed after a's abort
    snap = router.stats_snapshot()
    assert snap["gave_up"] == 1 and snap["transport_errors"] == 1
    assert snap["breaker_opened"] == 1
    assert snap["breakers"]["a"]["opened_total"] == 1


def test_router_counts_lost_when_no_survivor():
    reg = _fleet(["a"])
    reg.get("a").retry = RetryPolicy(max_retries=0)
    router = FleetRouter(reg, clock=lambda: 0.0)
    chaos = ChaosController(reg, FaultSchedule(), router=router)
    chaos.apply(FaultSchedule.transport_error("a", 0.0, abort_s=0.01))
    out = router.drive_virtual([_req(n_new=1, arrival_ts=0.0)])
    assert out["completions"] == []
    assert router.stats["lost"] == 1 and router.stats["gave_up"] == 1


def test_readmit_resets_breaker_and_reprofiles():
    reg = _fleet(["a"])
    w = reg.get("a")
    router = FleetRouter(reg, clock=lambda: 0.0, breaker_threshold=1)
    reg.fail("a")
    assert reg.check_dead() == ["a"]
    router.breaker("a").record_failure(0.0)
    before = w.profiled_count
    got = router.readmit("a", now=1.5)
    assert got is w and reg.is_alive("a")
    assert w.profiled_count == before + 1
    assert router.breaker("a").state == "closed"
    evs = [e for e in router.events if isinstance(e, ReadmissionEvent)]
    assert len(evs) == 1 and evs[0].worker == "a" and evs[0].at == 1.5
    snap = router.stats_snapshot()
    assert snap["readmitted"] == 1 and snap["readmissions"] == 1


def test_router_telemetry_keys():
    router = FleetRouter(_fleet(["a"]), clock=lambda: 0.0)
    snap = router.stats_snapshot()
    for key in ("retries", "timeouts", "transport_errors", "gave_up",
                "placement_retries", "breaker_opened", "readmitted",
                "failovers", "readmissions", "breakers"):
        assert key in snap, key


# --- satellite: liveness invariants ------------------------------------------

def test_heartbeat_revive_restarts_deadline():
    t = [0.0]
    mon = HeartbeatMonitor(["a"], timeout_s=5.0, clock=lambda: t[0])
    mon.fail("a")
    mon.beat("a")                          # beats ignored while failed
    assert mon.dead_nodes() == ["a"]
    t[0] = 100.0
    mon.revive("a")                        # clears failure AND re-arms
    assert mon.dead_nodes() == []
    t[0] = 104.0
    assert mon.dead_nodes() == []          # deadline restarted at revive
    t[0] = 106.0
    assert mon.dead_nodes() == ["a"]       # then expires normally


def test_check_dead_consumes_each_death_exactly_once_seeded():
    """Property-style: under seeded interleaved beat/fail/revive traffic, a
    worker is reported by ``check_dead`` at most once per revival."""
    rng = np.random.RandomState(1234)
    t = [0.0]
    reg = DeviceRegistry(heartbeat_timeout_s=5.0, clock=lambda: t[0])
    names = ["a", "b", "c"]
    for n in names:
        reg.add(_sim_worker(n))
    reported_since_revive = set()
    reports = {n: 0 for n in names}
    revives = {n: 0 for n in names}
    for _ in range(300):
        t[0] += rng.uniform(0.0, 2.0)
        for n in names:
            if rng.rand() < 0.8:
                reg.beat(n)
        if rng.rand() < 0.15:
            reg.fail(rng.choice(names))
        if rng.rand() < 0.3:
            dead = reg.dead()
            if dead:
                n = rng.choice(dead)
                reg.revive(n)
                revives[n] += 1
                reported_since_revive.discard(n)
        for n in reg.check_dead():
            assert n not in reported_since_revive, \
                f"{n} reported dead twice without an intervening revive"
            reported_since_revive.add(n)
            reports[n] += 1
    for n in names:
        assert reports[n] <= revives[n] + 1


# --- satellite: shed-on-expired ----------------------------------------------

def test_queue_shed_expired_is_opt_in():
    q = RequestQueue(8)                     # default: late work dispatches
    r = _req(slo_ms=10.0)
    q.put(r)
    assert q.pop(now=5.0) is r
    assert q.rejections == {}

    q2 = RequestQueue(8, shed_expired=True)
    late = _req(slo_ms=10.0)
    ok = _req(slo_ms=10_000.0)
    q2.put(late)
    q2.put(ok)
    assert q2.pop(now=5.0) is ok            # deadline-passed work dropped
    assert q2.expired == [late]
    assert q2.rejections["expired"] == 1
    q2.put(_req(slo_ms=1.0))
    assert q2.pop_many(4, now=5.0) == []    # only expired left → nothing
    assert q2.rejections["expired"] == 2


def test_simworker_shed_expired_surfaces_in_stats():
    w = _sim_worker("a", shed_expired=True)
    w.submit_request(_req(slo_ms=10.0, arrival_ts=0.0))
    w.step(5.0)                             # expired before admission
    assert w.in_flight == 0
    assert w.stats_snapshot()["expired"] == 1


# --- satellite: per-device codec calibration ---------------------------------

def _measurable_codec():
    return next(n for n in list_codecs()
                if type(get_codec(n)).decode_bw > 0
                and not get_codec(n).summarizing)


def test_codec_overrides_install_and_restore_exactly():
    name = _measurable_codec()
    codec = get_codec(name)
    before = (codec.__dict__.get("decode_bw"),
              codec.__dict__.get("decode_bw_measured"))
    with codec_overrides({name: 123.0}):
        assert get_codec(name).decode_bw == 123.0
        assert get_codec(name).decode_bw_measured
    after = (codec.__dict__.get("decode_bw"),
             codec.__dict__.get("decode_bw_measured"))
    assert after == before


def test_device_codec_bws_scale_with_hardware():
    name = _measurable_codec()
    reg = DeviceRegistry(heartbeat_timeout_s=1e9)
    reg.codec_bws = {name: 1e9}            # pretend the host measured 1 GB/s
    w = _sim_worker("slow", factor=0.5)
    assert reg.device_codec_bws(w)[name] == pytest.approx(0.5e9)
    before = w.profiled_count
    reg.add(w)                             # add() calibrates + re-profiles
    assert w.codec_bws[name] == pytest.approx(0.5e9)
    assert w.profiled_count == before + 1


def test_readmit_recalibrates_codecs_for_the_device():
    name = _measurable_codec()
    reg = DeviceRegistry(heartbeat_timeout_s=1e9)
    w = reg.add(_sim_worker("slow", factor=0.5))
    reg.codec_bws = {name: 2e9}            # host calibration after add()
    reg.fail("slow")
    assert reg.check_dead() == ["slow"]
    before = w.profiled_count
    reg.readmit("slow")
    assert reg.is_alive("slow")
    assert w.codec_bws[name] == pytest.approx(1e9)   # re-scaled on revive
    assert w.profiled_count == before + 1
    # opting out leaves the profile untouched (plain revive)
    reg.fail("slow")
    reg.check_dead()
    reg.readmit("slow", recalibrate=False, reprofile=False)
    assert w.profiled_count == before + 1


# --- real-worker chaos hook --------------------------------------------------

def test_serving_runtime_consumes_dispatch_faults():
    s = InferenceSession.from_config(
        "llama3.2-1b", reduced={"vocab_size": 64},
        plans=[ExecutionPlan.local()])
    s.profile(backend="simulated")
    reg = DeviceRegistry(heartbeat_timeout_s=1e9)
    w = reg.add(WorkerHandle("w", s, n_slots=2, max_len=64))
    chaos = ChaosController(reg, FaultSchedule())
    assert w.runtime.chaos is chaos        # attach wired through the runtime
    chaos.apply(FaultSchedule.straggle("w", 0.0, 3.0))
    chaos.apply(FaultSchedule.transport_error("w", 0.0))
    router = FleetRouter(reg)
    # >1 decode chunk (chunk=8), so the error fault hits a later dispatch
    placed = router.fanout([_prompt(6)], 20)
    assert placed[0][1] is not None
    router.run()
    comp = router.completion_for(placed[0][0].id)
    assert comp is not None and len(comp.tokens) == 20  # aborts don't lose
    snap = w.runtime.stats_snapshot()
    assert snap["straggled"] == 1 and snap["retries"] == 1
    for key in ("expired", "failovers"):
        assert key in snap, key
    assert chaos.pending_faults == 0


def test_chaos_kill_reaches_a_real_process_boundary():
    """A ``kill`` event on a process-backed worker must SIGKILL the
    subprocess (not just flip registry membership): the controller calls
    ``kill_process`` when the worker exposes one and marks the worker
    unhealthy so ``readmit`` knows to respawn it."""
    reg = DeviceRegistry(heartbeat_timeout_s=1e9)
    w = reg.add(_sim_worker("proc", factor=1.0))
    killed = []
    w.kill_process = lambda: killed.append(True)
    chaos = ChaosController(reg, FaultSchedule())
    chaos.apply(ChaosEvent(0.5, "kill", "proc"))
    assert killed == [True]                 # the process died for real
    assert w.healthy is False               # recorded for readmission
    assert not reg.is_alive("proc")
    assert ["kill", "proc"] in [[r[1], r[2]] for r in chaos.log]


def test_chaos_kill_on_sim_worker_is_membership_only():
    """SimWorkers have no subprocess — kill stays a membership change and
    leaves ``healthy`` alone (the model does not pretend a process died)."""
    reg = DeviceRegistry(heartbeat_timeout_s=1e9)
    w = reg.add(_sim_worker("sim", factor=1.0))
    chaos = ChaosController(reg, FaultSchedule())
    chaos.apply(ChaosEvent(0.5, "kill", "sim"))
    assert w.healthy is True
    assert not reg.is_alive("sim")
