"""The loop-aware HLO roofline analyzer vs known-cost programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo_text


def _cost(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return analyze_hlo_text(compiled.as_text())


def test_matmul_flops():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = _cost(lambda x, y: x @ y, a, b)
    expect = 2 * 128 * 256 * 512
    assert c.flops == pytest.approx(expect, rel=0.05)


def test_scan_multiplies_by_trip_count():
    """The reason this analyzer exists: XLA's cost_analysis counts while
    bodies once; ours multiplies by known_trip_count."""
    n_layers = 17

    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((n_layers, 128, 128), jnp.float32)
    c = _cost(f, x, ws)
    expect = n_layers * 2 * 64 * 128 * 128
    assert c.flops == pytest.approx(expect, rel=0.10)


def test_bytes_slice_aware():
    """A scan that slices one [128,128] weight per step must charge the
    slice, not the full stacked array, per iteration."""
    n = 16

    def f(x, ws):
        def body(h, w):
            return h * 1.0 + w[0, 0], None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    ws = jax.ShapeDtypeStruct((n, 128, 128), jnp.float32)
    c = _cost(f, x, ws)
    full_per_iter = n * (n * 128 * 128 * 4)      # what naive counting gives
    assert c.bytes < full_per_iter / 2


def test_nested_scan():
    def f(x, ws):
        def outer(h, w):
            def inner(g, _):
                return g @ w, None
            g, _ = jax.lax.scan(inner, h, None, length=3)
            return g, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    c = _cost(f, x, ws)
    expect = 5 * 3 * 2 * 32 * 64 * 64
    assert c.flops == pytest.approx(expect, rel=0.10)


def test_elementwise_counted_linear():
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _cost(lambda x: jnp.tanh(x) + x * 2.0, a)
    assert 1024 * 1024 <= c.flops <= 6 * 1024 * 1024
