"""Kernel-dispatch layer: backend resolution and pallas(interpret)-vs-
reference parity for every routed op, across dtypes and odd shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch as kdsp

RNG = np.random.RandomState(11)


def _pair(fn, *args, **kw):
    with kdsp.force_backend("pallas"):
        a = fn(*args, **kw)
    with kdsp.force_backend("reference"):
        b = fn(*args, **kw)
    return a, b


def _tol(dtype):
    return dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 else \
        dict(atol=3e-6, rtol=1e-6)


# --- backend resolution ----------------------------------------------------

def test_backend_resolution_order(monkeypatch):
    monkeypatch.delenv(kdsp.ENV_VAR, raising=False)
    assert kdsp.resolve_backend() in ("pallas", "reference")
    monkeypatch.setenv(kdsp.ENV_VAR, "pallas")
    assert kdsp.resolve_backend() == "pallas"
    prev = kdsp.set_backend("reference")      # override beats the env
    try:
        assert kdsp.resolve_backend() == "reference"
    finally:
        kdsp.set_backend(prev)
    monkeypatch.setenv(kdsp.ENV_VAR, "warp")
    with pytest.raises(ValueError, match="invalid"):
        kdsp.resolve_backend()
    with pytest.raises(ValueError, match="unknown kernel backend"):
        kdsp.set_backend("warp")


def test_backend_auto_matches_jax_backend(monkeypatch):
    monkeypatch.delenv(kdsp.ENV_VAR, raising=False)
    want = "pallas" if jax.default_backend() == "tpu" else "reference"
    with kdsp.force_backend("auto"):
        assert kdsp.resolve_backend() == want
    info = kdsp.backend_info()
    assert info["resolved"] == want and info["jax_backend"] is not None


# --- segment means ---------------------------------------------------------

@pytest.mark.parametrize("B,N,L,feat", [(1, 16, 4, (128,)), (2, 64, 8, (48,)),
                                        (3, 33, 11, (7,)),
                                        (2, 32, 8, (4, 16))])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_means_parity(B, N, L, feat, dtype):
    x = jnp.asarray(RNG.randn(B, N, *feat), dtype)
    a, b = _pair(kdsp.segment_means, x, L, axis=1)
    assert a.shape == b.shape == (B, L, *feat)
    if dtype == jnp.float32:   # f32: kernel and reference are bit-compatible
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    else:
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,N,L,feat", [(2, 32, 8, (4, 16)), (1, 24, 3, (5,)),
                                        (3, 48, 6, (2, 32))])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_means_masked_parity(B, N, L, feat, dtype):
    x = jnp.asarray(RNG.randn(B, N, *feat), dtype)
    mask = jnp.asarray(RNG.rand(B, N) > 0.3)
    (am, ac), (bm, bc) = _pair(kdsp.segment_means_masked, x, L, mask, axis=1)
    np.testing.assert_array_equal(np.asarray(ac), np.asarray(bc))
    np.testing.assert_allclose(np.asarray(am, np.float32),
                               np.asarray(bm, np.float32), **_tol(dtype))


def test_segment_means_masked_empty_segment():
    """A fully-padded segment must produce count 0 (and a finite mean)."""
    x = jnp.asarray(RNG.randn(1, 16, 8), jnp.float32)
    mask = jnp.asarray(np.arange(16) < 8)[None, :]
    (am, ac), (bm, bc) = _pair(kdsp.segment_means_masked, x, 4, mask, axis=1)
    np.testing.assert_array_equal(np.asarray(ac), [[4, 4, 0, 0]])
    assert np.isfinite(np.asarray(am)).all()
    np.testing.assert_allclose(np.asarray(am), np.asarray(bm), atol=3e-6)


def test_segment_means_non_token_axis_falls_back():
    """Axes the kernel can't tile still work (reference route)."""
    x = jnp.asarray(RNG.randn(2, 3, 12, 8), jnp.float32)
    with kdsp.force_backend("pallas"):
        out = kdsp.segment_means(x, 4, axis=2)
    from repro.core.segment_means import segment_means as ref
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref(x, 4, axis=2)),
                               atol=1e-6)


# --- decode attention ------------------------------------------------------

@pytest.mark.parametrize("B,S,H,Hk,dh", [(1, 32, 2, 2, 16), (2, 64, 4, 2, 16),
                                         (3, 48, 6, 3, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_parity(B, S, H, Hk, dh, dtype):
    q = jnp.asarray(RNG.randn(B, 1, H, dh), dtype)
    k = jnp.asarray(RNG.randn(B, S, Hk, dh), dtype)
    v = jnp.asarray(RNG.randn(B, S, Hk, dh), dtype)
    clen = jnp.asarray(RNG.randint(1, S + 1, size=B))
    a, b = _pair(kdsp.decode_attention, q, k, v, clen)
    assert a.shape == b.shape == (B, 1, H, dh)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **_tol(dtype))


def test_decode_attention_window_softcap_parity():
    q = jnp.asarray(RNG.randn(1, 1, 4, 16), jnp.float32)
    k = jnp.asarray(RNG.randn(1, 64, 4, 16), jnp.float32)
    v = jnp.asarray(RNG.randn(1, 64, 4, 16), jnp.float32)
    a, b = _pair(kdsp.decode_attention, q, k, v, 50, window=16,
                 logit_softcap=30.0, scale=0.2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-6)


def test_decode_attention_matches_sharded_entrypoint():
    """core.exchange.decode_attention_sharded (degenerate layout) is the
    wired call site — same numbers as calling the dispatch layer direct."""
    from repro.core.exchange import ExchangeConfig, decode_attention_sharded
    q = jnp.asarray(RNG.randn(2, 1, 4, 16), jnp.float32)
    k = jnp.asarray(RNG.randn(2, 32, 2, 16), jnp.float32)
    v = jnp.asarray(RNG.randn(2, 32, 2, 16), jnp.float32)
    clen = jnp.asarray([20, 32])
    for backend in ("pallas", "reference"):
        with kdsp.force_backend(backend):
            got = decode_attention_sharded(q, k, v, clen, ExchangeConfig())
            want = kdsp.decode_attention(q, k, v, clen)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6)


# --- PRISM prefill attention ----------------------------------------------

@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("counts", [False, True])
def test_prism_attention_parity(causal, counts):
    B, Nq, H, Hk, dh, P, L = 2, 16, 4, 2, 16, 2, 4
    q = jnp.asarray(RNG.randn(B, Nq, H, dh), jnp.float32)
    kl = jnp.asarray(RNG.randn(B, Nq, Hk, dh), jnp.float32)
    vl = jnp.asarray(RNG.randn(B, Nq, Hk, dh), jnp.float32)
    km = jnp.asarray(RNG.randn(B, P, L, Hk, dh), jnp.float32)
    vm = jnp.asarray(RNG.randn(B, P, L, Hk, dh), jnp.float32)
    mc = (jnp.asarray(RNG.randint(0, 5, (B, P, L)), jnp.float32)
          if counts else None)
    a, b = _pair(kdsp.prism_attention, q, kl, vl, km, vm, 1, 4,
                 causal=causal, mean_counts=mc)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_prism_attention_masked_falls_back():
    """kv_mask has no kernel support — both backends must agree (reference
    route) rather than silently dropping the mask."""
    B, Nq, H, dh, P, L = 1, 8, 2, 8, 2, 2
    q = jnp.asarray(RNG.randn(B, Nq, H, dh), jnp.float32)
    kl = jnp.asarray(RNG.randn(B, Nq, H, dh), jnp.float32)
    vl = jnp.asarray(RNG.randn(B, Nq, H, dh), jnp.float32)
    km = jnp.asarray(RNG.randn(B, P, L, H, dh), jnp.float32)
    vm = jnp.asarray(RNG.randn(B, P, L, H, dh), jnp.float32)
    mask = jnp.asarray([[True] * 6 + [False] * 2])
    a, b = _pair(kdsp.prism_attention, q, kl, vl, km, vm, 0, 4,
                 kv_mask=mask)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
