"""Serving runtime: queue/scheduler semantics, policy-table batch
formation, continuous-batching token-exactness vs sequential
``session.generate``, and the fault/straggler hook wiring."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import ExecutionPlan, InferenceSession
from repro.api import generation as gen
from repro.core.policy import AdaptivePolicy, PolicyTable
from repro.profiling import ProfileContext, SweepSpec, get_backend
from repro.serving import (AdaptiveScheduler, FaultHook, QueueFull, Request,
                           RequestQueue, ServingRuntime, StragglerHook)
from repro.utils import BandwidthEstimator


@pytest.fixture(scope="module")
def perfmap():
    return get_backend("simulated").profile(ProfileContext(), SweepSpec())


@pytest.fixture(scope="module")
def session():
    s = InferenceSession.from_config(
        "llama3.2-1b", reduced={"vocab_size": 64},
        plans=[ExecutionPlan.local(), ExecutionPlan.prism_sim(L=4, cr=9.9)])
    s.profile(backend="simulated")
    return s


def _prompt(T0, seed=0):
    return np.random.RandomState(seed).randint(0, 64, T0)


# --- queue ------------------------------------------------------------------

def test_queue_edf_order():
    q = RequestQueue(max_size=8)
    a = q.put(Request(_prompt(4), 4, slo_ms=None, arrival_ts=1.0))
    b = q.put(Request(_prompt(4), 4, slo_ms=50.0, arrival_ts=2.0))
    c = q.put(Request(_prompt(4), 4, slo_ms=5000.0, arrival_ts=3.0))
    # tightest deadline first, then the looser SLO, then best-effort FIFO
    assert [q.pop().id for _ in range(3)] == [b.id, c.id, a.id]


def test_queue_fifo_among_equals_and_bounds():
    q = RequestQueue(max_size=2)
    a = q.put(Request(_prompt(4), 4, arrival_ts=1.0))
    b = q.put(Request(_prompt(4), 4, arrival_ts=2.0))
    with pytest.raises(QueueFull):
        q.put(Request(_prompt(4), 4))
    assert q.pop().id == a.id
    assert q.pop().id == b.id
    with pytest.raises(IndexError):
        q.pop()


def test_queue_oldest_wait():
    q = RequestQueue()
    assert q.oldest_wait_ms() == 0.0
    q.put(Request(_prompt(4), 4, arrival_ts=10.0))
    q.put(Request(_prompt(4), 4, arrival_ts=11.0))
    assert q.oldest_wait_ms(now=10.5) == pytest.approx(500.0)


def test_queue_rejection_accounting():
    """Backpressure is telemetry, not a silent exception: refused puts are
    counted by reason, force-puts bypass both the bound and the count."""
    q = RequestQueue(max_size=1)
    q.put(Request(_prompt(4), 4))
    with pytest.raises(QueueFull) as ei:
        q.put(Request(_prompt(4), 4))
    assert ei.value.reason == "full"
    assert q.rejected == 1 and q.rejections == {"full": 1}
    q.reject("dead_worker")               # router-decided shed
    assert q.rejected == 2 and q.rejections["dead_worker"] == 1
    q.put(Request(_prompt(4), 4), force=True)
    assert q.rejected == 2 and len(q) == 2
    drained = q.drain()
    assert len(drained) == 2 and len(q) == 0 and not q
    assert q.rejections == {"full": 1, "dead_worker": 1}  # counts survive


def test_request_validation():
    r = Request(np.ones((1, 5), np.int64), 3)      # [1, T0] squeezed
    assert r.prompt.shape == (5,) and r.total_len == 8
    assert r.deadline() == float("inf")
    with pytest.raises(ValueError):
        Request(np.ones(4, np.int64), 0)
    with pytest.raises(ValueError):
        Request(np.ones((2, 3), np.int64), 4)


# --- policy-table batch formation ------------------------------------------

def test_plan_batch_prefers_cheapest_grid_batch(perfmap):
    table = PolicyTable.compile(perfmap, ("local", "prism"), "latency")
    bp = table.plan_batch(32, 400.0)
    # per-sample latency falls with batch on this profile → take the full
    # grid batch, no padding
    assert bp.batch == 32 and bp.n_admit == 32 and bp.padded == 0
    assert not bp.extrapolated
    assert bp.decision.mode in ("local", "prism")


def test_plan_batch_admits_partially_when_cheaper(perfmap):
    """A short queue need not be padded up: serving min(batch, queue) at
    the cheapest grid point and leaving the rest queued is a valid (and
    here cheaper) formation."""
    table = PolicyTable.compile(perfmap, ("local", "prism"), "latency")
    bp = table.plan_batch(3, 400.0)
    assert bp.batch in table.batches
    assert bp.n_admit == min(bp.batch, 3)
    assert bp.padded == bp.batch - bp.n_admit
    d = table.decide(bp.batch, 400.0)
    assert bp.per_request_cost == pytest.approx(
        table.objective.cost(d.expected) * bp.batch / bp.n_admit)


def test_plan_batch_pads_to_cheaper_grid_point():
    """When a larger profiled batch is cheap enough, the queue is padded up
    to it and the waste is charged to the admitted requests."""
    from repro.core.perfmap import PerfEntry, PerfKey, PerfMap
    pm = PerfMap()
    for b, ps in ((1, 100.0), (4, 10.0)):
        pm.put(PerfKey("local", b, 0.0, 0.0),
               PerfEntry(total_ms=ps * b, per_sample_ms=ps,
                         per_sample_j=1.0, compute_ms=ps * b,
                         staging_ms=0.0, comm_ms=0.0))
    table = PolicyTable.compile(pm, ("local",), "latency")
    bp = table.plan_batch(3, 400.0)
    assert bp.batch == 4 and bp.n_admit == 3 and bp.padded == 1
    assert bp.per_request_cost == pytest.approx(10.0 * 4 / 3)
    assert not bp.extrapolated                 # 3 is inside the grid range


def test_plan_batch_extrapolated_and_capped(perfmap):
    table = PolicyTable.compile(perfmap, ("local", "prism"), "latency")
    assert table.plan_batch(1000, 400.0).extrapolated
    bp = table.plan_batch(1000, 400.0, max_batch=4)
    assert bp.batch <= 4
    with pytest.raises(ValueError):
        table.plan_batch(0, 400.0)
    with pytest.raises(ValueError):
        table.plan_batch(4, 400.0, max_batch=0)


def test_plan_batch_fallback_respects_max_batch():
    """When no grid batch fits under max_batch, the formed batch stays a
    grid shape but admissions never exceed the caller's free-slot cap."""
    from repro.core.perfmap import PerfEntry, PerfKey, PerfMap
    pm = PerfMap()
    pm.put(PerfKey("local", 8, 0.0, 0.0),
           PerfEntry(total_ms=80.0, per_sample_ms=10.0, per_sample_j=1.0,
                     compute_ms=80.0, staging_ms=0.0, comm_ms=0.0))
    table = PolicyTable.compile(pm, ("local",), "latency")
    bp = table.plan_batch(8, 400.0, max_batch=2)
    assert bp.batch == 8                       # only executable grid shape
    assert bp.n_admit == 2                     # but the cap holds
    assert bp.padded == 6


def test_scheduler_forms_and_holds(perfmap):
    import types
    sess = types.SimpleNamespace(policy=AdaptivePolicy(perfmap),
                                 bandwidth=400.0, objective="latency")
    sched = AdaptiveScheduler(sess, max_wait_ms=1e9)
    q = RequestQueue()
    assert sched.next_batch(q, free_slots=4) is None        # empty queue
    for i in range(3):
        q.put(Request(_prompt(4), 4, arrival_ts=float(i)))
    assert sched.next_batch(q, free_slots=0) is None        # no slots
    # busy pool + huge max_wait + short queue → hold for a fuller batch
    held = sched.next_batch(q, free_slots=8, idle=False, now=100.0)
    if held is None:                    # policy wanted a bigger batch
        assert len(q) == 3
    mb = sched.next_batch(q, free_slots=8, idle=True, now=100.0)
    assert mb is not None and 1 <= len(mb.requests) <= 3
    assert mb.exec_key.split("@")[0] in ("local", "prism")
    assert sched.history[-1] is mb


# --- continuous-batching exactness -----------------------------------------

def test_runtime_token_exact_vs_sequential_generate(session):
    """The acceptance bar: every request served by the continuous-batching
    runtime must match ``session.generate`` token-for-token (greedy AND
    sampled, same seed), with more requests than slots so admission into
    freed slots actually happens."""
    rt = ServingRuntime(session, n_slots=2, chunk=3, max_len=24)
    reqs = []
    for i, (T0, n_new, temp) in enumerate(
            [(4, 6, 0.0), (6, 5, 1.0), (4, 7, 0.0), (6, 4, 1.0),
             (4, 5, 0.0)]):
        reqs.append(rt.submit(_prompt(T0, seed=i), n_new, seed=i,
                              temperature=temp))
    done = rt.run()
    assert len(done) == len(reqs)
    assert rt.stats["max_concurrent"] == 2
    for req in reqs:
        comp = next(c for c in done if c.request_id == req.id)
        ref = session.generate(jnp.asarray(req.prompt)[None], req.n_new,
                               seed=req.seed, temperature=req.temperature)
        np.testing.assert_array_equal(comp.tokens, np.asarray(ref)[0])
        assert comp.latency_ms >= comp.queue_ms >= 0.0


def test_runtime_prism_pool_token_exact():
    """A PRISM-routed pool decodes with the plan's exchange semantics and
    still matches the per-request compiled generate on that plan."""
    sess = InferenceSession.from_config(
        "llama3.2-1b", reduced={"vocab_size": 64},
        plans=[ExecutionPlan.prism_sim(L=2, cr=9.9)],
        allow_modes=("prism",))
    sess.profile(backend="simulated")
    rt = ServingRuntime(sess, n_slots=2, chunk=4, max_len=16)
    reqs = [rt.submit(_prompt(4, seed=i), 5, seed=i) for i in range(3)]
    done = rt.run()
    plan = sess.plans["prism@9.9"]
    for req in reqs:
        comp = next(c for c in done if c.request_id == req.id)
        assert comp.plan_key == "prism@9.9"
        ref = sess.generate(jnp.asarray(req.prompt)[None], req.n_new,
                            plan=plan, seed=req.seed)
        np.testing.assert_array_equal(comp.tokens, np.asarray(ref)[0])


def test_runtime_one_executable_per_plan_slot_count(session):
    """Admissions into freed slots must NOT build new decode executables:
    one compiled chunk fn per (plan, slot-count), reused for the whole
    run."""
    rt = ServingRuntime(session, n_slots=2, chunk=4, max_len=16)
    for i in range(4):
        rt.submit(_prompt(4, seed=i), 5, seed=i)
    rt.run()                                   # warm build
    before = gen.build_count()
    rt2 = ServingRuntime(session, n_slots=2, chunk=4, max_len=16)
    for i in range(6):
        rt2.submit(_prompt(4, seed=10 + i), 5, seed=i)
    rt2.run()
    assert gen.build_count() == before         # everything cache-hit
    assert rt2.stats["admitted"] == 6


def test_prime_slot_forwards_prefill_mode(session):
    """prefill_mode must reach the built executable (and key its cache):
    a local dense plan resolves to single_pass under "auto" but must honor
    an explicit "scan"."""
    prompt = jnp.asarray(_prompt(4))[None]
    session.prime_slot(prompt, total_len=16)
    session.prime_slot(prompt, total_len=16, prefill_mode="scan")
    plan = session.plans["local"]
    fns = session._serve_execs[plan]
    modes = {fn.prefill_mode for k, fn in fns.items() if k[0] == "prefill"
             and k[2] == 4 and k[3] == 16}
    assert modes == {"single_pass", "scan"}


def test_decision_exec_key_is_canonical(perfmap):
    table = PolicyTable.compile(perfmap, ("local", "prism"), "latency")
    d = table.decide(1, 200.0)
    assert d.exec_key == ("local" if d.mode == "local"
                          else f"{d.mode}@{d.cr:g}")
    d32 = table.decide(32, 900.0)
    assert d32.exec_key.startswith(d32.mode)


def test_runtime_rejects_oversized_request(session):
    rt = ServingRuntime(session, n_slots=2, chunk=4, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        rt.submit(_prompt(12), 8)


def test_slot_pool_rejects_unsupported_families():
    """Non-generative (vit) and extras-needing (audio/vlm) families get a
    clear NotImplementedError at the gate, not an opaque crash deeper in."""
    sess = InferenceSession.from_config("vit-base-16",
                                        reduced={"n_layers": 1})
    with pytest.raises(NotImplementedError, match="slot"):
        sess.init_slot_pool(2, 16)
    with pytest.raises(NotImplementedError, match="slot"):
        sess.prime_slot(jnp.zeros((1, 4), jnp.int32), total_len=16)


# --- fault / straggler hooks ------------------------------------------------

def test_fault_hook_requeues_and_completes(session):
    from repro.runtime.elastic import ElasticMeshManager
    mgr = ElasticMeshManager(cfg=None, mode=None,
                             devices=["n0", "n1", "n2"])
    hook = FaultHook(nodes=["n0", "n1", "n2"], timeout_s=1e9,
                     mesh_manager=mgr)
    rt = ServingRuntime(session, n_slots=2, chunk=3, max_len=24,
                        fault_hook=hook)
    reqs = [rt.submit(_prompt(4, seed=i), 6, seed=i) for i in range(3)]
    rt.step()                                  # admit + first chunk
    hook.monitor.fail("n1")                    # heartbeat miss mid-flight
    done = rt.run()
    assert rt.stats["requeued"] >= 1           # in-flight work re-admitted
    assert [e.dead for e in hook.events] == [["n1"]]
    assert hook.events[0].requeued == rt.stats["requeued"]
    assert mgr.devices == ["n0", "n2"]         # explicit id, not the tail
    # re-admitted requests still finish token-exact
    all_done = rt.completions
    assert len(all_done) == len(reqs)
    for req in reqs:
        comp = next(c for c in all_done if c.request_id == req.id)
        ref = session.generate(jnp.asarray(req.prompt)[None], req.n_new,
                               seed=req.seed)
        np.testing.assert_array_equal(comp.tokens, np.asarray(ref)[0])


def test_fault_requeue_bypasses_queue_bound(session):
    """Failover must never drop in-flight work because the intake queue is
    full — internal re-queues bypass the backpressure bound."""
    hook = FaultHook(nodes=["n0"], timeout_s=1e9)
    rt = ServingRuntime(session, n_slots=2, chunk=3, max_len=24,
                        queue_size=1, fault_hook=hook)
    reqs = [rt.submit(_prompt(4, seed=i), 6, seed=i) for i in range(1)]
    rt.step()                                  # in flight, queue empty
    rt.queue.put(Request(_prompt(4, seed=9), 6, seed=9))   # fill the bound
    reqs.append(list(rt.queue)[0])
    hook.monitor.fail("n0")
    rt.run()                                   # must not raise QueueFull
    assert len(rt.completions) == 2


def test_drive_applies_backpressure_on_bounded_queue(session):
    """drive() must defer submissions when the intake queue is at
    capacity (resubmitting after the next step) instead of raising
    QueueFull mid-replay."""
    rt = ServingRuntime(session, n_slots=2, chunk=3, max_len=24,
                        queue_size=1)
    prompts = [_prompt(4, seed=i) for i in range(5)]
    comps = rt.drive(prompts, [0.0] * 5, 6)    # burst >> queue bound
    assert len(comps) == 5
    got = {c.request_id: c.tokens for c in comps}
    for i, rid in enumerate(sorted(got)):      # submitted in arrival order
        ref = session.generate(jnp.asarray(prompts[i])[None], 6, seed=i)
        np.testing.assert_array_equal(got[rid], np.asarray(ref)[0])


def test_stats_snapshot_is_consistent_copy(session):
    """stats_snapshot() hands a reader in another logical context (the
    fleet router, a benchmark) a copy with the derived gauges folded in —
    mutating it must not touch the live runtime state."""
    rt = ServingRuntime(session, n_slots=2, chunk=3, max_len=24,
                        queue_size=1)
    rt.submit(_prompt(4, seed=0), 6)
    with pytest.raises(QueueFull):
        rt.submit(_prompt(4, seed=1), 6)
    snap = rt.stats_snapshot()
    assert snap["queue_depth"] == 1 and snap["in_flight"] == 0
    assert snap["rejected"] == 1 and snap["rejections"] == {"full": 1}
    snap["steps"] = 999
    snap["rejections"]["full"] = 999
    assert rt.stats["steps"] == 0
    assert rt.queue.rejections == {"full": 1}
    rt.run()
    snap2 = rt.stats_snapshot()
    assert snap2["completed"] == 1 and snap2["queue_depth"] == 0
    assert snap2["in_flight"] == 0 and snap2["steps"] == rt.stats["steps"]


def test_drain_requests_empties_queue_and_pools(session):
    """drain_requests() (the fleet dead-worker path) hands back queued AND
    in-flight requests; the runtime is left empty."""
    rt = ServingRuntime(session, n_slots=2, chunk=3, max_len=24)
    reqs = [rt.submit(_prompt(4, seed=i), 6, seed=i) for i in range(3)]
    rt.step()                               # 2 in flight + 1 queued
    drained = rt.drain_requests()
    assert {r.id for r in drained} == {r.id for r in reqs}
    assert len(rt.queue) == 0 and rt.idle
    assert rt.stats_snapshot()["in_flight"] == 0


def test_prime_slot_temperature_is_traced(session):
    """Per-request temperatures must reuse ONE compiled prefill (the
    serving path would otherwise recompile per distinct float)."""
    prompt = jnp.asarray(_prompt(5))[None]
    session.prime_slot(prompt, total_len=16, temperature=0.0)
    before = gen.build_count()
    for T in (0.3, 0.7, 1.1):
        session.prime_slot(prompt, total_len=16, temperature=T)
    assert gen.build_count() == before


def test_straggler_hook_skips_tiny_workloads():
    """A workload with fewer segments than devices yields no rebalance
    proposal instead of raising inside the serving loop."""
    hook = StragglerHook(n_devices=8, seg_size=64)
    for _ in range(10):
        ev = hook.observe([1.0] * 7 + [9.0], n_tokens=256)
    assert ev is None and not hook.events


def test_straggler_hook_emits_rebalance():
    hook = StragglerHook(n_devices=4, seg_size=2)
    for _ in range(10):
        ev = hook.observe([1.0, 1.0, 1.0, 3.0], n_tokens=64)
    assert ev is not None and ev.stragglers == [3]
    assert sum(ev.partitions) == 64
    assert all(p % 2 == 0 and p > 0 for p in ev.partitions)
    assert ev.partitions[3] == min(ev.partitions)
    assert hook.events


def test_runtime_feeds_straggler_hook(session):
    hook = StragglerHook(n_devices=2, seg_size=2)
    rt = ServingRuntime(session, n_slots=2, chunk=3, max_len=16,
                        straggler_hook=hook)
    rt.submit(_prompt(4), 5)
    rt.run()
    assert hook.chunk_walls_ms                 # chunk telemetry recorded
    # chunk walls must NOT masquerade as per-device times: the mitigator
    # only sees what the fleet feeds via hook.observe()
    assert hook.mitigator._seen == 0
    hook.observe([1.0, 3.0], n_tokens=16)      # a real per-device sample
    assert hook.mitigator._seen == 1


# --- shared bandwidth estimator ---------------------------------------------

def test_bandwidth_estimator_shared_impl():
    est = BandwidthEstimator(400.0, alpha=0.5)
    assert est.observe(200.0) == pytest.approx(300.0)
    assert est.observe(300.0) == pytest.approx(300.0)
    est.reset(100.0)
    assert est.mbps == 100.0 and est.observations == 2
    with pytest.raises(ValueError):
        BandwidthEstimator(400.0, alpha=0.0)


def test_session_uses_shared_estimator(perfmap):
    sess = InferenceSession.from_config("llama3.2-1b",
                                        reduced={"vocab_size": 64},
                                        perfmap=perfmap, bandwidth_alpha=0.5)
    assert isinstance(sess._bwest, BandwidthEstimator)
    sess.observe_bandwidth(200.0)
    assert sess.bandwidth == pytest.approx(300.0)
    sess._bw = 123.0                           # legacy pin still works
    assert sess.bandwidth == 123.0


def test_estimator_observe_transfer():
    """bytes/wall folds into the EWMA like a probe: 1 MB in 20 ms is
    exactly a 400 Mbps link."""
    est = BandwidthEstimator(400.0, alpha=0.5)
    implied = est.observe_transfer(1_000_000, 20.0)
    assert implied == pytest.approx(400.0)
    assert est.mbps == pytest.approx(400.0)
    est.observe_transfer(1_000_000, 40.0)      # 200 Mbps observed
    assert est.mbps == pytest.approx(300.0)
    with pytest.raises(ValueError):
        est.observe_transfer(0, 10.0)


# --- slot-pool key init (shared placeholder keys) ---------------------------

def test_placeholder_keys_cached_per_size():
    """Satellite: pools no longer rebuild jnp.stack([key(0)] * n) per
    construction — one cached placeholder array per size, shared."""
    from repro.serving.engine import _placeholder_keys
    a = _placeholder_keys(4)
    assert a is _placeholder_keys(4)           # same object, not a rebuild
    assert a.shape == (4,)
    assert _placeholder_keys(3) is not a


def test_sampled_decode_deterministic_across_admit_order(session):
    """Sharing placeholder key arrays must not couple requests: sampled
    decode stays per-request deterministic whatever order (and into
    whatever slot) requests are admitted."""
    specs = [(_prompt(4, seed=i), 40 + i, 1.0) for i in range(4)]

    def serve(order):
        rt = ServingRuntime(session, n_slots=2, chunk=3, max_len=16)
        reqs = {}
        for i in order:
            p, seed, temp = specs[i]
            reqs[i] = rt.submit(p, 5, seed=seed, temperature=temp)
        done = {c.request_id: c.tokens for c in rt.run()}
        return {i: done[r.id] for i, r in reqs.items()}

    a = serve([0, 1, 2, 3])
    b = serve([3, 1, 0, 2])                    # different order, slots differ
    for i in range(4):
        np.testing.assert_array_equal(a[i], b[i])
        ref = session.generate(jnp.asarray(specs[i][0])[None], 5,
                               seed=specs[i][1], temperature=1.0)
        np.testing.assert_array_equal(a[i], np.asarray(ref)[0])
