"""PRISM scaling-aware attention: exactness, masking, paper-semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core.partition import (partition_sequence,
                                  simulate_prism_attention,
                                  simulate_voltage_attention,
                                  unpartition_sequence)
from repro.core.prism_attention import (chunked_reference_attention,
                                        prism_attention, reference_attention)

RNG = np.random.RandomState(0)


def _qkv(B=2, N=32, H=4, Hk=2, dh=16, dtype=jnp.float32):
    q = jnp.asarray(RNG.randn(B, N, H, dh), dtype)
    k = jnp.asarray(RNG.randn(B, N, Hk, dh), dtype)
    v = jnp.asarray(RNG.randn(B, N, Hk, dh), dtype)
    return q, k, v


def test_voltage_equals_full_attention():
    """Voltage's AllGather reconstructs full K/V — math must be identical."""
    q, k, v = _qkv()
    for causal in (False, True):
        out = simulate_voltage_attention(q, k, v, P=4, causal=causal)
        ref = reference_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)


def test_prism_seg1_equals_full_bidirectional():
    """Segment size 1 → means are the tokens; scaling bias log(1)=0 →
    PRISM attention must equal full attention exactly (paper's limit)."""
    q, k, v = _qkv(N=32)
    out = simulate_prism_attention(q, k, v, P=4, L=8, causal=False)
    ref = reference_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_prism_causal_first_partition_is_local_only():
    """Partition 0 under causality sees no remote means — equals local-only
    causal attention on its slice."""
    q, k, v = _qkv(N=32)
    P = 4
    out = simulate_prism_attention(q, k, v, P=P, L=2, causal=True)
    qp = partition_sequence(q, P)
    kp = partition_sequence(k, P)
    vp = partition_sequence(v, P)
    local0 = reference_attention(qp[0], kp[0], vp[0], causal=True)
    np.testing.assert_allclose(np.asarray(out[:, :8]), np.asarray(local0),
                               atol=2e-5)


def test_scaling_aware_bias_equals_duplicate_keys():
    """THE paper property: one mean key with +log(s) bias carries the mass
    of s identical keys — verify exactly with duplicated keys."""
    B, Nq, H, dh, s = 1, 4, 2, 8, 5
    q = jnp.asarray(RNG.randn(B, Nq, H, dh), jnp.float32)
    k1 = jnp.asarray(RNG.randn(B, 1, H, dh), jnp.float32)
    v1 = jnp.asarray(RNG.randn(B, 1, H, dh), jnp.float32)
    k_loc = jnp.asarray(RNG.randn(B, Nq, H, dh), jnp.float32)
    v_loc = jnp.asarray(RNG.randn(B, Nq, H, dh), jnp.float32)
    # (a) local keys + s duplicates of (k1, v1)
    k_dup = jnp.concatenate([k_loc] + [k1] * s, axis=1)
    v_dup = jnp.concatenate([v_loc] + [v1] * s, axis=1)
    ref = reference_attention(q, k_dup, v_dup)
    # (b) local keys + ONE mean key with seg_size=s bias (means of partition
    # 1; query partition 0, bidirectional → remote visible)
    km = jnp.stack([k1 * jnp.nan, k1], axis=1)  # own partition masked anyway
    vm = jnp.stack([v1 * jnp.nan, v1], axis=1)
    km = jnp.where(jnp.isnan(km), 0.0, km)
    vm = jnp.where(jnp.isnan(vm), 0.0, vm)
    out = prism_attention(q, k_loc, v_loc, km, vm, part_idx=0, seg_size=s,
                          causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_mean_counts_mask_empty_segments():
    q, k, v = _qkv(N=8, H=2, Hk=2)
    km = jnp.asarray(RNG.randn(2, 2, 2, 2, 16), jnp.float32)
    vm = jnp.asarray(RNG.randn(2, 2, 2, 2, 16), jnp.float32)
    counts = jnp.asarray([[[4.0, 0.0], [4.0, 4.0]]] * 2)   # one empty segment
    out = prism_attention(q, k, v, km, vm, part_idx=0, seg_size=4,
                          causal=False, mean_counts=counts)
    assert not bool(jnp.any(jnp.isnan(out)))
    # zeroing the masked mean's value must not change anything
    vm2 = vm.at[:, 0, 1].set(1e3)
    out2 = prism_attention(q, k, v, km, vm2, part_idx=0, seg_size=4,
                           causal=False, mean_counts=counts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)


def test_partition_roundtrip():
    x = jnp.asarray(RNG.randn(3, 24, 5), jnp.float32)
    p = partition_sequence(x, 4)
    assert p.shape == (4, 3, 6, 5)
    np.testing.assert_array_equal(np.asarray(unpartition_sequence(p)),
                                  np.asarray(x))


def test_chunked_equals_reference():
    q, k, v = _qkv(B=1, N=64, H=2, Hk=2)
    for causal in (False, True):
        for window in (None, 16):
            ref = reference_attention(q, k, v, causal=causal, window=window)
            out = chunked_reference_attention(q, k, v, chunk=16,
                                              causal=causal, window=window)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=2e-5)


def test_chunked_gradient_matches():
    q, k, v = _qkv(B=1, N=32, H=2, Hk=2)

    def loss_ref(q):
        return jnp.sum(reference_attention(q, k, v, causal=True) ** 2)

    def loss_chk(q):
        return jnp.sum(chunked_reference_attention(q, k, v, chunk=8,
                                                   causal=True) ** 2)
    g1 = jax.grad(loss_ref)(q)
    g2 = jax.grad(loss_chk)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-3,
                               rtol=1e-3)


@given(st.integers(2, 4), st.integers(1, 4), st.booleans())
@settings(max_examples=20, deadline=None)
def test_prism_rows_sum_to_one(P, L, causal):
    """Softmax over [local ‖ means] is a proper distribution: outputs are
    convex combinations → bounded by the max |v|."""
    rng = np.random.RandomState(P * 10 + L)
    N = P * L * 2
    q = jnp.asarray(rng.randn(1, N, 2, 8), jnp.float32)
    k = jnp.asarray(rng.randn(1, N, 2, 8), jnp.float32)
    v = jnp.asarray(rng.randn(1, N, 2, 8), jnp.float32)
    out = simulate_prism_attention(q, k, v, P=P, L=L, causal=causal)
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4
