"""Unit + property tests for Segment Means (PRISM Eq. 1) and the CR math."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core.segment_means import (comm_elements_prism,
                                      comm_elements_voltage, comm_reduction,
                                      cr_to_L, L_to_cr, segment_means,
                                      segment_means_masked, segment_sizes)


def test_segment_sizes_divisibility():
    assert segment_sizes(100, 10) == 10
    with pytest.raises(ValueError):
        segment_sizes(100, 7)
    with pytest.raises(ValueError):
        segment_sizes(100, 0)


def test_segment_means_basic():
    x = jnp.arange(12, dtype=jnp.float32).reshape(1, 6, 2)
    z = segment_means(x, 3, axis=1)
    assert z.shape == (1, 3, 2)
    np.testing.assert_allclose(np.asarray(z[0, 0]), [1.0, 2.0])   # mean of rows 0,1


def test_segment_means_seg1_identity():
    x = jnp.asarray(np.random.RandomState(0).randn(2, 8, 4), jnp.float32)
    np.testing.assert_allclose(np.asarray(segment_means(x, 8, axis=1)),
                               np.asarray(x), rtol=1e-6)


def test_masked_means_match_unmasked_when_full():
    x = jnp.asarray(np.random.RandomState(1).randn(2, 12, 4), jnp.float32)
    mask = jnp.ones((2, 12), bool)
    m, counts = segment_means_masked(x, 3, mask, axis=1)
    np.testing.assert_allclose(np.asarray(m),
                               np.asarray(segment_means(x, 3, axis=1)),
                               rtol=1e-6)
    assert np.all(np.asarray(counts) == 4)


def test_masked_means_exclude_pads():
    x = jnp.asarray(np.random.RandomState(2).randn(1, 8, 2), jnp.float32)
    mask = jnp.asarray([[1, 1, 1, 1, 1, 0, 0, 0]], bool)   # last 3 are pads
    m, counts = segment_means_masked(x, 2, mask, axis=1)
    np.testing.assert_allclose(np.asarray(counts[0]), [4, 1])
    np.testing.assert_allclose(np.asarray(m[0, 1]), np.asarray(x[0, 4]),
                               rtol=1e-6)


@given(st.integers(1, 8), st.integers(1, 16), st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_cr_L_roundtrip(P, L, seg):
    """CR↔L inversion is consistent for integer segmentations."""
    N = P * L * seg
    cr = L_to_cr(N, P, L)
    assert cr_to_L(N, P, cr) == L


@given(st.integers(2, 16), st.integers(64, 4096), st.integers(1, 32),
       st.integers(32, 1024))
@settings(max_examples=50, deadline=None)
def test_comm_reduction_matches_cr(P, N, L, D):
    """PRISM/Voltage comm ratio ≈ CR·(P-1)/P·P/(P-1) — exactly N/(L·P)."""
    volt = comm_elements_voltage(P, N, D)
    prism = comm_elements_prism(P, L, D)
    assert volt == (P - 1) * N * D // P
    assert prism == (P - 1) * L * D
    # reduction equals N/(P·L) up to the floor in voltage's //P
    red = comm_reduction(P, N, L)
    assert red == pytest.approx(N / (P * L), rel=0.02)


@given(st.integers(1, 4), st.integers(1, 6), st.integers(1, 8),
       st.integers(1, 6))
@settings(max_examples=30, deadline=None)
def test_mean_linearity_property(b, L, seg, d):
    """mean(X)·W == mean(X·W) — the identity that lets PRISM exchange
    *projected* means and never re-project remote features (paper §3.1)."""
    rng = np.random.RandomState(b * 100 + L * 10 + seg)
    X = jnp.asarray(rng.randn(b, L * seg, d), jnp.float32)
    W = jnp.asarray(rng.randn(d, d + 1), jnp.float32)
    lhs = segment_means(X, L, axis=1) @ W
    rhs = segment_means(X @ W, L, axis=1)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)
