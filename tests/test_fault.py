"""Fault tolerance: heartbeat detection, checkpoint-restart determinism,
straggler mitigation, elastic re-meshing logic."""
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.elastic import ElasticMeshManager, largest_mesh_shape
from repro.runtime.fault import FaultTolerantLoop, HeartbeatMonitor
from repro.runtime.straggler import StragglerMitigator


def test_heartbeat_timeout():
    t = [0.0]
    mon = HeartbeatMonitor(["a", "b"], timeout_s=5.0, clock=lambda: t[0])
    assert mon.healthy()
    t[0] = 4.0
    mon.beat("a")
    t[0] = 7.0
    assert mon.dead_nodes() == ["b"]


def test_heartbeat_miss_requeue_preserves_edf_order():
    """The fleet failover contract at the queue level: a dead worker's
    drained requests are force-put (the bound must not drop admitted work)
    into a survivor's queue, and EDF order is *recovered* by the target
    queue's deadline-ordered pop — not by replay of insertion order."""
    from repro.serving.queue import Request, RequestQueue
    slos = [9000.0, 1000.0, None, 3000.0]       # arrival order != EDF order
    reqs = [Request(np.ones(4, np.int64), 4, slo_ms=s,
                    arrival_ts=float(i)) for i, s in enumerate(slos)]
    dead = RequestQueue(max_size=4)
    for r in reqs:
        dead.put(r)
    drained = dead.drain()
    assert len(dead) == 0 and len(drained) == 4
    survivor = RequestQueue(max_size=2)          # smaller than the drain
    for r in drained:
        survivor.put(r, force=True)              # failover bypasses bound
    by_deadline = [r.id for r in sorted(
        reqs, key=lambda r: (r.deadline(), r.arrival_ts))]
    assert [survivor.pop().id for _ in range(4)] == by_deadline


def _counter_loop(tmp_path, ckpt_every=2):
    """step_fn: state = (count, checksum); checksum folds the batch in, so
    divergent replay would change it."""
    def step_fn(state, batch):
        c, h = state
        return (c + 1, h * 31 + int(batch)), {}

    def batch_fn(step):
        return step * step + 7          # deterministic cursor

    ckpt = CheckpointManager(str(tmp_path), keep=3)
    mon = HeartbeatMonitor(["n0", "n1"], timeout_s=1e9)
    return FaultTolerantLoop(
        step_fn, batch_fn, ckpt, mon, ckpt_every=ckpt_every), ckpt


def test_restart_deterministic(tmp_path):
    loop, _ = _counter_loop(tmp_path / "a")
    clean, _ = loop.run((jnp.asarray(0), jnp.asarray(1)), 0, 10)

    loop2, _ = _counter_loop(tmp_path / "b")
    failed, _ = loop2.run((jnp.asarray(0), jnp.asarray(1)), 0, 10,
                          fail_at={5: "n1"})
    assert any(e.kind == "node_down" for e in loop2.events)
    assert int(clean[0]) == int(failed[0])
    assert int(clean[1]) == int(failed[1])      # bit-identical replay


def test_resume_from_existing_checkpoint(tmp_path):
    loop, ckpt = _counter_loop(tmp_path)
    state, step = loop.run((jnp.asarray(0), jnp.asarray(1)), 0, 6)
    assert step == 6
    # new loop, same dir → resumes from the last checkpoint, not step 0
    loop2 = FaultTolerantLoop(loop.step_fn, loop.batch_fn, ckpt,
                              HeartbeatMonitor(["n0"]), ckpt_every=2)
    state2, step2 = loop2.run((jnp.asarray(0), jnp.asarray(1)), 0, 8)
    assert any(e.kind == "restart" for e in loop2.events)
    assert step2 == 8


def test_straggler_detection_and_rebalance():
    mit = StragglerMitigator(n_devices=4)
    for _ in range(10):
        mit.observe(np.array([1.0, 1.0, 1.0, 2.0]))   # device 3 is slow
    assert mit.stragglers() == [3]
    parts = mit.rebalanced_partitions(n_tokens=1600, seg_size=10)
    assert sum(parts) == 1600
    assert all(p % 10 == 0 for p in parts)
    assert parts[3] == min(parts)                     # slow device gets less


@given(st.integers(1, 600))
@settings(max_examples=60, deadline=None)
def test_largest_mesh_shape_properties(n):
    d, m = largest_mesh_shape(n)
    assert d * m <= n
    assert m in (1, 2, 4, 8, 16)
    # never wastes more than half the fleet beyond what divisibility forces
    assert d * m >= n // 2 or n < 2


@given(st.lists(st.floats(0.1, 10.0), min_size=2, max_size=16))
@settings(max_examples=40, deadline=None)
def test_rebalance_total_invariant(times):
    mit = StragglerMitigator(n_devices=len(times))
    mit.observe(np.asarray(times))
    parts = mit.rebalanced_partitions(n_tokens=len(times) * 160, seg_size=8)
    assert sum(parts) == len(times) * 160
    assert all(p >= 8 for p in parts)


@given(st.lists(st.floats(1e-4, 1e4), min_size=2, max_size=16),
       st.integers(1, 16), st.integers(0, 64))
@settings(max_examples=60, deadline=None)
def test_rebalance_properties_extreme_skew(times, seg_size, extra_segs):
    """Partitions stay positive, segment-quantized, and sum to n_tokens even
    under extreme speed skew, where naive rounding used to overdraw the
    fastest device's share (negative drift → zero/negative partition)."""
    n = len(times)
    n_tokens = (n + extra_segs) * seg_size
    mit = StragglerMitigator(n_devices=n)
    mit.observe(np.asarray(times))
    parts = mit.rebalanced_partitions(n_tokens=n_tokens, seg_size=seg_size)
    assert len(parts) == n
    assert all(p > 0 for p in parts), parts
    assert all(p % seg_size == 0 for p in parts), parts
    assert sum(parts) == n_tokens, parts


def test_rebalance_negative_drift_regression():
    """The seed's drift fix subtracted the overdraft from the fastest
    device; with one dominant device and many slow ones at the minimum, it
    went non-positive.  Now the overdraft is reclaimed one segment at a
    time from the largest allocations."""
    mit = StragglerMitigator(n_devices=8)
    mit.observe(np.array([1e-4] + [10.0] * 7))   # one device ~owns the fleet
    parts = mit.rebalanced_partitions(n_tokens=160, seg_size=10)
    assert sum(parts) == 160
    assert all(p > 0 for p in parts)
    assert parts[0] == max(parts)                # fast device keeps the bulk


def test_rebalance_too_few_segments_rejected():
    mit = StragglerMitigator(n_devices=4)
    mit.observe(np.ones(4))
    with pytest.raises(ValueError, match="fewer than"):
        mit.rebalanced_partitions(n_tokens=30, seg_size=10)


# --- elastic drop: explicit failed ids --------------------------------------

def test_elastic_drop_explicit_ids():
    mgr = ElasticMeshManager(cfg=None, mode=None,
                             devices=["d0", "d1", "d2", "d3"])
    mgr.drop(["d1"], rebuild=False)
    assert mgr.devices == ["d0", "d2", "d3"]     # not the tail!
    mgr.drop(["d3", "d0"], rebuild=False)
    assert mgr.devices == ["d2"]
    with pytest.raises(ValueError, match="not in the healthy"):
        mgr.drop(["nope"], rebuild=False)


def test_elastic_drop_int_overload_and_device_ids():
    class Dev:                                   # duck-typed jax device
        def __init__(self, i):
            self.id = i

        def __repr__(self):
            return f"Dev({self.id})"

    devs = [Dev(i) for i in range(4)]
    mgr = ElasticMeshManager(cfg=None, mode=None, devices=list(devs))
    mgr.drop(1, rebuild=False)                   # legacy count overload
    assert mgr.devices == devs[:3]
    mgr.drop([0], rebuild=False)                 # match by .id
    assert mgr.devices == devs[1:3]
    with pytest.raises(ValueError, match="cannot drop"):
        mgr.drop(7, rebuild=False)
