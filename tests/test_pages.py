"""Paged KV pool: allocator/COW/commitment invariants, prefix-cache
token-exactness vs sequential ``session.generate``, cold-page codec round
trips, and the one-executable regression for the paged chunk."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_fallback import given, settings, st
from repro.api import ExecutionPlan, InferenceSession
from repro.api import generation as gen
from repro.serving import (PageAllocator, PagedPool, PagesExhausted,
                           ServingRuntime)


@pytest.fixture(scope="module")
def session():
    s = InferenceSession.from_config(
        "llama3.2-1b", reduced={"vocab_size": 64},
        plans=[ExecutionPlan.local(), ExecutionPlan.prism_sim(L=4, cr=9.9)])
    s.profile(backend="simulated")
    return s


def _prompt(T0, seed=0):
    return np.random.RandomState(seed).randint(1, 64, T0)


def _served(rt, reqs):
    done = rt.run()
    got = {c.request_id: c.tokens for c in done}
    return [got[r.id] for r in reqs]


# --- allocator property tests ----------------------------------------------

@given(st.lists(st.integers(0, 999), min_size=1, max_size=80),
       st.integers(1, 12))
@settings(deadline=None, max_examples=25)
def test_allocator_churn_never_leaks_or_double_frees(ops, n_pages):
    """Random alloc/retain/release churn: the free list and the refcounts
    always partition the pages, and releasing every holder drains the pool
    back to fully free."""
    alloc = PageAllocator(n_pages)
    holders = {}
    for op in ops:
        act = op % 3
        if act == 0 and alloc.available() >= 1:
            alloc.commit(1)
            pid = alloc.alloc(1)[0]
            assert pid not in holders
            holders[pid] = 1
        elif act in (1, 2) and holders:
            pid = sorted(holders)[op % len(holders)]
            if act == 1:
                alloc.retain(pid)
                holders[pid] += 1
            else:
                alloc.release(pid)
                holders[pid] -= 1
                if holders[pid] == 0:
                    del holders[pid]
        alloc.check()
        assert alloc.refs == holders
    for pid, n in list(holders.items()):
        for _ in range(n):
            alloc.release(pid)
    alloc.check()
    assert len(alloc.free) == n_pages
    assert not alloc.refs and alloc.committed == 0


def test_allocator_rejects_double_free_and_overcommit():
    alloc = PageAllocator(4)
    alloc.commit(2)
    a, b = alloc.alloc(2)
    alloc.release(a)
    with pytest.raises(KeyError):
        alloc.release(a)                       # double free
    with pytest.raises(PagesExhausted):
        alloc.commit(4)                        # only 3 free, 0 uncommitted? 3
    alloc.release(b)
    with pytest.raises(RuntimeError):
        alloc.alloc(1)                         # draws past the commitment


# --- serving token-exactness ------------------------------------------------

def test_paged_runtime_token_exact_vs_generate(session):
    """The acceptance bar: every request served through the paged pool
    (greedy AND sampled, unaligned prompt lengths, on-demand page growth
    across chunks) matches ``session.generate`` token-for-token."""
    rt = ServingRuntime(session, chunk=3, max_len=32, page_size=8,
                        n_pages=16, n_rows=3)
    reqs = []
    for i, (T0, n_new, temp) in enumerate(
            [(4, 6, 0.0), (9, 5, 1.0), (13, 7, 0.0), (6, 4, 1.0),
             (16, 5, 0.0), (5, 9, 0.7)]):
        reqs.append(rt.submit(_prompt(T0, seed=i), n_new, seed=i,
                              temperature=temp))
    outs = _served(rt, reqs)
    for req, out in zip(reqs, outs):
        ref = session.generate(jnp.asarray(req.prompt)[None], req.n_new,
                               seed=req.seed, temperature=req.temperature)
        np.testing.assert_array_equal(out, np.asarray(ref)[0])


def test_paged_prism_pool_token_exact():
    """Paged decode under a PRISM-routed plan (the prefill runs the plan's
    exchange semantics; decode reads the paged pool) still matches the
    per-request compiled generate on that plan."""
    sess = InferenceSession.from_config(
        "llama3.2-1b", reduced={"vocab_size": 64},
        plans=[ExecutionPlan.prism_sim(L=2, cr=9.9)],
        allow_modes=("prism",))
    sess.profile(backend="simulated")
    rt = ServingRuntime(sess, chunk=4, max_len=16, page_size=4, n_pages=12,
                        n_rows=3)
    reqs = [rt.submit(_prompt(5, seed=i), 5, seed=i,
                      temperature=float(i % 2)) for i in range(4)]
    outs = _served(rt, reqs)
    plan = sess.plans["prism@9.9"]
    for req, out in zip(reqs, outs):
        ref = sess.generate(jnp.asarray(req.prompt)[None], req.n_new,
                            plan=plan, seed=req.seed,
                            temperature=req.temperature)
        np.testing.assert_array_equal(out, np.asarray(ref)[0])


def test_prefix_hits_token_exact_vs_unshared(session):
    """Full hits (cached-logits first token + COW tail), partial hits
    (suffix-only prefill over shared pages), and concurrent sharers must
    all reproduce the unshared ``session.generate`` chain exactly."""
    base = _prompt(13, seed=42)                # unaligned vs page_size=8
    cases = [(list(base), 0.0),                # miss → inserts the entry
             (list(base), 1.0),                # full hit, sampled
             (list(base) + [7, 3, 9], 0.0),    # partial hit past the tail
             (list(base) + [5], 0.8)]          # partial hit, sampled
    rt = ServingRuntime(session, chunk=4, max_len=32, page_size=8,
                        n_pages=24, n_rows=4)
    outs, reqs = [], []
    for i, (p, temp) in enumerate(cases):      # sequential: hits see entry
        r = rt.submit(p, 5, seed=50 + i, temperature=temp)
        reqs.append(r)
        outs.append(_served(rt, [r])[0])
    for (p, temp), req, out in zip(cases, reqs, outs):
        ref = session.generate(jnp.asarray([p]), 5, seed=req.seed,
                               temperature=temp)
        np.testing.assert_array_equal(out, np.asarray(ref)[0])
    pool = next(iter(rt.pools.values()))
    assert pool.stats["full_hits"] == 1
    assert pool.stats["partial_hits"] == 2
    assert pool.stats["cow_splits"] >= 3       # every unaligned-tail share
    pool.alloc.check()


def test_prefix_sharing_saves_pages_and_prefill(session):
    """N requests extending one cached prefix: page use stays far below
    N x prompt pages (full pages are shared, only tails split), and no
    full-length prefill executable runs for the sharers."""
    base = list(_prompt(16, seed=7))           # exactly 2 pages @ ps=8
    rt = ServingRuntime(session, chunk=4, max_len=32, page_size=8,
                        n_pages=24, n_rows=6)
    r0 = rt.submit(base, 4, seed=0)
    _served(rt, [r0])                          # entry now cached
    before = gen.build_count()
    reqs = [rt.submit(base + [10 + j], 4, seed=j) for j in range(4)]
    outs = _served(rt, reqs)
    pool = next(iter(rt.pools.values()))
    assert pool.stats["partial_hits"] == 4
    # sharers compile no new prefill: the 1-token suffix scan was built by
    # nothing else, so allow exactly the first sharer's suffix build
    assert gen.build_count() - before <= 1
    for j, (req, out) in enumerate(zip(reqs, outs)):
        ref = session.generate(jnp.asarray([base + [10 + j]]), 4, seed=j)
        np.testing.assert_array_equal(out, np.asarray(ref)[0])


# --- admission is page-bounded ----------------------------------------------

def test_admission_bounded_by_pages_not_rows(session):
    """With plentiful rows but few pages, concurrency is capped by the page
    budget (commitments), yet everything still completes via requeue."""
    rt = ServingRuntime(session, chunk=4, max_len=32, page_size=8,
                        n_pages=4, n_rows=8)   # 4 pages, 8 rows
    # each request commits ceil((5+4)/8) = 2 pages → at most 2 in flight
    reqs = [rt.submit(_prompt(5, seed=i), 4, seed=i) for i in range(5)]
    outs = _served(rt, reqs)
    assert rt.stats["max_concurrent"] <= 2
    for req, out in zip(reqs, outs):
        ref = session.generate(jnp.asarray(req.prompt)[None], req.n_new,
                               seed=req.seed)
        np.testing.assert_array_equal(out, np.asarray(ref)[0])
    pool = next(iter(rt.pools.values()))
    pool.alloc.check()
    assert pool.alloc.committed == 0           # all commitments returned


def test_paged_pool_rejects_oversized_and_occupied(session):
    plan = session.plans["local"]
    pool = PagedPool(session, plan, 2, n_pages=4, page_size=4, max_pages=4)
    from repro.serving import Request
    big = Request(_prompt(4), n_new=20, arrival_ts=0.0)   # 24 > 16 positions
    with pytest.raises(ValueError):
        pool.admit(big, 0, "local", False, 0.0)
    with pytest.raises(ValueError):
        PagedPool(session, plan, 2, n_pages=2, page_size=4, max_pages=4)


def test_hit_under_eviction_pressure_falls_back_to_miss(session):
    """Regression: a prefix hit whose page reservation forces make_room to
    evict the very entry it just matched (cache-only pages ARE the
    reclaimable headroom counted by can_admit) must fall back to the miss
    path — not retain freed pages or COW-copy from a recycled one."""
    base = list(_prompt(13, seed=21))          # 2 pages @ ps=8 (tail=5)
    rt = ServingRuntime(session, chunk=4, max_len=24, page_size=8,
                        n_pages=3, n_rows=2)
    r0 = rt.submit(base, 4, seed=0)
    _served(rt, [r0])
    pool = next(iter(rt.pools.values()))
    assert len(pool.prefix.entries) == 1
    # extends the cached prefix, but reserving its non-shared pages (2)
    # exceeds the 1 free page, so _reserve must evict the entry itself
    r1 = rt.submit(base + [7, 3, 9], 4, seed=1)
    out = _served(rt, [r1])[0]
    ref = session.generate(jnp.asarray([base + [7, 3, 9]]), 4, seed=1)
    np.testing.assert_array_equal(out, np.asarray(ref)[0])
    assert pool.prefix.evictions >= 1          # the hit really was voided
    assert pool.stats["prefix_misses"] == 2
    pool.alloc.check()
    assert pool.alloc.committed == 0


def test_failed_miss_admission_rolls_back_commitments(session, monkeypatch):
    """Regression: an exception after _reserve (device failure mid-prefill)
    must return the reservation and every alloc'd page, leaving the pool
    as admissible as before the attempt."""
    from repro.serving import Request
    plan = session.plans["local"]
    pool = PagedPool(session, plan, 2, n_pages=8, page_size=4, max_pages=8)
    monkeypatch.setattr(pool.session, "prime_slot",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("device fell over")))
    req = Request(_prompt(5, seed=1), n_new=4, arrival_ts=0.0)
    with pytest.raises(RuntimeError, match="device fell over"):
        pool.admit(req, 0, "local", False, 0.0)
    pool.alloc.check()
    assert pool.alloc.committed == 0 and not pool.alloc.refs
    assert pool.slots[0] is None
    assert (pool.page_table == pool.trash).all()
    # the pool still serves after the failed attempt (nothing leaked)
    monkeypatch.undo()
    act = pool.admit(req, 0, "local", False, 0.0)
    assert act is pool.slots[0]
    pool.evict(0)
    pool.alloc.check()
    assert pool.alloc.committed == 0


def test_failed_hit_admission_keeps_cache_consistent(session, monkeypatch):
    """Regression: when a partial-hit admission dies after retaining shared
    pages and COW-splitting the tail, rollback must drop only the request's
    references — the cached entry (and its refcounts) stay intact."""
    from repro.serving import Request
    base = list(_prompt(13, seed=33))
    rt = ServingRuntime(session, chunk=4, max_len=32, page_size=8,
                        n_pages=16, n_rows=4)
    r0 = rt.submit(base, 4, seed=0)
    _served(rt, [r0])
    pool = next(iter(rt.pools.values()))
    entry = next(iter(pool.prefix.entries.values()))
    refs0 = dict(pool.alloc.refs)
    monkeypatch.setattr(pool.session, "suffix_paged",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("boom")))
    req = Request(np.asarray(base + [5], np.int32), n_new=4, arrival_ts=0.0)
    with pytest.raises(RuntimeError, match="boom"):
        pool.admit(req, pool.free_slots()[0], "local", False, 0.0)
    pool.alloc.check()
    assert pool.alloc.refs == refs0            # request refs rolled back
    assert pool.alloc.committed == 0
    assert pool.prefix.entries.get(entry.digest) is entry


def test_evicting_all_requests_frees_every_page(session):
    """Serve → drain → drop prefix entries: the pool must return to fully
    free with zero refcounts and zero commitments (no leak across the
    admit/ensure/evict/COW lifecycle)."""
    rt = ServingRuntime(session, chunk=4, max_len=32, page_size=8,
                        n_pages=16, n_rows=4)
    base = list(_prompt(13, seed=3))
    for i, p in enumerate([base, base + [1, 2], list(_prompt(6, seed=4))]):
        r = rt.submit(p, 4, seed=i)
        _served(rt, [r])
    pool = next(iter(rt.pools.values()))
    pool.alloc.check()
    for digest in list(pool.prefix.entries):
        pool.prefix.evict_entry(digest)
    pool.alloc.check()
    assert len(pool.alloc.free) == pool.n_pages
    assert not pool.alloc.refs and pool.alloc.committed == 0
    assert (pool.page_table == pool.trash).all()


# --- cold pages --------------------------------------------------------------

def test_cold_pages_roundtrip_within_codec_tolerance(session):
    """Quantize-to-cold then revive: page contents must come back within
    the int8 codec's per-vector tolerance (scale = maxabs/127, plus the
    pool dtype's own rounding)."""
    rt = ServingRuntime(session, chunk=4, max_len=32, page_size=8,
                        n_pages=16, n_rows=4, cold_horizon=1)
    r0 = rt.submit(list(_prompt(12, seed=9)), 4, seed=0)
    _served(rt, [r0])
    pool = next(iter(rt.pools.values()))
    entry = next(iter(pool.prefix.entries.values()))
    idx = jnp.asarray(entry.pages(), jnp.int32)
    before = [np.asarray(l[:, idx], np.float32)
              for l in jax.tree_util.tree_leaves(pool.pool)]
    pool.prefix.clock += 2                     # age the entry past horizon
    pool._sweep_cold()
    assert entry.cold and entry.payloads is not None
    pool.alloc.check()
    revived = pool._revive(entry)
    assert revived is not None and not revived.cold
    idx2 = jnp.asarray(revived.pages(), jnp.int32)
    after = [np.asarray(l[:, idx2], np.float32)
             for l in jax.tree_util.tree_leaves(pool.pool)]
    for b, a in zip(before, after):
        tol = np.abs(b).max(axis=-1, keepdims=True) * 0.02 + 1e-6
        assert np.all(np.abs(a - b) <= tol)
    # the revived entry serves a (lossy-tolerated) hit end to end
    r1 = rt.submit(list(_prompt(12, seed=9)), 4, seed=5)
    out = _served(rt, [r1])[0]
    assert out.shape == (4,)
    assert pool.stats["dequant_pages"] == len(entry.pages())


# --- compilation discipline ---------------------------------------------------

def test_paged_one_executable_per_shape(session):
    """Admissions, page growth, and varying page tables must NOT build new
    executables: one compiled paged chunk per (plan, rows, max_pages,
    chunk), reused across runtimes of the same shape."""
    def drive(seeds):
        rt = ServingRuntime(session, chunk=3, max_len=32, page_size=8,
                            n_pages=16, n_rows=3, prefix_cache=False)
        reqs = [rt.submit(_prompt(5, seed=s), 4, seed=s) for s in seeds]
        _served(rt, reqs)
        return rt

    drive([0, 1, 2, 3])                        # warm every executable
    before = gen.build_count()
    rt = drive([7, 8, 9, 10, 11])
    assert gen.build_count() == before         # everything cache-hit
    assert rt.stats["admitted"] == 5
