"""Checkpoint manager: atomic sharded save/restore, rotation, async."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointManager, latest_step,
                                      load_pytree, save_pytree)


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {"layer": {"w": jnp.asarray(rng.randn(4, 8), jnp.float32),
                      "b": jnp.asarray(rng.randn(8), jnp.bfloat16)},
            "step": jnp.asarray(3, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path), step=7)
    restored = load_pytree(jax.tree_util.tree_map(jnp.zeros_like, t),
                           str(tmp_path))
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_and_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        mgr.save(_tree(s), s)
    assert mgr.latest == 30
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000020", "step_00000030"]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save_async(_tree(1), 5)
    mgr.wait()
    assert mgr.latest == 5
    restored = mgr.restore(_tree(99))
    np.testing.assert_array_equal(np.asarray(restored["layer"]["w"]),
                                  np.asarray(_tree(1)["layer"]["w"]))


def test_restore_or_none_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.restore_or_none(_tree()) is None


def test_missing_leaf_raises(tmp_path):
    save_pytree({"a": jnp.zeros(3)}, str(tmp_path), step=1)
    with pytest.raises(KeyError):
        load_pytree({"a": jnp.zeros(3), "b": jnp.zeros(2)}, str(tmp_path))


def test_atomicity_no_partial_dirs(tmp_path):
    save_pytree(_tree(), str(tmp_path), step=2)
    assert all("tmp" not in d for d in os.listdir(tmp_path))
