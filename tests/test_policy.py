"""Performance map + adaptive policy: paper §3.3 semantics."""
import os

import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.api import (PAPER_BATCHES, PAPER_BWS, PAPER_CRS, AdaptivePolicy,
                       PerfEntry, PerfKey, PerfMap, SweepSpec,
                       profile_simulated, sweep_cost)
from repro.core.costmodel import EdgeCostModel


@pytest.fixture(scope="module")
def perfmap():
    return profile_simulated()


def test_sweep_cost_formula():
    """Paper: ~|B|·|CR|·|BW|·T passes ≈ a few thousand, 'a one-time
    profiling sweep of ~200 inference passes' per configuration grid cell."""
    spec = SweepSpec()
    assert sweep_cost(spec) == 6 * 3 * 8 * 20


def test_perfmap_roundtrip(tmp_path, perfmap):
    path = str(tmp_path / "perf.json")
    perfmap.save(path)
    loaded = PerfMap.load(path)
    assert len(loaded) == len(perfmap)
    k = PerfKey("prism", 8, 9.9, 400.0)
    assert loaded.get(k).total_ms == pytest.approx(perfmap.get(k).total_ms)


def test_policy_batch_crossover_is_8(perfmap):
    """Paper §5.1: 'Adaptive crossover at batch 8' at ≈400 Mbps."""
    pol = AdaptivePolicy(perfmap)
    assert pol.batch_crossover(400.0) == 8
    for b in (1, 2, 4):
        assert not pol.decide(b, 400.0).distributed
    for b in (8, 16, 32):
        assert pol.decide(b, 400.0).distributed


def test_policy_picks_best_cr(perfmap):
    d = pol = AdaptivePolicy(perfmap).decide(32, 400.0)
    assert d.mode == "prism"
    assert d.cr == max(PAPER_CRS)      # highest compression wins on latency


def test_policy_energy_objective(perfmap):
    pol = AdaptivePolicy(perfmap)
    d = pol.decide(16, 400.0, objective="energy")
    assert d.objective == "energy"
    assert d.expected.per_sample_j <= pol.decide(
        16, 400.0, objective="latency").expected.per_sample_j + 1e-9


def test_voltage_never_selected(perfmap):
    """Paper: full-tensor exchange loses at every batch size — the policy
    (allowed all modes) must never pick it."""
    pol = AdaptivePolicy(perfmap, allow_modes=("local", "prism", "voltage"))
    for b in PAPER_BATCHES:
        for bw in PAPER_BWS:
            assert pol.decide(b, bw).mode != "voltage"


def test_bandwidth_crossover_near_paper(perfmap):
    """Paper Fig. 6: PRISM crosses single-device near 340 Mbps at B=8 —
    accept the [200, 500] band for the simulator."""
    pol = AdaptivePolicy(perfmap)
    bw = pol.bandwidth_crossover(8)
    assert bw is not None and 200 <= bw <= 500


@given(st.integers(1, 64), st.floats(100, 1000))
@settings(max_examples=30, deadline=None)
def test_policy_total_function(b, bw):
    pm = profile_simulated()
    d = AdaptivePolicy(pm).decide(b, bw)
    assert d.mode in ("local", "prism")
    assert d.expected.per_sample_ms > 0
