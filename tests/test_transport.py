"""`repro.transport` — codecs, links, executor, and the codec policy axis."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import (AdaptivePolicy, CodecSpec, ExecutionPlan,
                       InferenceSession, PerfKey, SweepSpec, exchange_cost,
                       get_codec, get_link, list_codecs, list_links,
                       plan_wire_bytes)
from repro.core.exchange import exchange_attention
from repro.core.partition import (simulate_prism_attention,
                                  simulate_voltage_attention)
from repro.profiling import WIFI_GLOO
from repro.transport import (codec_sim_attention, payload_nbytes,
                             register_codec)
from repro.transport.codecs import ExchangeCodec

from _hypothesis_fallback import given, settings, st


def _rand(shape, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape),
                       jnp.float32)


# ---------------------------------------------------------------------------
# codec round trips + exact wire accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,spec", [
    ("identity", CodecSpec()),
    ("int8", CodecSpec()),
    ("int8", CodecSpec(param=8)),
    ("int4", CodecSpec()),
    ("int4", CodecSpec(param=8)),
    ("topk", CodecSpec(param=4)),
    ("segment_means", CodecSpec(L=4)),
])
def test_wire_bytes_match_payload(name, spec):
    """`wire_bytes` must equal the summed nbytes of the encoded leaves —
    the accounting can never drift from the arrays."""
    x = _rand((2, 8, 4, 16))
    codec = get_codec(name)
    payload = codec.encode(x, spec)
    assert codec.wire_bytes(x.shape, x.dtype, spec) == payload_nbytes(payload)
    assert codec.ratio(x.shape, x.dtype, spec) >= 1.0


def test_identity_roundtrip_exact():
    x = _rand((2, 8, 4, 16))
    c = get_codec("identity")
    out = c.decode(c.encode(x, CodecSpec()), CodecSpec())
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


@pytest.mark.parametrize("name,qmax,min_ratio", [("int8", 127, 3.0),
                                                 ("int4", 7, 6.0)])
def test_quant_roundtrip_error_bound(name, qmax, min_ratio):
    """Symmetric per-tile quantization: error ≤ half a quantization step
    of the tile's amax, and the wire really shrinks."""
    x = _rand((2, 16, 2, 32), seed=1)
    spec = CodecSpec()
    c = get_codec(name)
    dec = c.decode(c.encode(x, spec), spec, dtype=x.dtype)
    step = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True) / qmax
    assert np.all(np.abs(np.asarray(dec - x)) <= step * 0.5 + 1e-6)
    assert c.ratio(x.shape, x.dtype, spec) >= min_ratio


@given(st.integers(1, 6), st.integers(1, 4), st.floats(0.1, 50.0))
@settings(max_examples=15, deadline=None)
def test_quant_roundtrip_property(tokens, tiles, amp):
    """Any shape/amplitude: quantized round trip stays within one step."""
    feat = 8 * tiles
    x = amp * _rand((1, tokens, feat), seed=tokens + tiles)
    for name, qmax in (("int8", 127), ("int4", 7)):
        spec = CodecSpec(param=8)
        dec = get_codec(name).decode(get_codec(name).encode(x, spec), spec)
        step = np.max(np.abs(np.asarray(x).reshape(1, tokens, tiles, 8)),
                      axis=-1, keepdims=True) / qmax
        err = np.abs(np.asarray(dec - x)).reshape(1, tokens, tiles, 8)
        assert np.all(err <= step * 0.5 + 1e-5 * amp)


def test_topk_keeps_largest_exactly():
    x = _rand((2, 6, 3, 16), seed=2)
    spec = CodecSpec(param=4)
    c = get_codec("topk")
    dec = np.asarray(c.decode(c.encode(x, spec), spec, shape=x.shape,
                              dtype=x.dtype))
    xn = np.asarray(x)
    # exactly k nonzeros per vector, equal to the k largest-|x| entries
    nz = (dec != 0).sum(axis=-1)
    assert np.all(nz <= spec.param)
    order = np.argsort(-np.abs(xn), axis=-1)
    for idx in np.ndindex(xn.shape[:-1]):
        kept = order[idx][:spec.param]
        np.testing.assert_allclose(dec[idx][kept], xn[idx][kept], rtol=1e-6)
        dropped = order[idx][spec.param:]
        assert np.all(dec[idx][dropped] == 0)


def test_segment_means_codec_matches_kernel_reference():
    from repro.core import segment_means as ref_sm
    x = _rand((2, 12, 4, 8), seed=3)
    spec = CodecSpec(L=3)
    enc = get_codec("segment_means").encode(x, spec)
    np.testing.assert_array_equal(
        np.asarray(enc["means"]),
        np.asarray(ref_sm.segment_means(x, 3, axis=1)))


def test_codec_registry_contract():
    assert {"identity", "segment_means", "int8", "int4",
            "topk"} <= set(list_codecs())
    with pytest.raises(KeyError, match="unknown exchange codec"):
        get_codec("nope")
    with pytest.raises(ValueError, match="reserved"):
        @register_codec
        class Bad(ExchangeCodec):        # pragma: no cover - name rejected
            name = "has|pipe"
    with pytest.raises(ValueError, match="already registered"):
        @register_codec
        class Dup(ExchangeCodec):        # pragma: no cover - dup rejected
            name = "int8"


# ---------------------------------------------------------------------------
# exchange numerics
# ---------------------------------------------------------------------------

def test_prism_sim_codec_default_token_exact():
    """Acceptance: the refactored exchange under the (default)
    segment-means codec is numerically identical to the pre-refactor
    PRISM path."""
    q, k, v = (_rand((2, 32, 4, 16), seed=s) for s in (0, 1, 2))
    cfg = ExecutionPlan.prism_sim(L=4, cr=4.0).to_exchange_config()
    out = exchange_attention(q, k, v, cfg, causal=True)
    ref = simulate_prism_attention(q, k, v, 2, 4, causal=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # spelling the codec explicitly is the same plan, same bytes
    cfg2 = ExecutionPlan("prism_sim", 4.0, 4, "seq", 2,
                         codec="segment_means").to_exchange_config()
    np.testing.assert_array_equal(
        np.asarray(exchange_attention(q, k, v, cfg2, causal=True)),
        np.asarray(ref))


def test_identity_codec_sim_equals_voltage():
    q, k, v = (_rand((2, 32, 4, 16), seed=s) for s in (0, 1, 2))
    out = codec_sim_attention(q, k, v, 2, "identity", CodecSpec(),
                              causal=True)
    ref = simulate_voltage_attention(q, k, v, 2, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@pytest.mark.parametrize("codec,param,tol", [("int8", 0, 0.05),
                                             ("int4", 0, 0.3)])
def test_quant_codec_sim_close_to_exact(codec, param, tol):
    q, k, v = (_rand((2, 32, 4, 16), seed=s) for s in (0, 1, 2))
    cfg = ExecutionPlan("prism_sim", seq_axis="seq", seq_shards=2,
                        codec=codec, codec_param=param).to_exchange_config()
    out = exchange_attention(q, k, v, cfg, causal=True)
    ref = simulate_voltage_attention(q, k, v, 2, causal=True)
    assert float(jnp.max(jnp.abs(out - ref))) < tol


# ---------------------------------------------------------------------------
# identity: keys, plans
# ---------------------------------------------------------------------------

def test_perfkey_codec_roundtrip():
    k = PerfKey("prism", 8, 3.95, 400.0, "int8")
    assert k.encode() == "prism|8|3.95|400|int8"
    assert PerfKey.decode(k.encode()) == k
    # pre-codec 4-part keys still load (codec defaults to "")
    assert PerfKey.decode("prism|8|9.9|400") == PerfKey("prism", 8, 9.9,
                                                        400.0)
    with pytest.raises(ValueError):
        PerfKey("prism", 8, 1.0, 0.0, "a|b")


def test_plan_codec_identity_and_parse():
    # explicit default codec normalizes away: one identity per executable
    p1 = ExecutionPlan.prism_sim(L=4, cr=9.9)
    p2 = ExecutionPlan("prism_sim", 9.9, 4, "seq", 2, codec="segment_means")
    assert p1 == p2 and p2.codec == "" and p2.key == "prism@9.9"
    assert p2.effective_codec == "segment_means"
    p8 = ExecutionPlan("prism", 3.98, 0, "seq", 2, codec="int8")
    assert p8.key == "prism@3.98+int8"
    rt = ExecutionPlan.parse(p8.key, codec_param=0)
    assert (rt.mode, rt.cr, rt.codec) == ("prism", 3.98, "int8")
    with pytest.raises(KeyError, match="unknown exchange codec"):
        ExecutionPlan("prism", 4.0, 0, "seq", 2, codec="bogus")
    with pytest.raises(ValueError, match="k > 0"):
        ExecutionPlan("prism", 4.0, 0, "seq", 2, codec="topk")
    pk = p8.to_perf_key(8, 400.0)
    assert pk.codec == "int8" and pk.cr == 3.98
    back = ExecutionPlan.from_perf_key(pk, codec_param=0)
    assert back.codec == "int8" and back.L == 0


def test_split_key_exponent_cr_is_not_a_codec():
    """%g can format a huge CR with an exponent '+' — the key parser must
    not read it as a codec separator (codec names start with a letter)."""
    from repro.api.plan import split_key
    assert split_key("prism@1e+06") == ("prism", 1e6, "")
    assert split_key("prism@4+int8") == ("prism", 4.0, "int8")
    assert split_key("prism+int8") == ("prism", 0.0, "int8")
    assert split_key("local") == ("local", 0.0, "")
    with pytest.raises(ValueError, match="start with a letter"):
        @register_codec
        class Numeric(ExchangeCodec):    # pragma: no cover - name rejected
            name = "0bad"


def test_calibrate_folds_codec_dispatches():
    """A codec plan registers at cr=0 while the sweep keys its entries at
    the achieved ratio — calibrate() must still fold the dispatch into
    that cell (and refine the link bandwidth), not skip it."""
    sess = InferenceSession.from_config(
        "llama3.2-1b", reduced={"vocab_size": 64},
        plans=[ExecutionPlan.local(),
               ExecutionPlan("prism_sim", seq_axis="seq", seq_shards=2,
                             codec="int8")],
        allow_modes=("prism",), initial_bandwidth_mbps=400.0)
    sess.profile(SweepSpec(crs=(), codecs=("int8",)), backend="simulated")
    sess.dispatch({"tokens": jnp.ones((2, 8), jnp.int32)})
    rec = sess.history[-1]
    assert rec.exec_key == "prism+int8" and rec.wire_bytes > 0
    rep = sess.calibrate()
    assert rep.updated == 1 and rep.skipped_unprofiled == 0
    assert rep.bandwidth_updates == 1
    e = next(e for k, e in sess.perfmap.entries() if k.codec == "int8"
             and k.batch == 2 and k.bandwidth_mbps == 400.0)
    assert e.meta.get("calibrations") == 1


# ---------------------------------------------------------------------------
# links + accounting
# ---------------------------------------------------------------------------

def test_link_registry_and_stages():
    assert {"direct", "staged"} <= set(list_links())
    kw = dict(wire_bytes_per_call=1e6, n_calls=12, bandwidth_mbps=400.0,
              profile=WIFI_GLOO, raw_bytes_total=4e6, decode_bw=1e9)
    staged = get_link("staged").cost(**kw)
    direct = get_link("direct").cost(**kw)
    assert staged.staging_ms > 0 and direct.staging_ms == 0
    assert staged.wire_ms == pytest.approx(direct.wire_ms)
    assert staged.decode_ms == pytest.approx(4.0)
    assert staged.total_ms == pytest.approx(sum(staged.stages().values()))


def test_segment_means_accounting_matches_cost_model():
    """The transport accounting and the edge cost model must agree on the
    paper's PRISM staging/wire terms (no drift between the two)."""
    from repro.core.costmodel import EdgeCostModel
    model = EdgeCostModel()
    B, P, L, bw = 8, 2, 10, 400.0
    r = model.distributed(B, bw, P, L=L)
    t = exchange_cost("segment_means", n_tokens=model.w.n_tokens,
                      d_model=model.w.d_model,
                      bytes_per_el=model.w.bytes_per_el, batch=B, P=P,
                      n_layers=model.w.n_layers, bandwidth_mbps=bw,
                      profile=WIFI_GLOO, L=L)
    assert t["staging_ms"] == pytest.approx(r["staging_ms"])
    assert t["comm_ms"] == pytest.approx(r["comm_ms"])


def test_plan_wire_bytes():
    local = ExecutionPlan.local()
    prism = ExecutionPlan.prism_sim(L=20, cr=4.95)
    volt = ExecutionPlan.voltage()
    assert plan_wire_bytes(local, _VIT_CFG, 8) == 0
    wp = plan_wire_bytes(prism, _VIT_CFG, 8)
    wv = plan_wire_bytes(volt, _VIT_CFG, 8)
    assert 0 < wp < wv                      # compression shrinks the wire
    assert plan_wire_bytes(prism, _VIT_CFG, 16) == 2 * wp   # ∝ batch


# ---------------------------------------------------------------------------
# the codec axis in the policy
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def vit_session():
    s = InferenceSession.from_config(
        "vit-base-16", plans=[ExecutionPlan.local(),
                              ExecutionPlan.prism_sim(L=20, cr=4.95)])
    return s


from repro.configs import get_config                       # noqa: E402
_VIT_CFG = get_config("vit-base-16")


def test_codec_sweep_preserves_paper_artifacts(vit_session):
    """Adding the codec axis must not move the classic crossovers."""
    pm0 = vit_session.profile(backend="simulated")
    base = AdaptivePolicy(pm0)
    a = (base.batch_crossover(400.0), base.bandwidth_crossover(8))
    pm1 = vit_session.profile(SweepSpec(codecs=("int8", "int4")),
                              backend="simulated")
    aug = AdaptivePolicy(pm1)
    assert (aug.batch_crossover(400.0), aug.bandwidth_crossover(8)) == a


def test_policy_flips_codec_as_bandwidth_drops(vit_session):
    """Satellite regression: with the quantized codecs as the only
    distributed candidates, `decide()` trades the cheaper dequantization
    (int8) at high bandwidth for the smaller wire (int4) as the link
    degrades — a codec-aware decision, surfaced in `exec_key`."""
    pm = vit_session.profile(SweepSpec(crs=(), codecs=("int8", "int4")),
                             backend="simulated")
    pol = AdaptivePolicy(pm, ("prism",))
    hi = pol.decide(8, 900.0)
    lo = pol.decide(8, 200.0)
    assert hi.codec == "int8" and "+int8" in hi.exec_key
    assert lo.codec == "int4" and "+int4" in lo.exec_key
    assert hi.wire_bytes > lo.wire_bytes > 0     # surfaced per decision


def test_measured_backend_profiles_codec_plans():
    """The measured backend composes its timed compute with the transport
    accounting for codec plans — entries land under the codec key."""
    sess = InferenceSession.from_config(
        "llama3.2-1b", reduced={"vocab_size": 64},
        plans=[ExecutionPlan.local(),
               ExecutionPlan("prism_sim", seq_axis="seq", seq_shards=2,
                             codec="int8")])
    pm = sess.profile(SweepSpec(batches=(1, 2), bandwidths_mbps=(400.0,)),
                      backend="measured", iters=1, warmup=0)
    e = next((e for k, e in pm.entries()
              if k.mode == "prism" and k.codec == "int8"), None)
    assert e is not None
    assert e.meta["codec"] == "int8" and e.meta["wire_bytes"] > 0
    assert e.staging_ms > 0 and e.comm_ms > 0


def test_codec_entries_have_wire_bytes(vit_session):
    pm = vit_session.profile(SweepSpec(codecs=("int8",)),
                             backend="simulated")
    seen = {k.codec for k, _ in pm.entries() if k.mode == "prism"}
    assert seen == {"", "int8"}
    for k, e in pm.entries():
        if k.mode != "local":
            assert e.meta.get("wire_bytes", 0) > 0


# ---------------------------------------------------------------------------
# telemetry: dispatch, explanation, calibration, serving
# ---------------------------------------------------------------------------

def test_dispatch_records_codec_and_wire_bytes(vit_session):
    from repro.profiling.backends import _dummy_batch
    sess = vit_session
    sess.profile(backend="simulated")
    batch = _dummy_batch(sess.cfg, 8, 0)
    sess._bw = 900.0                      # distributed wins at B=8/900
    sess.dispatch(batch)
    rec = sess.history[-1]
    assert rec.decision.distributed
    assert rec.codec == "segment_means" and rec.wire_bytes > 0
    sess._bw = 900.0
    sess.dispatch(_dummy_batch(sess.cfg, 1, 0))   # B=1 → local
    rec1 = sess.history[-1]
    assert not rec1.decision.distributed
    assert rec1.codec == "" and rec1.wire_bytes == 0


def test_explanation_surfaces_codec_and_wire(vit_session):
    vit_session.profile(backend="simulated")
    ex = vit_session.explain(8, 900.0)
    assert ex.decision.distributed
    assert ex.codec == "segment_means" and ex.wire_bytes > 0
    s = ex.summary()
    assert "codec=segment_means" in s and "MB on wire" in s


def test_calibrate_refines_bandwidth_from_wire_bytes(vit_session):
    """Satellite: observed bytes-on-wire fold a bytes/wall EWMA into the
    session's link estimate — calibrate() refines bandwidth, not just
    latency."""
    sess = InferenceSession.from_config(
        "vit-base-16", plans=[ExecutionPlan.local(),
                              ExecutionPlan.prism_sim(L=20, cr=4.95)])
    sess.profile(backend="simulated")
    sess._bw = 900.0
    d = sess.decide(8, 900.0)
    assert d.distributed
    # the entry calibrate() apportions the wall against is the map cell of
    # the executable that ran (the registered CR), at the nearest bw
    entry = sess.perfmap.get(PerfKey("prism", 8, 4.95, 900.0))
    from repro.api.session import DispatchRecord
    wire = plan_wire_bytes(sess.plans["prism@4.95"], sess.cfg, 8)
    sess.history.append(DispatchRecord(
        8, 900.0, d, wall_ms=entry.total_ms, exec_key="prism@4.95",
        codec="segment_means", wire_bytes=wire))
    before = sess.bandwidth
    rep = sess.calibrate(alpha=0.5)
    assert rep.bandwidth_updates == 1
    assert sess.bandwidth != before       # EWMA moved toward the implied bw
    implied = wire * 8e-3 / entry.comm_ms   # wall == profile ⇒ comm share
    expected = 0.3 * implied + 0.7 * before
    assert sess.bandwidth == pytest.approx(expected)


def test_serving_completions_carry_codec_and_wire():
    sess = InferenceSession.from_config(
        "llama3.2-1b", reduced={"vocab_size": 64},
        plans=[ExecutionPlan.local(), ExecutionPlan.prism_sim(L=2, cr=9.9)],
        allow_modes=("prism",), initial_bandwidth_mbps=900.0)
    sess.profile(backend="simulated")
    from repro.serving import ServingRuntime
    rt = ServingRuntime(sess, n_slots=2, chunk=4, max_len=32)
    rt.submit(np.arange(4) % 64, n_new=4, seed=0)
    comps = rt.run()
    assert len(comps) == 1
    c = comps[0]
    assert c.codec == "segment_means" and c.wire_bytes > 0
    assert rt.stats["wire_bytes"] == c.wire_bytes
