"""The unified `repro.api` surface: ExecutionPlan conversions, the strategy
registry, InferenceSession routing vs the raw policy, perf-map hardening,
and the legacy deprecation shims."""
import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AdaptivePolicy, ExchangeConfig, ExchangeMode,
                       ExecutionPlan, InferenceSession, PerfKey, PerfMap,
                       get_strategy, list_strategies, profile_simulated,
                       register_strategy)
from repro.api.strategies import ExchangeStrategy
from repro.core.perfmap import SCHEMA_VERSION, PerfEntry


@pytest.fixture(scope="module")
def perfmap():
    return profile_simulated()


@pytest.fixture(scope="module")
def session(perfmap):
    sess = InferenceSession.from_config(
        "llama3.2-1b", reduced={"vocab_size": 64},
        plans=[ExecutionPlan.local(), ExecutionPlan.prism_sim(L=4, cr=9.9)],
        perfmap=perfmap)
    return sess


# --- ExecutionPlan ---------------------------------------------------------

def test_plan_keys():
    assert ExecutionPlan.local().key == "local"
    assert ExecutionPlan.prism(L=10, cr=9.9).key == "prism@9.9"
    # prism_sim shares prism's profiling identity
    assert ExecutionPlan.prism_sim(L=4, cr=4.95).key == "prism@4.95"
    assert ExecutionPlan.voltage().key == "voltage"


def test_plan_exchange_config_roundtrip():
    plan = ExecutionPlan.prism(L=10, cr=9.9, seq_axis="seq", seq_shards=2,
                               batch_axes=("data",))
    xcfg = plan.to_exchange_config()
    assert xcfg == ExchangeConfig(ExchangeMode.PRISM, "seq", 2, L=10,
                                  batch_axes=("data",), strategy="prism")
    back = ExecutionPlan.from_exchange_config(xcfg, cr=9.9)
    assert back == plan
    # CR recoverable from the sequence length: CR = N/(L·P) = 197/(10·2)
    lifted = ExecutionPlan.from_exchange_config(xcfg, n_tokens=197)
    assert lifted.cr == pytest.approx(9.85)


def test_plan_local_exchange_config_is_degenerate():
    xcfg = ExecutionPlan.local().to_exchange_config()
    assert xcfg.mode == ExchangeMode.LOCAL
    assert xcfg.seq_axis is None and xcfg.seq_shards == 1


def test_plan_perf_key_roundtrip():
    plan = ExecutionPlan.prism(L=10, cr=9.9)
    pk = plan.to_perf_key(8, 400.0)
    assert pk == PerfKey("prism", 8, 9.9, 400.0)
    back = ExecutionPlan.from_perf_key(pk, n_tokens=197, seq_shards=2)
    assert back.mode == "prism" and back.cr == 9.9 and back.L == 10
    sim = ExecutionPlan.from_perf_key(pk, n_tokens=197, simulated=True)
    assert sim.mode == "prism_sim" and sim.key == plan.key
    # local plans profile at bw=0 regardless of the observed bandwidth
    assert ExecutionPlan.local().to_perf_key(4, 700.0) == \
        PerfKey("local", 4, 0.0, 0.0)


def test_plan_parse_legacy_keys():
    p = ExecutionPlan.parse("prism@9.9", L=4)
    assert p.mode == "prism" and p.cr == 9.9 and p.L == 4
    assert ExecutionPlan.parse("local") == ExecutionPlan.local()
    with pytest.raises(ValueError):
        ExecutionPlan.parse("prism@fast")


def test_plan_validation_errors():
    with pytest.raises(KeyError):
        ExecutionPlan(mode="warp")
    with pytest.raises(ValueError):                 # PRISM without L or CR
        ExecutionPlan(mode="prism", seq_axis="seq", seq_shards=2)
    with pytest.raises(ValueError):                 # shards without an axis
        ExecutionPlan(mode="voltage", seq_axis=None, seq_shards=2)


def test_plan_resolve_L():
    plan = ExecutionPlan(mode="prism", cr=9.9, seq_axis="seq", seq_shards=2)
    assert plan.resolve_L(197).L == 10
    assert plan.resolve_L(197).resolve_L(400).L == 10   # idempotent


def test_exchange_config_with_mode_preserves_all_fields():
    xcfg = ExchangeConfig(ExchangeMode.PRISM, "seq", 4, L=8,
                          batch_axes=("data", "pod"))
    out = xcfg.with_mode(ExchangeMode.VOLTAGE)
    assert out == dataclasses.replace(xcfg, mode=ExchangeMode.VOLTAGE)


# --- strategy registry -----------------------------------------------------

def test_registry_contents():
    assert set(list_strategies()) >= {"local", "voltage", "prism",
                                      "prism_sim"}
    assert get_strategy("prism").distributed
    assert not get_strategy("local").distributed
    assert get_strategy("prism_sim").perf_mode == "prism"
    assert not get_strategy("voltage").selectable


def test_registry_unknown_lookup():
    with pytest.raises(KeyError, match="unknown exchange strategy"):
        get_strategy("warp")


def test_registry_rejects_duplicates_and_anonymous():
    with pytest.raises(ValueError, match="already registered"):
        @register_strategy
        class Dup(ExchangeStrategy):       # noqa: F811 — intentional clash
            name = "local"
    with pytest.raises(ValueError, match="non-empty `name`"):
        @register_strategy
        class Anon(ExchangeStrategy):
            name = ""


def test_new_strategy_plugs_into_plans():
    """A custom strategy reusing a built-in ExchangeMode must actually be
    dispatched by exchange_attention (via ExchangeConfig.strategy), not
    silently resolve back to the built-in."""
    from repro.core.exchange import exchange_attention

    @register_strategy
    class EchoStrategy(ExchangeStrategy):
        name = "echo-test"
        exchange_mode = ExchangeMode.PRISM     # reuses a built-in mode
        distributed = True

        def _prefill(self, q, k, v, cfg, **kw):
            return q + 1.0                      # sentinel, no collectives
    try:
        plan = ExecutionPlan(mode="echo-test", seq_axis="seq", seq_shards=2)
        assert plan.key == "echo-test"
        xcfg = plan.to_exchange_config()
        assert xcfg.mode == ExchangeMode.PRISM and xcfg.strategy == "echo-test"
        q = jnp.zeros((1, 8, 2, 4), jnp.float32)
        out = exchange_attention(q, q, q, xcfg)
        assert float(out.sum()) == q.size       # EchoStrategy ran, not PRISM
    finally:
        from repro.api import strategies as S
        S._REGISTRY.pop("echo-test")


# --- perf-map hardening ----------------------------------------------------

def test_perfkey_rejects_pipe_mode():
    with pytest.raises(ValueError):
        PerfKey("pri|sm", 8, 9.9, 400.0)


def test_perfkey_decode_tolerates_float_batch():
    assert PerfKey.decode("prism|8.0|9.9|400").batch == 8
    with pytest.raises(ValueError):
        PerfKey.decode("prism|8.5|9.9|400")
    with pytest.raises(ValueError):
        PerfKey.decode("prism|8|9.9")          # missing field


def test_perfmap_schema_version_roundtrip(tmp_path, perfmap):
    path = str(tmp_path / "pm.json")
    perfmap.save(path)
    import json
    data = json.load(open(path))
    assert data["schema_version"] == SCHEMA_VERSION
    assert len(PerfMap.load(path)) == len(perfmap)


def test_perfmap_schema_version_mismatch(tmp_path, perfmap):
    path = str(tmp_path / "pm.json")
    perfmap.save(path)
    import json
    data = json.load(open(path))
    data["schema_version"] = SCHEMA_VERSION + 1
    json.dump(data, open(path, "w"))
    with pytest.raises(ValueError, match="schema version"):
        PerfMap.load(path)


def test_perfmap_loads_legacy_flat_format(tmp_path):
    """Pre-versioning maps (flat key→entry dict) still load."""
    import json
    entry = PerfEntry(1.0, 1.0, 0.1, 0.5, 0.2, 0.3)
    path = str(tmp_path / "legacy.json")
    json.dump({PerfKey("local", 1, 0.0, 0.0).encode(): entry.to_dict()},
              open(path, "w"))
    pm = PerfMap.load(path)
    assert pm.get(PerfKey("local", 1, 0.0, 0.0)).total_ms == 1.0


# --- InferenceSession ------------------------------------------------------

def test_session_dispatch_matches_policy(session, perfmap):
    """Routing under swept (batch, bandwidth) pairs == AdaptivePolicy.decide."""
    pol = AdaptivePolicy(perfmap)
    rng = np.random.RandomState(0)
    V = session.cfg.vocab_size
    for batch in (1, 4, 8, 32):
        for bw in (200.0, 400.0, 900.0):
            session._bw = bw                       # pin the EWMA state
            toks = jnp.asarray(rng.randint(0, V, (batch, 32)))
            out = session.dispatch({"tokens": toks})
            assert out.shape == (batch, 32, V)
            rec = session.history[-1]
            expect = pol.decide(batch, bw)
            assert rec.decision.mode == expect.mode
            assert rec.decision.cr == expect.cr
            assert rec.batch == batch
            assert not rec.substituted             # both plans registered
            want = ("local" if expect.mode == "local"
                    else f"{expect.mode}@{expect.cr:g}")
            assert rec.exec_key == want


def test_session_dispatch_substitution_recorded(perfmap):
    """No local executable registered → same-mode/any fallback, recorded."""
    sess = InferenceSession.from_config(
        "llama3.2-1b", reduced={"vocab_size": 64},
        plans=[ExecutionPlan.prism_sim(L=4, cr=3.3)], perfmap=perfmap)
    toks = jnp.ones((1, 32), jnp.int32)
    sess._bw = 400.0
    sess.dispatch({"tokens": toks})                # B=1 decides "local"
    rec = sess.history[-1]
    assert rec.decision.mode == "local"
    assert rec.substituted and rec.exec_key == "prism@3.3"


def test_session_explain_reproduces_paper_artifacts(session):
    exp = session.explain(8, 400.0)
    pol = session.policy
    assert exp.batch_crossover == pol.batch_crossover(400.0) == 8
    assert exp.bandwidth_crossover == pol.bandwidth_crossover(8)
    assert exp.decision.mode == pol.decide(8, 400.0).mode
    assert exp.plan_key in session.plans
    assert any(k.mode == "local" for k, _ in exp.candidates)
    assert "crossover" in exp.summary()


def test_session_requires_perfmap_for_policy():
    sess = InferenceSession.from_config("llama3.2-1b",
                                        reduced={"vocab_size": 64})
    with pytest.raises(RuntimeError, match="performance map"):
        sess.decide(8)


def test_session_generate_and_run(session):
    prompt = jnp.ones((2, 4), jnp.int32)
    out = session.generate(prompt, n_new=3)
    assert out.shape == (2, 3)
    lg = session.run("local", {"tokens": jnp.ones((1, 32), jnp.int32)})
    assert lg.shape == (1, 32, session.cfg.vocab_size)
    with pytest.raises(KeyError):
        session.run("voltage", {"tokens": jnp.ones((1, 32), jnp.int32)})


def test_session_generate_distinct_plans_not_conflated(session):
    """Two plans sharing a key (prism_sim L=4 vs L=8, both cr=0) must get
    distinct decode executables — and sim plans must decode at all
    (exact path; sim has no sharded-cache analogue)."""
    prompt = jnp.ones((1, 4), jnp.int32)
    n0 = len(session._decode_execs)
    o1 = session.generate(prompt, n_new=2, plan=ExecutionPlan.prism_sim(L=4))
    o2 = session.generate(prompt, n_new=2, plan=ExecutionPlan.prism_sim(L=8))
    assert o1.shape == o2.shape == (1, 2)
    assert len(session._decode_execs) == n0 + 2


def test_session_duplicate_plan_rejected(session):
    with pytest.raises(ValueError, match="already registered"):
        session.add_plan(ExecutionPlan.local())


def test_session_rejects_unresolved_L(session):
    """A cr-only plan (no physical L) cannot be jitted — clear error up
    front instead of a ZeroDivisionError at trace time."""
    with pytest.raises(ValueError, match="resolve_L"):
        session.add_plan(ExecutionPlan.parse("prism@3.3"))
    # resolving L makes the same plan registrable
    key = session.add_plan(ExecutionPlan.parse("prism@3.3").resolve_L(197))
    assert key == "prism@3.3"


def test_session_bandwidth_ewma():
    sess = InferenceSession.from_config(
        "llama3.2-1b", reduced={"vocab_size": 64},
        bandwidth_alpha=0.5, initial_bandwidth_mbps=400.0)
    sess.observe_bandwidth(200.0)
    assert sess.bandwidth == pytest.approx(300.0)


# --- legacy shims are gone -------------------------------------------------

def test_legacy_shims_removed():
    """The docs promised removal in this release: the serving package no
    longer exports the deprecated dispatcher/engine surfaces."""
    import repro.serving as serving
    assert not hasattr(serving, "AdaptiveDispatcher")
    assert not hasattr(serving, "ServeEngine")
    assert "AdaptiveDispatcher" not in serving.__all__
    assert "ServeEngine" not in serving.__all__
    with pytest.raises(ImportError):
        from repro.serving import AdaptiveDispatcher  # noqa: F401
    with pytest.raises(ImportError):
        from repro.serving.dispatcher import AdaptiveDispatcher  # noqa: F401,F811
