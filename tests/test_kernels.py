"""Per-kernel shape/dtype sweeps vs the pure-jnp ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode_op, flash_decode_ref
from repro.kernels.flash_decode.ops import merge_partials, validity_bias
from repro.kernels.prism_attention import (prism_attention_op,
                                           prism_attention_ref)
from repro.kernels.prism_attention.ops import build_mean_bias
from repro.kernels.segment_means import segment_means_op, segment_means_ref

RNG = np.random.RandomState(7)


def _tol(dtype):
    return dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 else \
        dict(atol=3e-5, rtol=1e-5)


@pytest.mark.parametrize("B,N,D,L", [(1, 16, 128, 4), (2, 64, 48, 8),
                                     (3, 33, 7, 11), (1, 256, 512, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_means_sweep(B, N, D, L, dtype):
    if N % L:
        pytest.skip("integer segments only")
    x = jnp.asarray(RNG.randn(B, N, D), dtype)
    out = segment_means_op(x, L)
    ref = segment_means_ref(x, L)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_segment_means_nd_features():
    x = jnp.asarray(RNG.randn(2, 32, 4, 16), jnp.float32)   # [B, N, Hk, dh]
    out = segment_means_op(x, 8)
    ref = segment_means_ref(x.reshape(2, 32, 64), 8).reshape(2, 8, 4, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("B,Nq,H,Hk,dh,P,L",
                         [(1, 16, 2, 2, 8, 2, 2), (2, 32, 4, 2, 16, 4, 4),
                          (1, 128, 8, 8, 64, 2, 8), (1, 24, 6, 2, 32, 3, 2)])
@pytest.mark.parametrize("causal", [False, True])
def test_prism_attention_sweep(B, Nq, H, Hk, dh, P, L, causal):
    q = jnp.asarray(RNG.randn(B, Nq, H, dh), jnp.float32)
    kl = jnp.asarray(RNG.randn(B, Nq, Hk, dh), jnp.float32)
    vl = jnp.asarray(RNG.randn(B, Nq, Hk, dh), jnp.float32)
    km = jnp.asarray(RNG.randn(B, P, L, Hk, dh), jnp.float32)
    vm = jnp.asarray(RNG.randn(B, P, L, Hk, dh), jnp.float32)
    pidx = P // 2
    out = prism_attention_op(q, kl, vl, km, vm, pidx, seg_size=4,
                             causal=causal)
    bias = build_mean_bias(B, P, L, pidx, 4, causal=causal)
    ref = prism_attention_ref(q, kl, vl, km.reshape(B, P * L, Hk, dh),
                              vm.reshape(B, P * L, Hk, dh), bias,
                              causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_prism_attention_bf16_and_softcap():
    B, Nq, H, dh, P, L = 1, 32, 2, 16, 2, 4
    q = jnp.asarray(RNG.randn(B, Nq, H, dh), jnp.bfloat16)
    kl = jnp.asarray(RNG.randn(B, Nq, H, dh), jnp.bfloat16)
    vl = jnp.asarray(RNG.randn(B, Nq, H, dh), jnp.bfloat16)
    km = jnp.asarray(RNG.randn(B, P, L, H, dh), jnp.bfloat16)
    vm = jnp.asarray(RNG.randn(B, P, L, H, dh), jnp.bfloat16)
    out = prism_attention_op(q, kl, vl, km, vm, 1, seg_size=4, causal=True,
                             softcap=50.0)
    bias = build_mean_bias(B, P, L, 1, 4, causal=True)
    ref = prism_attention_ref(q, kl, vl, km.reshape(B, P * L, H, dh),
                              vm.reshape(B, P * L, H, dh), bias, causal=True,
                              logit_softcap=50.0)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2,
                               rtol=3e-2)


def test_prism_kernel_matches_core_semantics():
    from repro.core.prism_attention import prism_attention as core
    B, Nq, H, dh, P, L = 2, 32, 4, 16, 4, 4
    q = jnp.asarray(RNG.randn(B, Nq, H, dh), jnp.float32)
    kl = jnp.asarray(RNG.randn(B, Nq, H, dh), jnp.float32)
    vl = jnp.asarray(RNG.randn(B, Nq, H, dh), jnp.float32)
    km = jnp.asarray(RNG.randn(B, P, L, H, dh), jnp.float32)
    vm = jnp.asarray(RNG.randn(B, P, L, H, dh), jnp.float32)
    for pidx in range(P):
        out = prism_attention_op(q, kl, vl, km, vm, pidx, seg_size=2,
                                 causal=True)
        ref = core(q, kl, vl, km, vm, pidx, 2, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5)


@pytest.mark.parametrize("B,S,H,Hk,dh", [(1, 32, 2, 2, 16), (2, 64, 4, 2, 16),
                                         (1, 128, 8, 1, 64), (3, 48, 6, 3, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, S, H, Hk, dh, dtype):
    q = jnp.asarray(RNG.randn(B, H, dh), dtype)
    k = jnp.asarray(RNG.randn(B, S, Hk, dh), dtype)
    v = jnp.asarray(RNG.randn(B, S, Hk, dh), dtype)
    clen = jnp.asarray(RNG.randint(1, S + 1, size=B))
    o, m, l = flash_decode_op(q, k, v, clen)
    orf, mrf, lrf = flash_decode_ref(q, k, v, validity_bias(B, S, clen))
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(l), np.asarray(lrf),
                               **_tol(dtype))


def test_flash_decode_window():
    B, S, H, dh = 1, 64, 2, 16
    q = jnp.asarray(RNG.randn(B, H, dh), jnp.float32)
    k = jnp.asarray(RNG.randn(B, S, H, dh), jnp.float32)
    v = jnp.asarray(RNG.randn(B, S, H, dh), jnp.float32)
    o, m, l = flash_decode_op(q, k, v, 50, window=16)
    from repro.core.prism_attention import reference_attention
    pos = jnp.arange(S)[None, :]
    mask = (pos < 50) & (pos >= 50 - 16)
    full = reference_attention(q[:, None], k, v, kv_mask=mask)[:, 0]
    np.testing.assert_allclose(np.asarray(o / l[..., None]),
                               np.asarray(full), atol=3e-5)


def test_flash_decode_merge_shards():
    B, S, H, dh, P = 2, 64, 4, 16, 4
    q = jnp.asarray(RNG.randn(B, H, dh), jnp.float32)
    k = jnp.asarray(RNG.randn(B, S, H, dh), jnp.float32)
    v = jnp.asarray(RNG.randn(B, S, H, dh), jnp.float32)
    clen = jnp.asarray([40, 64])
    parts = [flash_decode_op(q, k[:, i * 16:(i + 1) * 16],
                             v[:, i * 16:(i + 1) * 16], clen, offset=i * 16)
             for i in range(P)]
    merged = merge_partials(jnp.stack([p[0] for p in parts]),
                            jnp.stack([p[1] for p in parts]),
                            jnp.stack([p[2] for p in parts]))
    from repro.core.prism_attention import reference_attention
    pos = jnp.arange(S)[None, :]
    full = reference_attention(q[:, None], k, v,
                               kv_mask=pos < clen[:, None])[:, 0]
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               atol=3e-5)


# --- paged flash decode (page table via scalar prefetch) --------------------

@pytest.mark.parametrize("B,P,ps,MP,H,Hk,dh",
                         [(2, 9, 16, 4, 4, 2, 16), (3, 13, 8, 3, 6, 3, 32),
                          (1, 5, 32, 2, 8, 1, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_paged_matches_gather_reference(B, P, ps, MP, H, Hk,
                                                     dh, dtype):
    """Pallas paged kernel (page table as block index map through scalar
    prefetch) vs the jnp.take gather + dense reference, on random page
    tables with repeated pages and ragged valid lengths."""
    from repro.kernels.flash_decode import (flash_decode_paged_op,
                                            flash_decode_paged_ref,
                                            gather_pages)
    q = jnp.asarray(RNG.randn(B, H, dh), dtype)
    kp = jnp.asarray(RNG.randn(P, ps, Hk, dh), dtype)
    vp = jnp.asarray(RNG.randn(P, ps, Hk, dh), dtype)
    pt = jnp.asarray(RNG.randint(0, P, size=(B, MP)), jnp.int32)
    clen = jnp.asarray(RNG.randint(1, MP * ps + 1, size=B))
    bias = validity_bias(B, MP * ps, clen)
    o, m, l = flash_decode_paged_op(q, kp, vp, pt, clen, interpret=True)
    orf, mrf, lrf = flash_decode_paged_ref(q, kp, vp, pt, bias)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(l), np.asarray(lrf),
                               **_tol(dtype))
    # and the gather itself is the dense layout the dense op sees
    assert gather_pages(kp, pt).shape == (B, MP * ps, Hk, dh)


def test_flash_decode_paged_softcap_and_normalized():
    """Softcapped paged partials normalize to the dense op's output on the
    gathered layout — ONE validity definition shared by both paths."""
    from repro.kernels.flash_decode import (flash_decode_paged_op,
                                            gather_pages)
    B, P, ps, MP, H, dh = 2, 7, 16, 3, 4, 16
    q = jnp.asarray(RNG.randn(B, H, dh), jnp.float32)
    kp = jnp.asarray(RNG.randn(P, ps, H, dh), jnp.float32)
    vp = jnp.asarray(RNG.randn(P, ps, H, dh), jnp.float32)
    pt = jnp.asarray(RNG.randint(0, P, size=(B, MP)), jnp.int32)
    clen = jnp.asarray([17, 40])
    o, m, l = flash_decode_paged_op(q, kp, vp, pt, clen, softcap=30.0,
                                    interpret=True)
    od, md, ld = flash_decode_op(q, gather_pages(kp, pt),
                                 gather_pages(vp, pt), clen, softcap=30.0)
    np.testing.assert_allclose(np.asarray(o / l[..., None]),
                               np.asarray(od / ld[..., None]), atol=3e-5)


def test_paged_dispatch_backend_parity():
    """dispatch.decode_attention_paged: forced pallas (interpret) and
    forced reference agree on the same paged inputs."""
    from repro.kernels import dispatch as kdsp
    B, P, ps, MP, H, dh = 2, 6, 8, 3, 2, 16
    q = jnp.asarray(RNG.randn(B, 1, H, dh), jnp.float32)
    kp = jnp.asarray(RNG.randn(P, ps, H, dh), jnp.float32)
    vp = jnp.asarray(RNG.randn(P, ps, H, dh), jnp.float32)
    pt = jnp.asarray(RNG.randint(0, P, size=(B, MP)), jnp.int32)
    clen = jnp.asarray([5, 20])
    with kdsp.force_backend("pallas"):
        a = kdsp.decode_attention_paged(q, kp, vp, pt, clen)
    with kdsp.force_backend("reference"):
        b = kdsp.decode_attention_paged(q, kp, vp, pt, clen)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_pick_s_block_cached_and_shared():
    """Satellite: the s_block divisor search is computed once per S (an
    lru_cache), and dense + paged ops share ONE validity definition."""
    from repro.kernels.flash_decode.ops import pick_s_block, validity_mask
    assert pick_s_block(512) == 512
    assert pick_s_block(48) == 16
    assert pick_s_block(7) == 7 or pick_s_block(7) == 1
    info = pick_s_block.cache_info()
    pick_s_block(48)
    assert pick_s_block.cache_info().hits > info.hits
    m = validity_mask(2, 8, jnp.asarray([3, 8]))
    np.testing.assert_array_equal(
        np.asarray(m),
        np.arange(8)[None, :] < np.asarray([3, 8])[:, None])
