"""Training substrate: optimizer math, grad-accum equivalence, learning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.exchange import ExchangeConfig, ExchangeMode
from repro.models import registry
from repro.train.optimizer import OptConfig, adamw_init, adamw_update, schedule
from repro.train.train_step import build_train_step

XLOC = ExchangeConfig(ExchangeMode.LOCAL)


def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    cfg = OptConfig(lr=0.1, warmup_steps=0, weight_decay=0.0)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, opt, m = adamw_update(grads, opt, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.4


def test_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(schedule(jnp.asarray(0), cfg)) == pytest.approx(0.0)
    assert float(schedule(jnp.asarray(10), cfg)) == pytest.approx(1.0)
    assert float(schedule(jnp.asarray(100), cfg)) == pytest.approx(0.1)


def test_grad_clip():
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    cfg = OptConfig(clip_norm=1.0, warmup_steps=0)
    _, _, m = adamw_update({"w": jnp.asarray([1e4, 0, 0])}, opt, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(1e4)


def test_grad_accum_equivalence():
    """ga=2 must match ga=1 on the same global batch (up to f32 accum)."""
    cfg = get_config("llama3.2-1b").reduced()
    params = registry.init_params(cfg, seed=0)
    opt = adamw_init(params)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 16))),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 16)))}
    p1, _, m1 = jax.jit(build_train_step(cfg, XLOC, grad_accum=1))(
        params, opt, batch)
    p2, _, m2 = jax.jit(build_train_step(cfg, XLOC, grad_accum=2))(
        params, adamw_init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-3)


@pytest.mark.slow
def test_loss_decreases_on_markov_data():
    """End-to-end learning check: 30 steps on the synthetic Markov stream
    must beat the initial loss decisively."""
    from repro.train.loop import Trainer, TrainerConfig
    from repro.train.optimizer import OptConfig
    cfg = get_config("llama3.2-1b").reduced(vocab_size=64)
    tr = Trainer(cfg, XLOC, TrainerConfig(steps=60, ckpt_every=1000,
                                          ckpt_dir="/tmp/repro_test_ckpt",
                                          batch_size=16, seq_len=64),
                 opt_cfg=OptConfig(lr=5e-3, warmup_steps=3, total_steps=200,
                                   min_lr_frac=1.0))
    tr.run(60)
    first = np.mean([m["loss"] for m in tr.metrics_log[:3]])
    last = np.mean([m["loss"] for m in tr.metrics_log[-3:]])
    assert last < first - 0.25, (first, last)


def test_train_step_prism_sim_mode():
    """Training THROUGH the PRISM approximation (the paper's fine-tuning
    path) — gradients flow through segment means + scaling-aware softmax."""
    cfg = get_config("llama3.2-1b").reduced()
    xp = ExchangeConfig(ExchangeMode.PRISM_SIM, "seq", 4, L=2)
    params = registry.init_params(cfg, seed=0)
    opt = adamw_init(params)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 32))),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 32)))}
    p2, _, m = jax.jit(build_train_step(cfg, xp))(params, opt, batch)
    assert jnp.isfinite(m["loss"])
    assert float(m["grad_norm"]) > 0
